//! Accelerator-simulator walkthrough: reproduces the paper's hardware
//! evaluation (Fig. 5b + §5 headline) and prints the per-phase breakdown
//! that explains *where* EfficientGrad's advantage comes from — the
//! eliminated transposed-weight fetch and the pruned backward MACs.
//!
//!     cargo run --release --example accel_sim [-- --batch 16 --prune-rate 0.9]

use anyhow::Result;

use efficientgrad::accel::config::{efficientgrad, efficientgrad_bp_ablation, eyeriss_v2_bp};
use efficientgrad::accel::sim::{simulate_training, ALL_PHASES};
use efficientgrad::accel::workload::resnet18_cifar;
use efficientgrad::cli::{Args, FlagSpec};
use efficientgrad::figures::fig5b;
use efficientgrad::sparsity::expected_survivor_fraction;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let specs = vec![
        FlagSpec { name: "batch", help: "batch size", takes_value: true, default: Some("16") },
        FlagSpec { name: "prune-rate", help: "pruning rate P", takes_value: true, default: Some("0.9") },
    ];
    let args = Args::parse(&raw, &specs)?;
    let batch = args.get_usize("batch")?.unwrap();
    let p = args.get_f64("prune-rate")?.unwrap();

    let wl = resnet18_cifar(batch);
    let surv = expected_survivor_fraction(p);
    println!(
        "workload: {} — {:.1} GMAC fwd, {:.1} M params; P={p} -> survivor {surv:.3}",
        wl.name,
        wl.fwd_macs() as f64 / 1e9,
        wl.weight_words() as f64 / 1e6
    );

    // Fig. 5b + headline
    let out = fig5b::generate(&wl, p, None);
    out.report.print();
    fig5b::headline(p).print();

    // per-phase breakdown for both chips
    for cfg in [eyeriss_v2_bp(), efficientgrad()] {
        let r = simulate_training(&cfg, &wl, surv);
        println!("\n### {} — per-phase breakdown", cfg.name);
        println!("phase          |   GMACs | cycles(M) | DRAM MB | ms    | mJ");
        for ph in ALL_PHASES {
            let c = r.phase(ph);
            println!(
                "{:14} | {:7.2} | {:9.1} | {:7.1} | {:5.1} | {:5.1}",
                format!("{ph:?}"),
                c.macs / 1e9,
                c.cycles / 1e6,
                c.dram_words * 2.0 / 1e6,
                c.seconds * 1e3,
                c.energy.total_joules() * 1e3,
            );
        }
        println!(
            "total: {:.1} ms, {:.1} mJ, avg power {:.3} W",
            r.step_seconds() * 1e3,
            r.total_energy_j() * 1e3,
            r.avg_power_w(&cfg)
        );
    }

    // ablation: same silicon, dataflow features toggled off
    println!("\n### ablation — EfficientGrad array running plain BP (isolates dataflow)");
    let rows = efficientgrad::accel::compare(
        &[&efficientgrad_bp_ablation(), &efficientgrad()],
        &wl,
        surv,
    );
    for r in &rows {
        println!(
            "{:24} {:7.1} ms  {:.3} W  -> {:.2}x throughput, {:.2}x power",
            r.name, r.step_ms, r.power_w, r.norm_throughput, r.norm_power
        );
    }
    Ok(())
}
