//! Federated edge training — the paper's §1 deployment scenario.
//!
//! A leader coordinates N edge workers (each with its own PJRT client,
//! private data shard and EfficientGrad train loop), aggregating with
//! examples-weighted FedAvg each round. Reports accuracy per round,
//! communication volume (with the pruned-delta comm modes —
//! `--comm pruned|sign` — cutting the network tier by the survivor
//! fraction), per-round network vs device-bus Joules, and per-worker
//! (simulated) device time with optional straggler/dropout injection.
//!
//!     cargo run --release --example federated_edge [-- --workers 4 --rounds 6 --comm sign]

use anyhow::Result;

use efficientgrad::accel::{EnergyTable, LinkEnergy};
use efficientgrad::cli::{Args, FlagSpec};
use efficientgrad::config::{CommMode, FedConfig, TrainConfig};
use efficientgrad::coordinator::Leader;
use efficientgrad::manifest::Manifest;
use efficientgrad::runtime::Runtime;

fn main() -> Result<()> {
    efficientgrad::util::logging::init();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let specs = vec![
        FlagSpec { name: "workers", help: "edge workers", takes_value: true, default: Some("4") },
        FlagSpec { name: "rounds", help: "federated rounds", takes_value: true, default: Some("6") },
        FlagSpec { name: "local-steps", help: "steps per round per worker", takes_value: true, default: Some("8") },
        FlagSpec { name: "non-iid", help: "label-skewed shards", takes_value: false, default: None },
        FlagSpec { name: "straggler-prob", help: "straggler probability", takes_value: true, default: Some("0.25") },
        FlagSpec { name: "dropout-prob", help: "per-round worker dropout probability", takes_value: true, default: Some("0.0") },
        FlagSpec { name: "comm", help: "network encoding (dense|pruned|sign)", takes_value: true, default: Some("sign") },
        FlagSpec { name: "comm-rate", help: "comm pruning rate P", takes_value: true, default: Some("0.9") },
        FlagSpec { name: "model", help: "model", takes_value: true, default: Some("convnet_t") },
        FlagSpec { name: "pipeline", help: "pipelined leader schedule (off-thread eval + streaming aggregation)", takes_value: false, default: None },
        FlagSpec { name: "quorum", help: "fold at this fraction of dispatched reports (1.0 = full barrier)", takes_value: true, default: Some("1.0") },
        FlagSpec { name: "max-chain", help: "chained-delta resync window (0 = dense resyncs)", takes_value: true, default: Some("0") },
    ];
    let args = Args::parse(&raw, &specs)?;

    let cfg = FedConfig {
        workers: args.get_usize("workers")?.unwrap(),
        rounds: args.get_usize("rounds")?.unwrap(),
        local_steps: args.get_usize("local-steps")?.unwrap(),
        iid: !args.get_bool("non-iid"),
        straggler_prob: args.get_f64("straggler-prob")?.unwrap(),
        straggler_slowdown: 4.0,
        straggler_sleep: false,
        pipeline: args.get_bool("pipeline"),
        dropout_prob: args.get_f64("dropout-prob")?.unwrap(),
        comm: CommMode::parse(args.get("comm").unwrap())?,
        comm_rate: args.get_f64("comm-rate")?.unwrap(),
        quorum: args.get_f64("quorum")?.unwrap(),
        max_chain: args.get_usize("max-chain")?.unwrap(),
        train: TrainConfig {
            model: args.get("model").unwrap().to_string(),
            mode: "efficientgrad".into(),
            train_examples: 1024,
            test_examples: 256,
            ..Default::default()
        },
        // comm pruner, staleness decay and pipeline depth at their
        // documented defaults
        ..FedConfig::default()
    };
    cfg.validate()?; // shared range checks (comm_rate, dropout_prob, quorum)

    println!(
        "== federated: {} workers x {} rounds x {} local steps ({} shards, comm={} P={}, \
         quorum={}) ==",
        cfg.workers,
        cfg.rounds,
        cfg.local_steps,
        if cfg.iid { "IID" } else { "non-IID" },
        cfg.comm.as_str(),
        cfg.comm_rate,
        cfg.quorum,
    );

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&efficientgrad::artifacts_dir())?;
    let mut leader = Leader::new(&rt, &manifest, cfg.clone())?;
    let summary = leader.run()?;
    leader.shutdown();

    let energy = EnergyTable::smic14();
    let link = LinkEnergy::wifi();
    println!(
        "\nround | mean loss | eval acc | net KB | net mJ | dev mJ | dropped | late | worker secs (sim)"
    );
    for r in &summary.rounds {
        let times: Vec<String> = r.worker_secs.iter().map(|t| format!("{t:.2}")).collect();
        println!(
            "{:5} | {:9.4} | {:8.4} | {:6.1} | {:6.2} | {:6.3} | {:7} | {:4} | [{}]",
            r.round,
            r.mean_loss,
            r.eval_acc,
            r.network_bytes() as f64 / 1e3,
            r.network_joules(&link) * 1e3,
            r.device_joules(&energy) * 1e3,
            r.dropped.len(),
            r.late_reports,
            times.join(", ")
        );
    }
    let chained_total: usize = summary.rounds.iter().map(|r| r.chained_downlinks).sum();
    if chained_total > 0 {
        println!(
            "{chained_total} resyncs rode chained deltas instead of dense snapshots \
             (--max-chain {})",
            cfg.max_chain
        );
    }
    println!(
        "\nfinal acc {:.4}; comms: {:.2} MB up + {:.2} MB down \
         (params only — EfficientGrad's fixed feedback B never travels: \
         it is re-derived from the shared seed on-device)",
        summary.final_acc,
        summary.total_upload_bytes as f64 / 1e6,
        summary.total_download_bytes as f64 / 1e6
    );
    let net_j: f64 = summary.rounds.iter().map(|r| r.network_joules(&link)).sum();
    let dev_j: f64 = summary.rounds.iter().map(|r| r.device_joules(&energy)).sum();
    println!(
        "energy (measured ledgers): network {:.1} mJ vs device bus {:.2} mJ \
         — the radio dominates, which is what the comm codec attacks",
        net_j * 1e3,
        dev_j * 1e3
    );
    let dt = summary.total_device_transfer;
    println!(
        "device bus (fleet + leader eval): {:.2} MB state, {:.2} MB batches, \
         {:.2} MB metrics over {} steps / {} evals (docs/TRANSFER_MODEL.md)",
        (dt.state_up + dt.state_down) as f64 / 1e6,
        dt.batch_up as f64 / 1e6,
        dt.metrics_down as f64 / 1e6,
        dt.steps,
        dt.evals
    );
    anyhow::ensure!(
        summary.rounds.last().unwrap().mean_loss < summary.rounds[0].mean_loss,
        "federated training made no progress"
    );
    println!("FEDERATED RUN OK");
    Ok(())
}
