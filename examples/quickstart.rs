//! Quickstart: load the AOT-compiled EfficientGrad train step, run a few
//! SGD steps on a synthetic batch, and print loss + realized gradient
//! sparsity. ~30 lines of actual API use.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{Runtime, TrainState};

fn main() -> Result<()> {
    efficientgrad::util::logging::init();

    // 1. the runtime: a PJRT CPU client (Python is NOT involved)
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // 2. the manifest describes every AOT artifact python exported
    let manifest = Manifest::load(&efficientgrad::artifacts_dir())?;
    let model = manifest.model("convnet_t")?;
    println!(
        "model {}: {} params in {} tensors, batch {}",
        model.name,
        model.param_count,
        model.params.len(),
        model.batch
    );

    // 3. compile the EfficientGrad train step and initialize state
    let exe = rt.load(model.artifact("train_efficientgrad")?)?;
    let step = TrainState::new(exe, model)?;
    let mut store = ParamStore::init(model, 42);

    // 4. a synthetic CIFAR-like batch (offline stand-in, see DESIGN.md)
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 0,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());

    // 5. train: phases 1-3 of Algo. 1 run inside one XLA executable
    for i in 0..10 {
        let out = step.step(&mut store, &batch, 0.05, 0.9)?;
        println!(
            "step {i:2}  loss {:.4}  batch-acc {:.3}  grad-sparsity {:.3}",
            out.loss,
            out.acc,
            efficientgrad::util::stats::mean(&out.sparsity)
        );
    }
    println!("done — the loss should be falling and sparsity ~0.5 at P=0.9");
    Ok(())
}
