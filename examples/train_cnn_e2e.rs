//! End-to-end training driver — the repo's headline validation run.
//!
//! Trains ConvNet-S (default; `--model resnet8` / `resnet18` with `make
//! artifacts-full`) for several hundred steps on the synthetic CIFAR-10
//! stand-in through the full stack: Pallas kernels -> JAX train-step ->
//! HLO text -> PJRT CPU executable -> this Rust loop. Logs the loss curve,
//! evaluates periodically, writes metrics CSV, and cross-checks the
//! realized gradient sparsity against the paper's eq. 4/5 prediction.
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example train_cnn_e2e [-- --model convnet_s --steps 300]

use anyhow::Result;

use efficientgrad::cli::{Args, FlagSpec};
use efficientgrad::config::TrainConfig;
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::runtime::Runtime;
use efficientgrad::sparsity;
use efficientgrad::training::Trainer;

fn main() -> Result<()> {
    efficientgrad::util::logging::init();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let specs = vec![
        FlagSpec { name: "model", help: "model", takes_value: true, default: Some("convnet_s") },
        FlagSpec { name: "mode", help: "feedback mode", takes_value: true, default: Some("efficientgrad") },
        FlagSpec { name: "steps", help: "steps", takes_value: true, default: Some("300") },
        FlagSpec { name: "lr", help: "learning rate", takes_value: true, default: Some("0.05") },
        FlagSpec { name: "csv", help: "metrics csv path", takes_value: true, default: Some("reports/train_e2e.csv") },
    ];
    let args = Args::parse(&raw, &specs)?;

    let cfg = TrainConfig {
        model: args.get("model").unwrap().to_string(),
        mode: args.get("mode").unwrap().to_string(),
        steps: args.get_usize("steps")?.unwrap(),
        lr: args.get_f64("lr")?.unwrap(),
        train_examples: 2048,
        test_examples: 512,
        eval_every: 50,
        log_every: 10,
        ..Default::default()
    };

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&efficientgrad::artifacts_dir())?;
    println!(
        "== e2e training: {} / {} for {} steps (batch {}) ==",
        cfg.model,
        cfg.mode,
        cfg.steps,
        manifest.model(&cfg.model)?.batch
    );

    let ds = generate(&SynthConfig {
        n: cfg.train_examples + cfg.test_examples,
        difficulty: cfg.difficulty as f32,
        seed: cfg.seed,
        ..Default::default()
    });
    let (train, test) = ds.split(cfg.train_examples);

    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(&rt, &manifest, cfg.clone())?;
    let acc = trainer.run(&train, &test)?;
    let wall = t0.elapsed().as_secs_f64();

    // loss-curve summary (the EXPERIMENTS.md log)
    println!("\nloss curve (downsampled):");
    for (step, loss) in trainer.log.loss_curve(12) {
        println!("  step {step:5}  loss {loss:.4}");
    }
    let first = trainer.log.records.first().map(|r| r.loss).unwrap_or(f64::NAN);
    let last = trainer.log.trailing_loss(20).unwrap_or(f64::NAN);
    println!("\nfinal: eval_acc {acc:.4}  loss {first:.3} -> {last:.3}  wall {wall:.1}s  ({:.2} steps/s)",
        cfg.steps as f64 / wall);

    // sparsity cross-check: measured vs eq. 4/5 gaussian prediction
    if cfg.mode == "efficientgrad" {
        let measured = trainer.log.mean_sparsity();
        let predicted = sparsity::expected_zero_fraction(manifest.prune_rate);
        println!(
            "gradient sparsity: measured {measured:.3} vs gaussian-model {predicted:.3} (P={})",
            manifest.prune_rate
        );
    }

    if let Some(csv) = args.get("csv") {
        trainer.log.save_csv(std::path::Path::new(csv))?;
        println!("metrics -> {csv}");
    }
    anyhow::ensure!(last < first, "loss did not decrease over the run");
    anyhow::ensure!(acc > 0.3, "eval accuracy {acc} too close to chance");
    println!("E2E VALIDATION PASSED");
    Ok(())
}
