//! Integration tests for the federated coordinator (leader + workers over
//! real PJRT executables; each worker brings up its own client).

use efficientgrad::config::{FedConfig, TrainConfig};
use efficientgrad::coordinator::Leader;
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{resident_step_state_bytes, Runtime, TransferStats};

fn manifest() -> Option<Manifest> {
    Manifest::load(&efficientgrad::artifacts_dir()).ok()
}

fn small_cfg(workers: usize, rounds: usize) -> FedConfig {
    FedConfig {
        workers,
        rounds,
        local_steps: 3,
        iid: true,
        straggler_prob: 0.0,
        straggler_slowdown: 3.0,
        train: TrainConfig {
            model: "convnet_t".into(),
            mode: "efficientgrad".into(),
            train_examples: 256,
            test_examples: 64,
            difficulty: 0.4,
            lr: 0.05,
            ..Default::default()
        },
    }
}

#[test]
fn federated_two_workers_improves_over_rounds() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut leader = Leader::new(&rt, &m, small_cfg(2, 4)).unwrap();
    let summary = leader.run().unwrap();
    leader.shutdown();
    assert_eq!(summary.rounds.len(), 4);
    // learning signal: last round's mean loss below the first round's
    let first = summary.rounds.first().unwrap().mean_loss;
    let last = summary.rounds.last().unwrap().mean_loss;
    assert!(last < first, "no federated progress: {first} -> {last}");
    // accuracy above chance by round 4 on the easy dataset
    assert!(summary.final_acc > 0.15, "final acc {}", summary.final_acc);
    // comms accounting: 2 workers x 4 rounds x param bytes, both ways
    let model = m.model("convnet_t").unwrap();
    let expect = (model.param_count * 4 * 2 * 4) as u64;
    assert_eq!(summary.total_upload_bytes, expect);
    assert_eq!(summary.total_download_bytes, expect);
}

#[test]
fn round_report_ledger_matches_worker_transfer_sum() {
    // the tentpole accounting claim: RoundReport's device-bus totals are
    // exactly the fedavg-style aggregate of the per-worker TransferStats,
    // and each resident worker's round moves params-up + per-step tails
    // + one mutable-state sync down — never O(model) per step
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let cfg = small_cfg(2, 3);
    let local_steps = cfg.local_steps as u64;
    let mut leader = Leader::new(&rt, &m, cfg).unwrap();
    let summary = leader.run().unwrap();
    leader.shutdown();

    let model = m.model("convnet_t").unwrap();
    let probe = ParamStore::init(model, 0);
    let params_bytes = (probe.param_elements() * 4) as u64;
    let tail = resident_step_state_bytes(probe.feedback.len());

    let mut fleet_total = TransferStats::default();
    for r in &summary.rounds {
        assert_eq!(r.worker_transfer.len(), 2);
        let sum = r
            .worker_transfer
            .iter()
            .fold(TransferStats::default(), |acc, &t| acc + t);
        assert_eq!(r.device_transfer, sum, "round {} ledger != worker sum", r.round);
        for (w, t) in r.worker_transfer.iter().enumerate() {
            assert_eq!(t.steps, local_steps, "worker {w} step count");
            assert_eq!(t.state_up, params_bytes, "worker {w} broadcast upload");
            assert_eq!(
                t.state_down,
                local_steps * tail + probe.mutable_state_bytes(),
                "worker {w} downloads must be tails + one sync"
            );
        }
        // the leader's resident eval uploads the new global params once
        // per round, regardless of how many test batches it sweeps
        assert_eq!(r.leader_eval_transfer.state_up, params_bytes);
        assert!(r.leader_eval_transfer.evals > 0);
        fleet_total += r.device_transfer + r.leader_eval_transfer;
    }
    assert_eq!(summary.total_device_transfer, fleet_total);
    assert_eq!(summary.total_device_transfer.steps, 2 * 3 * local_steps);
}

#[test]
fn federated_non_iid_still_learns() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 4);
    cfg.iid = false; // label-skewed shards
    let mut leader = Leader::new(&rt, &m, cfg).unwrap();
    let summary = leader.run().unwrap();
    leader.shutdown();
    let first = summary.rounds.first().unwrap().mean_loss;
    let last = summary.rounds.last().unwrap().mean_loss;
    assert!(
        last < first * 1.05,
        "non-IID run diverged: {first} -> {last}"
    );
}

#[test]
fn stragglers_show_in_worker_times_not_results() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 2);
    cfg.straggler_prob = 1.0; // every worker is a straggler
    cfg.straggler_slowdown = 5.0;
    let mut leader = Leader::new(&rt, &m, cfg.clone()).unwrap();
    let with_stragglers = leader.run().unwrap();
    leader.shutdown();

    cfg.straggler_prob = 0.0;
    let mut leader2 = Leader::new(&rt, &m, cfg).unwrap();
    let without = leader2.run().unwrap();
    leader2.shutdown();

    // simulated per-worker time inflated ~5x; learning outcome unaffected
    let t_slow: f64 = with_stragglers.rounds[0].worker_secs.iter().sum();
    let t_fast: f64 = without.rounds[0].worker_secs.iter().sum();
    assert!(t_slow > t_fast * 2.0, "straggler time {t_slow} vs {t_fast}");
    assert!((with_stragglers.final_acc - without.final_acc).abs() < 0.5);
}
