//! Integration tests for the federated coordinator (leader + workers over
//! real PJRT executables; each worker brings up its own client).

use std::sync::atomic::AtomicBool;
use std::thread;

use efficientgrad::comm::wire::{sign_model_bytes_envelope, sparse_model_bytes};
use efficientgrad::config::{CommMode, CommPruner, FedConfig, TrainConfig, WireQuant};
use efficientgrad::coordinator::{self, runstore, Leader};
use efficientgrad::faults::FaultPlan;
use efficientgrad::manifest::Manifest;
use efficientgrad::net::client::{self, ClientConfig};
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{resident_step_state_bytes, Runtime, TransferStats};
use efficientgrad::testing::harness::{
    self, assert_round_parity, assert_twin_parity, Parity, TwinRun,
};

fn manifest() -> Option<Manifest> {
    Manifest::load(&efficientgrad::artifacts_dir()).ok()
}

fn small_cfg(workers: usize, rounds: usize) -> FedConfig {
    FedConfig {
        workers,
        rounds,
        local_steps: 3,
        iid: true,
        straggler_prob: 0.0,
        straggler_slowdown: 3.0,
        straggler_sleep: false,
        pipeline: false,
        dropout_prob: 0.0,
        comm: CommMode::Dense,
        comm_rate: 0.9,
        train: TrainConfig {
            model: "convnet_t".into(),
            mode: "efficientgrad".into(),
            train_examples: 256,
            test_examples: 64,
            difficulty: 0.4,
            lr: 0.05,
            ..Default::default()
        },
        // quorum 1.0 (full barrier), stochastic pruner, max_chain 0 —
        // the oracle knobs
        ..FedConfig::default()
    }
}

// Every integration run goes through the shared twin-run harness
// (testing::harness); tests that only need the pieces unpack them here.
fn run_to_summary(
    rt: &Runtime,
    m: &Manifest,
    cfg: FedConfig,
) -> (efficientgrad::coordinator::FedSummary, Vec<efficientgrad::tensor::Tensor>) {
    let t = harness::run(rt, m, cfg).unwrap();
    (t.summary, t.params)
}

#[test]
fn federated_two_workers_improves_over_rounds() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut leader = Leader::new(&rt, &m, small_cfg(2, 4)).unwrap();
    let summary = leader.run().unwrap();
    leader.shutdown();
    assert_eq!(summary.rounds.len(), 4);
    // learning signal: last round's mean loss below the first round's
    let first = summary.rounds.first().unwrap().mean_loss;
    let last = summary.rounds.last().unwrap().mean_loss;
    assert!(last < first, "no federated progress: {first} -> {last}");
    // accuracy above chance by round 4 on the easy dataset
    assert!(summary.final_acc > 0.15, "final acc {}", summary.final_acc);
    // comms accounting: 2 workers x 4 rounds x param bytes, both ways
    let model = m.model("convnet_t").unwrap();
    let expect = (model.param_count * 4 * 2 * 4) as u64;
    assert_eq!(summary.total_upload_bytes, expect);
    assert_eq!(summary.total_download_bytes, expect);
}

#[test]
fn round_report_ledger_matches_worker_transfer_sum() {
    // the tentpole accounting claim: RoundReport's device-bus totals are
    // exactly the fedavg-style aggregate of the per-worker TransferStats,
    // and each resident worker's round moves params-up + per-step tails
    // + one mutable-state sync down — never O(model) per step
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let cfg = small_cfg(2, 3);
    let local_steps = cfg.local_steps as u64;
    let mut leader = Leader::new(&rt, &m, cfg).unwrap();
    let summary = leader.run().unwrap();
    leader.shutdown();

    let model = m.model("convnet_t").unwrap();
    let probe = ParamStore::init(model, 0);
    let params_bytes = (probe.param_elements() * 4) as u64;
    let tail = resident_step_state_bytes(probe.feedback.len());

    let mut fleet_total = TransferStats::default();
    for r in &summary.rounds {
        assert_eq!(r.worker_transfer.len(), 2);
        let sum = r
            .worker_transfer
            .iter()
            .fold(TransferStats::default(), |acc, &t| acc + t);
        assert_eq!(r.device_transfer, sum, "round {} ledger != worker sum", r.round);
        for (w, t) in r.worker_transfer.iter().enumerate() {
            assert_eq!(t.steps, local_steps, "worker {w} step count");
            assert_eq!(t.state_up, params_bytes, "worker {w} broadcast upload");
            assert_eq!(
                t.state_down,
                local_steps * tail + probe.mutable_state_bytes(),
                "worker {w} downloads must be tails + one sync"
            );
        }
        // the leader's resident eval uploads the new global params once
        // per round, regardless of how many test batches it sweeps
        assert_eq!(r.leader_eval_transfer.state_up, params_bytes);
        assert!(r.leader_eval_transfer.evals > 0);
        fleet_total += r.device_transfer + r.leader_eval_transfer;
    }
    assert_eq!(summary.total_device_transfer, fleet_total);
    assert_eq!(summary.total_device_transfer.steps, 2 * 3 * local_steps);
}

#[test]
fn dense_comm_is_bit_for_bit_reproducible_with_legacy_bytes() {
    // `comm = dense` IS the legacy exchange: same aggregation, same
    // snapshot broadcasts, same 4·P·workers accounting both ways — and
    // identical configs give identical global params, so the explicit
    // mode pins the default
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let default_cfg = small_cfg(2, 3); // comm: Dense is the default
    let mut explicit = small_cfg(2, 3);
    explicit.comm = CommMode::Dense;
    let (sum_a, params_a) = run_to_summary(&rt, &m, default_cfg);
    let (sum_b, params_b) = run_to_summary(&rt, &m, explicit);
    assert_eq!(params_a, params_b, "dense comm drifted from the default path");
    assert_eq!(sum_a.final_acc, sum_b.final_acc);
    assert_eq!(sum_a.total_upload_bytes, sum_b.total_upload_bytes);
    let model = m.model("convnet_t").unwrap();
    let expect = (model.param_count * 4 * 2 * 3) as u64;
    assert_eq!(sum_a.total_upload_bytes, expect);
    assert_eq!(sum_a.total_download_bytes, expect);
    for r in &sum_a.rounds {
        assert!(r.dropped.is_empty());
        assert_eq!(r.dispatched, 2);
        assert_eq!(r.dense_downlinks, 2); // dense mode: snapshots always
        assert_eq!(r.uplink_survivors, 0); // survivor is a delta notion
    }
}

#[test]
fn pruned_comm_tracks_dense_accuracy_and_cuts_bytes() {
    // the tentpole acceptance: ≥5 rounds of error-feedback pruned comm
    // land within a pinned tolerance of the dense run's final accuracy,
    // while the steady-state wire bytes match the documented formulas
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    const ROUNDS: usize = 6;
    let (dense, _) = run_to_summary(&rt, &m, small_cfg(2, ROUNDS));

    let model = m.model("convnet_t").unwrap();
    let probe = ParamStore::init(model, 0);
    let n_tensors = probe.params.len() as u64;
    let dense_model_bytes = (probe.param_elements() * 4) as u64;

    for comm in [CommMode::Pruned, CommMode::Sign] {
        let mut cfg = small_cfg(2, ROUNDS);
        cfg.comm = comm;
        let (sum, _) = run_to_summary(&rt, &m, cfg);
        assert_eq!(sum.rounds.len(), ROUNDS);
        // expectation preservation carried to the network tier: the
        // compressed run's final accuracy stays within the pin
        assert!(
            (sum.final_acc - dense.final_acc).abs() <= 0.25,
            "{comm:?}: final acc {} vs dense {}",
            sum.final_acc,
            dense.final_acc
        );
        // and it still learns on its own terms
        let first = sum.rounds.first().unwrap().mean_loss;
        let last = sum.rounds.last().unwrap().mean_loss;
        assert!(last < first, "{comm:?}: no progress {first} -> {last}");

        for r in &sum.rounds {
            // round 0 resyncs everyone with a dense snapshot; after
            // that every downlink is a delta
            let expect_dense = if r.round == 0 { 2 } else { 0 };
            assert_eq!(r.dense_downlinks, expect_dense, "{comm:?} round {}", r.round);
            // uplinks are always deltas; measured bytes must equal the
            // documented formulas applied to the measured survivors
            match comm {
                CommMode::Pruned => {
                    assert_eq!(
                        r.upload_bytes,
                        sparse_model_bytes(r.uplink_survivors, 2 * n_tensors),
                        "{comm:?} round {}: uplink bytes != formula",
                        r.round
                    );
                    if r.round > 0 {
                        assert_eq!(
                            r.download_bytes,
                            sparse_model_bytes(r.downlink_survivors, 2 * n_tensors),
                            "{comm:?} round {}: downlink bytes != formula",
                            r.round
                        );
                    } else {
                        assert_eq!(r.download_bytes, 2 * dense_model_bytes);
                    }
                }
                _ => {
                    // measured sign messages sit inside the normative
                    // envelope (per-tensor formula pinned in tests/comm.rs)
                    let (lo, hi) =
                        sign_model_bytes_envelope(probe.params.iter().map(|t| t.len()));
                    let (lo, hi) = (lo * 2, hi * 2);
                    assert!(
                        (lo..=hi).contains(&r.upload_bytes),
                        "{comm:?} round {}: uplink {} outside [{lo}, {hi}]",
                        r.round,
                        r.upload_bytes
                    );
                }
            }
        }
        // the headline cut, steady state (round 0's downlink is a dense
        // snapshot by design): sign ≤ 1/5 of dense, pruned strictly below
        let steady_net: u64 = sum.rounds[1..]
            .iter()
            .map(|r| r.upload_bytes + r.download_bytes)
            .sum();
        let dense_net: u64 = dense.rounds[1..]
            .iter()
            .map(|r| r.upload_bytes + r.download_bytes)
            .sum();
        assert!(
            steady_net < dense_net,
            "{comm:?}: {steady_net} not below dense {dense_net}"
        );
        if comm == CommMode::Sign {
            assert!(
                steady_net * 5 <= dense_net,
                "sign comm missed the 5x cut: {steady_net} vs dense {dense_net}"
            );
        }
    }
}

#[test]
fn quantized_wire_tracks_dense_accuracy_and_cuts_pruned_bytes() {
    // the wire-v2 acceptance pin: replacing pruned-mode f32 survivors
    // with q8/q4 affine codes (each off by ≤ scale/2, the error carried
    // in the codec's error-feedback residual) must stay within the SAME
    // accuracy pin the f32 pruned run holds against dense, while the
    // steady-state wire bytes drop ~4x (q8, ~1.3 B/survivor vs 8) and
    // further at q4
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    const ROUNDS: usize = 6;
    let (dense, _) = run_to_summary(&rt, &m, small_cfg(2, ROUNDS));
    let mut f32cfg = small_cfg(2, ROUNDS);
    f32cfg.comm = CommMode::Pruned;
    let (f32run, _) = run_to_summary(&rt, &m, f32cfg.clone());
    let steady = |sum: &efficientgrad::coordinator::FedSummary| -> u64 {
        sum.rounds[1..]
            .iter()
            .map(|r| r.upload_bytes + r.download_bytes)
            .sum()
    };
    let mut nets = Vec::new();
    for wq in [WireQuant::Q8, WireQuant::Q4] {
        let mut cfg = f32cfg.clone();
        cfg.wire_quant = wq;
        let (sum, _) = run_to_summary(&rt, &m, cfg);
        assert_eq!(sum.rounds.len(), ROUNDS);
        assert!(
            (sum.final_acc - dense.final_acc).abs() <= 0.25,
            "{wq:?}: final acc {} vs dense {}",
            sum.final_acc,
            dense.final_acc
        );
        let first = sum.rounds.first().unwrap().mean_loss;
        let last = sum.rounds.last().unwrap().mean_loss;
        assert!(last < first, "{wq:?}: no progress {first} -> {last}");
        for r in &sum.rounds {
            // the round-0 resync is still a dense snapshot; every later
            // link is a quantized delta
            let expect_dense = if r.round == 0 { 2 } else { 0 };
            assert_eq!(r.dense_downlinks, expect_dense, "{wq:?} round {}", r.round);
            assert!(r.uplink_survivors > 0, "{wq:?} round {}", r.round);
        }
        nets.push(steady(&sum));
    }
    // the headline cut: q8 ≤ 1/4 of the f32 pruned exchange (survivor
    // counts land in the same ~46% regime, bytes/survivor drop 8 → ~1.3),
    // q4 strictly below q8
    let f32_net = steady(&f32run);
    assert!(
        nets[0] * 4 <= f32_net,
        "q8 missed the 4x cut: {} vs f32 pruned {f32_net}",
        nets[0]
    );
    assert!(nets[1] < nets[0], "q4 {} not below q8 {}", nets[1], nets[0]);
}

#[test]
fn wire_quant_off_is_bit_for_bit_the_legacy_exchange() {
    // `--wire-quant off` (the default) must keep every legacy code path:
    // a default-config run and an explicitly-off run — with churn, so
    // resync/chain paths fire too — are bit-for-bit twins across every
    // family, which together with the untouched PR 9 ledger pins above
    // proves no quantization machinery leaks into the off path
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut base = small_cfg(3, 5);
    base.comm = CommMode::Pruned;
    base.dropout_prob = 0.3;
    base.max_chain = 3;
    let mut explicit = base.clone();
    explicit.wire_quant = WireQuant::Off;
    let a = harness::run(&rt, &m, base).unwrap();
    let b = harness::run(&rt, &m, explicit).unwrap();
    assert_twin_parity("wire-quant off vs default", &a, &b, Parity::full());
}

#[test]
fn stale_quantized_reports_fold_below_full_weight_and_learn() {
    // λ < 1 staleness crossed with q4 quantization: a late report now
    // carries BOTH a decayed fold weight and a quantized payload — the
    // elastic schedule and the v2 wire must compose without either
    // breaking the other's accounting
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    const ROUNDS: usize = 6;
    let mut cfg = small_cfg(3, ROUNDS);
    cfg.comm = CommMode::Pruned;
    cfg.wire_quant = WireQuant::Q4;
    cfg.quorum = 0.5;
    cfg.staleness_decay = 0.7;
    cfg.pipeline_depth = 2;
    let (sum, _) = run_to_summary(&rt, &m, cfg);
    assert_eq!(sum.rounds.len(), ROUNDS);
    let mut total_late = 0usize;
    for r in &sum.rounds {
        if r.late_reports > 0 {
            // λ = 0.7 at staleness ≥ 1: each late report folds at < 1
            assert!(
                r.stale_weight_mass < r.late_reports as f64,
                "round {}: λ<1 mass {} not below late count {}",
                r.round,
                r.stale_weight_mass,
                r.late_reports
            );
            assert!(r.stale_weight_mass > 0.0, "round {}", r.round);
        }
        assert!(r.mean_loss.is_finite());
        assert!(r.eval_acc.is_finite());
        total_late += r.late_reports;
    }
    assert!(
        total_late >= ROUNDS - 2,
        "late folding barely exercised: {total_late} late reports"
    );
    assert!(sum.final_acc > 0.12, "final acc {}", sum.final_acc);
}

#[test]
fn partial_rounds_reweight_and_record_dropouts() {
    // a worker that misses a round must not abort the run: the leader
    // aggregates the reports that arrived, records the dropout, and
    // resyncs the returning worker with a dense snapshot
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(3, 5);
    cfg.comm = CommMode::Pruned;
    cfg.dropout_prob = 0.4;
    let (sum, _) = run_to_summary(&rt, &m, cfg);
    assert_eq!(sum.rounds.len(), 5);
    let total_dropped: usize = sum.rounds.iter().map(|r| r.dropped.len()).sum();
    assert!(total_dropped > 0, "dropout injection produced no dropouts");
    let mut resynced = 0usize;
    for (i, r) in sum.rounds.iter().enumerate() {
        // bookkeeping: every worker is either dropped or reported, and
        // with injection-only dropouts the dispatch count is the rest
        assert_eq!(r.dropped.len() + r.worker_transfer.len(), 3, "round {i}");
        assert_eq!(r.dispatched, 3 - r.dropped.len(), "round {i}");
        // rounds that measured anything report finite means; a fleet-wide
        // outage round (possible under injection) reports NaN instead of
        // a fake 0.0
        if r.worker_transfer.is_empty() {
            assert!(r.mean_loss.is_nan(), "round {i}: outage must report NaN");
        } else {
            assert!(r.mean_loss.is_finite());
        }
        if i > 0 {
            // dense downlinks after round 0 are exactly the resyncs:
            // workers offline last round that came back online this round
            let came_back = sum.rounds[i - 1]
                .dropped
                .iter()
                .filter(|&&id| !r.dropped.contains(&id))
                .count();
            assert_eq!(r.dense_downlinks, came_back, "round {i}");
            resynced += came_back;
        }
    }
    assert!(resynced > 0, "no worker ever resynced from a snapshot");
    // the run still learns despite the churn (10 classes, chance = 0.1)
    assert!(sum.final_acc > 0.12, "final acc {}", sum.final_acc);
}

#[test]
fn pipelined_matches_sequential_bit_for_bit() {
    // the pipelined schedule's acceptance pin: over ≥5 rounds with BOTH
    // dropout and straggler injection enabled and compressed comm, the
    // pipelined leader (streaming decode-at-arrival, worker-id-order f64
    // fold, off-thread eval) must reproduce the sequential oracle
    // exactly — global params, per-round eval accuracy, and every byte
    // ledger, bit for bit
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    for comm in [CommMode::Sign, CommMode::Dense] {
        let mut cfg = small_cfg(3, 5);
        cfg.comm = comm;
        cfg.dropout_prob = 0.3;
        cfg.straggler_prob = 0.5;
        let seq = harness::run(&rt, &m, cfg.clone()).unwrap();
        cfg.pipeline = true;
        let pipe = harness::run(&rt, &m, cfg).unwrap();
        // injection must actually have fired, or the test proves little
        assert!(
            seq.summary.rounds.iter().any(|r| !r.dropped.is_empty()),
            "{comm:?}: dropout injection produced no dropouts"
        );
        assert_twin_parity(&format!("pipelined {comm:?}"), &seq, &pipe, Parity::full());
    }
}

#[test]
fn outage_rounds_report_nan_and_are_skipped_by_summary() {
    // the `reports.len().max(1)` bugfix pin: a fleet-wide outage round
    // must report NaN means (no measurement exists), never a fake 0.0
    // that poisons averaged trajectories — and the summary helpers skip
    // those rounds
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 3);
    cfg.dropout_prob = 1.0; // every round is a fleet-wide outage
    let (sum, params) = run_to_summary(&rt, &m, cfg);
    assert_eq!(sum.rounds.len(), 3);
    for r in &sum.rounds {
        assert_eq!(r.dispatched, 0);
        assert_eq!(r.dropped, vec![0, 1]);
        assert!(r.worker_transfer.is_empty());
        assert!(r.mean_loss.is_nan(), "round {}: loss {}", r.round, r.mean_loss);
        assert!(r.mean_sparsity.is_nan(), "round {}", r.round);
        // the global model stands, and the leader still evaluates it
        assert!(r.eval_acc.is_finite());
        assert_eq!(r.upload_bytes, 0);
        assert_eq!(r.download_bytes, 0);
    }
    // nothing measured anywhere → the skipping average has no rounds left
    assert!(sum.mean_round_loss().is_nan());
    assert!(sum.mean_round_sparsity().is_nan());
    // untouched global: still exactly the init params
    let model = m.model("convnet_t").unwrap();
    let init = ParamStore::init(model, small_cfg(2, 3).train.seed);
    assert_eq!(params, init.params);
}

#[test]
fn full_barrier_quorum_is_bit_for_bit_the_oracle() {
    // the versioned-round acceptance pin: quorum = 1.0 with
    // pipeline_depth = 1 (and an explicitly non-default λ, which must be
    // inert — no report is ever late at a full barrier) reproduces the
    // default schedule bit for bit over ≥5 rounds with dropout AND
    // straggler injection — params, eval accs, every ledger
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut base = small_cfg(3, 5);
    base.comm = CommMode::Sign;
    base.dropout_prob = 0.3;
    base.straggler_prob = 0.5;
    let mut explicit = base.clone();
    explicit.quorum = 1.0;
    explicit.pipeline_depth = 1;
    explicit.max_chain = 0;
    explicit.staleness_decay = 0.9; // consulted only below quorum 1.0
    let a = harness::run(&rt, &m, base).unwrap();
    let b = harness::run(&rt, &m, explicit).unwrap();
    assert_twin_parity("full-barrier quorum", &a, &b, Parity::full());
    // the elastic-schedule machinery must be provably idle at a full
    // barrier, and every round advances exactly one version
    for r in a.summary.rounds.iter().chain(&b.summary.rounds) {
        assert_eq!(r.late_reports, 0, "round {}", r.round);
        assert_eq!(r.stale_weight_mass, 0.0, "round {}", r.round);
        assert_eq!(r.chained_downlinks, 0, "round {}", r.round);
        assert_eq!(r.version, r.round as u64 + 1, "round {}", r.round);
    }
}

#[test]
fn quorum_rounds_fold_stragglers_late_and_still_learn() {
    // quorum = 0.5 over 3 healthy workers: every round closes after
    // ⌈0.5·3⌉ = 2 reports, the third is stashed and folded into a later
    // round as a late report. λ = 1 keeps a late report's full weight
    // (the synchronous-fold equivalence is pinned at the unit level in
    // coordinator::fedavg); stale_weight_mass must then equal the late
    // count exactly.
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    const ROUNDS: usize = 6;
    let mut cfg = small_cfg(3, ROUNDS);
    cfg.quorum = 0.5;
    cfg.staleness_decay = 1.0;
    cfg.pipeline_depth = 2;
    let (sum, _) = run_to_summary(&rt, &m, cfg);
    assert_eq!(sum.rounds.len(), ROUNDS);
    let mut total_late = 0usize;
    let mut total_folded = 0usize;
    for r in &sum.rounds {
        assert_eq!(r.dispatched, 3, "round {}", r.round);
        assert!(r.dropped.is_empty(), "round {}: healthy workers dropped", r.round);
        // every round folds exactly its quorum of fresh reports plus
        // whatever stragglers landed; ledgers follow arrival accounting
        assert_eq!(
            r.worker_transfer.len(),
            2 + r.late_reports,
            "round {}: ledger entries != fresh + late",
            r.round
        );
        assert!(
            (r.stale_weight_mass - r.late_reports as f64).abs() < 1e-12,
            "round {}: λ=1 mass {} != late count {}",
            r.round,
            r.stale_weight_mass,
            r.late_reports
        );
        assert!(r.mean_loss.is_finite());
        assert!(r.eval_acc.is_finite());
        total_late += r.late_reports;
        total_folded += r.worker_transfer.len();
    }
    // each round stashes exactly one straggler; all but the final
    // rounds' stragglers (bounded by the pipeline depth) fold late
    assert!(
        total_late >= ROUNDS - 2,
        "late folding barely exercised: {total_late} late reports"
    );
    assert!(
        total_folded >= 3 * ROUNDS - 2,
        "lost reports: {total_folded} folded of {} dispatched",
        3 * ROUNDS
    );
    // the run still learns at chance-beating accuracy (10 classes)
    assert!(sum.final_acc > 0.12, "final acc {}", sum.final_acc);
}

#[test]
fn chained_downlinks_replace_dense_resyncs_within_the_window() {
    // twin runs under identical dropout injection (same seeds → same
    // draw sequence), differing only in max_chain: every comeback that
    // the max_chain=0 run resynced with a dense 4·P snapshot must ride a
    // chained delta in the max_chain=3 run (k = 2 fits the window; a
    // worker's FIRST dispatch is dense in both runs), and the chain is
    // cheaper on the wire in sign mode
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    const ROUNDS: usize = 6;
    let mk = |max_chain: usize| {
        let mut cfg = small_cfg(3, ROUNDS);
        cfg.comm = CommMode::Sign;
        cfg.dropout_prob = 0.4;
        cfg.max_chain = max_chain;
        cfg
    };
    let (dense_resync, _) = run_to_summary(&rt, &m, mk(0));
    let (chained, _) = run_to_summary(&rt, &m, mk(3));
    let total_chained: usize = chained.rounds.iter().map(|r| r.chained_downlinks).sum();
    assert!(
        total_chained > 0,
        "dropout injection produced no chained resyncs (seed drift?)"
    );
    for (d, c) in dense_resync.rounds.iter().zip(&chained.rounds) {
        // identical injection: the same workers were reachable
        assert_eq!(d.dispatched, c.dispatched, "round {}", d.round);
        assert_eq!(d.dropped, c.dropped, "round {}", d.round);
        // bookkeeping: every resync is dense or chained, totals agree
        assert_eq!(
            c.dense_downlinks + c.chained_downlinks,
            d.dense_downlinks,
            "round {}: resyncs went missing",
            d.round
        );
        assert_eq!(c.version, d.version, "round {}", d.round);
    }
    // up to the first chained round the two runs are bit-identical (the
    // only divergence is the resync payload), so that round's downlink
    // ledger is directly comparable — the chain must undercut the dense
    // snapshot it replaced
    let first = chained
        .rounds
        .iter()
        .position(|r| r.chained_downlinks > 0)
        .unwrap();
    for i in 0..first {
        assert_eq!(
            chained.rounds[i].download_bytes, dense_resync.rounds[i].download_bytes,
            "round {i}: runs diverged before the first chain"
        );
    }
    assert!(
        chained.rounds[first].download_bytes < dense_resync.rounds[first].download_bytes,
        "round {first}: chain {} B did not undercut dense resync {} B",
        chained.rounds[first].download_bytes,
        dense_resync.rounds[first].download_bytes
    );
    // both runs still learn through the churn
    assert!(chained.final_acc > 0.12, "final acc {}", chained.final_acc);
}

#[test]
fn topk_comm_pruner_sharpens_the_pruned_cut() {
    // the eq. 3 stochastic pruner floors out at ≈46% survivors at P=0.9;
    // exact top-k ships exactly (1−P) = 10% — the uplink ledger must
    // show the sharper cut, at comparable accuracy (error feedback
    // carries the bias)
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    const ROUNDS: usize = 6;
    let mut stoch = small_cfg(2, ROUNDS);
    stoch.comm = CommMode::Pruned;
    let mut topk = stoch.clone();
    topk.comm_pruner = CommPruner::TopK;
    let (s, _) = run_to_summary(&rt, &m, stoch);
    let (t, _) = run_to_summary(&rt, &m, topk);
    let s_up: u64 = s.rounds.iter().map(|r| r.upload_bytes).sum();
    let t_up: u64 = t.rounds.iter().map(|r| r.upload_bytes).sum();
    // ~10% vs ~46% survivors: at least a 2x sharper uplink
    assert!(
        t_up * 2 <= s_up,
        "top-k uplink {t_up} B not ≤ half of stochastic {s_up} B"
    );
    // survivor budget is exact: ⌈0.1·E⌉ per tensor per worker per round
    let model = m.model("convnet_t").unwrap();
    let probe = ParamStore::init(model, 0);
    let budget: u64 = probe
        .params
        .iter()
        .map(|p| ((p.len() as f64) * 0.1).ceil() as u64)
        .sum();
    for r in &t.rounds {
        // the budget is a hard ceiling; a selected coordinate can only
        // go missing if its delta is exactly 0.0 (encode ships nonzeros),
        // so the floor is tight
        assert!(
            r.uplink_survivors <= 2 * budget,
            "round {}: top-k overshot the budget: {} > {}",
            r.round,
            r.uplink_survivors,
            2 * budget
        );
        assert!(
            r.uplink_survivors * 10 >= 2 * budget * 9,
            "round {}: top-k shipped {} of budget {}",
            r.round,
            r.uplink_survivors,
            2 * budget
        );
    }
    // and accuracy stays in the same regime as the stochastic run
    assert!(
        (t.final_acc - s.final_acc).abs() <= 0.3,
        "top-k acc {} vs stochastic {}",
        t.final_acc,
        s.final_acc
    );
}

#[test]
fn federated_non_iid_still_learns() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 4);
    cfg.iid = false; // label-skewed shards
    let mut leader = Leader::new(&rt, &m, cfg).unwrap();
    let summary = leader.run().unwrap();
    leader.shutdown();
    let first = summary.rounds.first().unwrap().mean_loss;
    let last = summary.rounds.last().unwrap().mean_loss;
    assert!(
        last < first * 1.05,
        "non-IID run diverged: {first} -> {last}"
    );
}

#[test]
fn stragglers_show_in_worker_times_not_results() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 2);
    cfg.straggler_prob = 1.0; // every worker is a straggler
    cfg.straggler_slowdown = 5.0;
    let mut leader = Leader::new(&rt, &m, cfg.clone()).unwrap();
    let with_stragglers = leader.run().unwrap();
    leader.shutdown();

    cfg.straggler_prob = 0.0;
    let mut leader2 = Leader::new(&rt, &m, cfg).unwrap();
    let without = leader2.run().unwrap();
    leader2.shutdown();

    // simulated per-worker time inflated ~5x; learning outcome unaffected
    let t_slow: f64 = with_stragglers.rounds[0].worker_secs.iter().sum();
    let t_fast: f64 = without.rounds[0].worker_secs.iter().sum();
    assert!(t_slow > t_fast * 2.0, "straggler time {t_slow} vs {t_fast}");
    assert!((with_stragglers.final_acc - without.final_acc).abs() < 0.5);
}

#[test]
fn zero_fault_plan_is_bit_for_bit_no_plan() {
    // the fault subsystem's determinism contract: a plan whose every
    // probability is zero must be *behaviorally identical* to no plan —
    // same params, same eval accs, same payload AND envelope ledgers —
    // because plan decisions live on their own RNG streams and an
    // unfired decision perturbs nothing
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 4);
    cfg.comm = CommMode::Pruned;
    let clean = harness::run(&rt, &m, cfg.clone()).unwrap();
    cfg.faults = Some("seed=99".parse().unwrap()); // every knob zero
    let zeroed = harness::run(&rt, &m, cfg).unwrap();
    assert_twin_parity("zero fault plan", &clean, &zeroed, Parity::full());
    for r in clean.summary.rounds.iter().chain(&zeroed.summary.rounds) {
        // nothing fired, nothing was detected
        assert_eq!(r.corrupt_frames, 0, "round {}", r.round);
        assert_eq!(r.rejected_reports, 0, "round {}", r.round);
        assert_eq!(r.downlink_retries, 0, "round {}", r.round);
        // envelope accounting on a clean 2-worker round: one sealed task
        // down + one sealed report up per worker, 24 B of header each
        assert_eq!(r.envelope_bytes, 2 * 2 * 24, "round {}", r.round);
    }
}

#[test]
fn nacked_downlink_retries_dense_and_the_worker_survives() {
    // escalation step 1: a corrupt downlink is rejected worker-side
    // (never applied), nacked, and answered with ONE dense retry — the
    // worker completes the round and is not dropped
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 4);
    cfg.comm = CommMode::Pruned;
    cfg.faults = Some(FaultPlan {
        force_downlink_corrupt: vec![(1, 0, 0)], // round 1, worker 0, initial send
        ..FaultPlan::default()
    });
    let (sum, _) = run_to_summary(&rt, &m, cfg);
    for r in &sum.rounds {
        assert!(r.dropped.is_empty(), "round {}: a nacked worker was dropped", r.round);
        assert_eq!(r.worker_transfer.len(), 2, "round {}: a report went missing", r.round);
        assert_eq!(r.corrupt_frames, 0, "round {}: nacks are not corruption", r.round);
        if r.round == 1 {
            assert_eq!(r.downlink_retries, 1, "the nack must draw exactly one retry");
            // steady-state round, so the only dense downlink is the retry
            assert_eq!(r.dense_downlinks, 1);
        } else {
            assert_eq!(r.downlink_retries, 0, "round {}", r.round);
        }
    }
    assert!(sum.final_acc.is_finite());
}

#[test]
fn double_corruption_quarantines_then_dense_resyncs() {
    // escalation step 2: when the dense retry is corrupted too, the
    // worker is written off for the round (dropped, replica unknown) and
    // the next round's dispatch dense-resyncs it
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(2, 4);
    cfg.comm = CommMode::Pruned;
    cfg.faults = Some(FaultPlan {
        force_downlink_corrupt: vec![(1, 0, 0), (1, 0, 1)], // initial send AND retry
        ..FaultPlan::default()
    });
    let (sum, _) = run_to_summary(&rt, &m, cfg);
    let r1 = &sum.rounds[1];
    assert_eq!(r1.downlink_retries, 1, "the ladder allows exactly one retry");
    assert_eq!(r1.dropped, vec![0], "the double-corrupted worker must be quarantined");
    assert_eq!(r1.worker_transfer.len(), 1, "only the healthy worker folds");
    let r2 = &sum.rounds[2];
    assert!(r2.dropped.is_empty(), "the quarantined worker must come back");
    assert_eq!(r2.dense_downlinks, 1, "the comeback must ride a dense resync");
    assert_eq!(r2.worker_transfer.len(), 2);
    assert!(sum.final_acc.is_finite());
}

#[test]
fn poisoned_and_crashed_workers_recover_on_identical_trajectories() {
    // the poisoned-replica pin: a worker that poisons its replica (both
    // downlink attempts corrupted) and a worker that crashes at step 0
    // leave *identical* model state behind — neither stepped, both are
    // quarantined for the round and dense-resynced — so twin runs must
    // reproduce each other's params and eval accs bit for bit (only the
    // wire ledgers differ: the poisoned run paid for a retry)
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut base = small_cfg(3, 4);
    base.comm = CommMode::Pruned;
    let mut poisoned = base.clone();
    poisoned.faults = Some(FaultPlan {
        force_downlink_corrupt: vec![(1, 0, 0), (1, 0, 1)],
        ..FaultPlan::default()
    });
    let mut crashed = base;
    crashed.faults = Some(FaultPlan {
        force_crash: vec![(1, 0, 0)], // dies before its first local step
        ..FaultPlan::default()
    });
    let p = harness::run(&rt, &m, poisoned).unwrap();
    let c = harness::run(&rt, &m, crashed).unwrap();
    // identical trajectories on deliberately different wire/schedule
    // paths — exactly what the trajectory family pins
    assert_twin_parity("poisoned vs crashed", &p, &c, Parity::trajectory());
    for (a, b) in p.summary.rounds.iter().zip(&c.summary.rounds) {
        assert_eq!(a.dropped, b.dropped, "round {}", a.round);
    }
    // both runs wrote worker 0 off in round 1 — by different detectors
    assert_eq!(p.summary.rounds[1].dropped, vec![0]);
    assert_eq!(
        p.summary.rounds[1].downlink_retries, 1,
        "poison path: nack → retry → give up"
    );
    assert_eq!(
        c.summary.rounds[1].downlink_retries, 0,
        "crash path: silence, no nack"
    );
    // and both resynced it the same way next round
    assert_eq!(p.summary.rounds[2].dense_downlinks, 1);
    assert_eq!(c.summary.rounds[2].dense_downlinks, 1);
}

#[test]
fn kill_and_resume_reproduces_the_uninterrupted_run() {
    // the durability pin: kill the coordinator after round 1, resume
    // from the run store, and the stitched run must be bit-for-bit the
    // uninterrupted one — params, per-round eval accs, payload ledgers
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let dir = std::env::temp_dir().join(format!("effgrad_fed_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut base = small_cfg(3, 4);
    base.comm = CommMode::Pruned;

    let x = harness::run(&rt, &m, base.clone()).unwrap();
    assert_eq!(x.summary.rounds.len(), 4);

    let mut killed = base.clone();
    killed.run_store = Some(dir.to_string_lossy().into_owned());
    killed.faults = Some(FaultPlan {
        kill_round: Some(1),
        ..FaultPlan::default()
    });
    let y1 = harness::run(&rt, &m, killed).unwrap();
    assert_eq!(y1.summary.rounds.len(), 2, "the kill must halt the run after round 1");

    let mut resumed = base;
    resumed.run_store = Some(dir.to_string_lossy().into_owned());
    resumed.resume = true;
    let y2 = harness::run(&rt, &m, resumed).unwrap();
    assert_eq!(y2.summary.rounds.len(), 2, "the resume must run exactly rounds 2 and 3");
    assert_eq!(y2.summary.rounds[0].round, 2);

    // the headline: identical final model, bit for bit
    assert_eq!(x.params, y2.params, "resume forked the trajectory");
    // every round of the stitched run matches its uninterrupted twin, at
    // FULL families — every ledger, schedule, and device field
    assert_round_parity(
        "kill/resume",
        &x.summary.rounds,
        y1.summary.rounds.iter().chain(&y2.summary.rounds),
        Parity::full(),
    );
    assert_eq!(
        x.summary.total_upload_bytes,
        y1.summary.total_upload_bytes + y2.summary.total_upload_bytes,
        "uplink bytes must be conserved across the kill"
    );
    assert_eq!(
        x.summary.total_download_bytes,
        y1.summary.total_download_bytes + y2.summary.total_download_bytes
    );
    // resuming under a different core config must refuse, not fork
    let mut wrong = small_cfg(3, 5); // rounds differ → different hash
    wrong.comm = CommMode::Pruned;
    wrong.run_store = Some(dir.to_string_lossy().into_owned());
    wrong.resume = true;
    assert!(
        Leader::new(&rt, &m, wrong).is_err(),
        "resume accepted a store written under a different config"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn two_tier_aggregation_is_bit_for_bit_the_flat_path() {
    // the fleet-tier acceptance pin: with quorum 1.0, λ = 1, and
    // sample_m = N (every knob at its oracle setting, all stated
    // explicitly), routing reports through 2 edge aggregators instead of
    // folding flat must be a pure no-op — params, eval accs, and every
    // PR-6-era byte ledger bit for bit, under live dropout AND straggler
    // injection. Only the tier ledger itself may (must) differ: the
    // tiered run prices its edge→root prefolds, the flat run ships none.
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut base = small_cfg(3, 5);
    base.comm = CommMode::Pruned;
    base.quorum = 1.0;
    base.staleness_decay = 1.0; // λ = 1, explicit
    base.sample_m = 3; // = N, explicit: the literal full-fleet path
    base.dropout_prob = 0.3;
    base.straggler_prob = 0.5;
    let mut tiered = base.clone();
    tiered.aggregators = 2;
    let flat = harness::run(&rt, &m, base).unwrap();
    let two_tier = harness::run(&rt, &m, tiered).unwrap();
    // injection must actually have fired, or the test proves little
    assert!(
        flat.summary.rounds.iter().any(|r| !r.dropped.is_empty()),
        "dropout injection produced no dropouts"
    );
    assert_twin_parity("two-tier vs flat", &flat, &two_tier, Parity::full());
    // the tier ledger is the one permitted difference, and it must say
    // what actually happened: the flat run never opened an edge tier,
    // the tiered run shipped a priced prefold whenever anything folded
    for r in &flat.summary.rounds {
        assert_eq!(r.aggregators, 1, "round {}: flat run grew a tier", r.round);
        assert_eq!(r.tier_upload_bytes, 0, "round {}: flat run priced a tier", r.round);
    }
    for r in &two_tier.summary.rounds {
        assert_eq!(r.aggregators, 2, "round {}", r.round);
        if r.worker_transfer.is_empty() {
            // fleet-wide outage: no reports, no prefolds to ship
            assert_eq!(r.tier_upload_bytes, 0, "round {}: outage priced a tier", r.round);
        } else {
            assert!(
                r.tier_upload_bytes > 0,
                "round {}: edge→root prefolds went unpriced",
                r.round
            );
        }
    }
}

#[test]
fn sampled_cohorts_are_deterministic_and_schedule_independent() {
    // cohort sampling's determinism pins: (1) the pipelined leader draws
    // the exact cohort sequence the sequential oracle draws — full
    // parity, cohorts included (the schedule family compares them);
    // (2) the sample stream is its own RNG stream, so turning fault
    // knobs on (which consume the dropout/straggler streams) must not
    // move a single cohort.
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(4, 5);
    cfg.comm = CommMode::Pruned;
    cfg.sample_m = 2;
    let seq = harness::run(&rt, &m, cfg.clone()).unwrap();
    let mut piped = cfg.clone();
    piped.pipeline = true;
    let pipe = harness::run(&rt, &m, piped).unwrap();
    assert_twin_parity("sampled sequential vs pipelined", &seq, &pipe, Parity::full());
    for r in &seq.summary.rounds {
        assert_eq!(r.cohort.len(), 2, "round {}: cohort size", r.round);
        assert!(
            r.cohort.windows(2).all(|w| w[0] < w[1]),
            "round {}: cohort {:?} not strictly ascending",
            r.round,
            r.cohort
        );
        assert!(r.cohort.iter().all(|&w| w < 4), "round {}: unknown worker", r.round);
        // no churn injected: everyone sampled is dispatched
        assert_eq!(r.dispatched, 2, "round {}", r.round);
        assert!(r.dropped.is_empty(), "round {}", r.round);
    }
    // the sampler must actually resample: 5 draws of 2-of-4 freezing on
    // one cohort means the stream is not advancing
    let distinct: std::collections::BTreeSet<_> =
        seq.summary.rounds.iter().map(|r| r.cohort.clone()).collect();
    assert!(distinct.len() > 1, "sampler froze on {:?}", seq.summary.rounds[0].cohort);
    // stream disjointness: fault knobs draw on their own streams
    let mut churned = cfg;
    churned.dropout_prob = 0.4;
    churned.straggler_prob = 0.5;
    let c = harness::run(&rt, &m, churned).unwrap();
    for (a, b) in seq.summary.rounds.iter().zip(&c.summary.rounds) {
        assert_eq!(
            a.cohort, b.cohort,
            "round {}: dropout/straggler draws moved the cohort",
            a.round
        );
    }
}

#[test]
fn sample_m_off_and_full_fleet_are_bit_for_bit() {
    // sample_m = 0 (the default: sampling off) and sample_m = N (an
    // explicit full fleet) both take the literal pre-fleet dispatch path:
    // the sample stream is never consumed, the cohort field stays empty,
    // and the runs are bit-for-bit twins across every family
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut off = small_cfg(3, 4);
    off.comm = CommMode::Sign;
    let mut full = off.clone();
    full.sample_m = 3;
    let a = harness::run(&rt, &m, off).unwrap();
    let b = harness::run(&rt, &m, full).unwrap();
    assert_twin_parity("sample_m off vs = N", &a, &b, Parity::full());
    for r in a.summary.rounds.iter().chain(&b.summary.rounds) {
        assert!(r.cohort.is_empty(), "round {}: full fleet reported a cohort", r.round);
        assert_eq!(r.dispatched, 3, "round {}", r.round);
    }
}

#[cfg(feature = "simd")]
#[test]
fn simd_and_scalar_kernels_are_bit_for_bit_twin_runs() {
    // the tentpole's end-to-end pin: a federated run with every host
    // kernel forced down the scalar oracle path must be a bit-for-bit
    // twin — across ALL parity families — of the same run on the
    // vectorized kernels. Chunk boundaries are fixed by util::par, so
    // vectorizing inside a chunk must not move a single ledger byte,
    // survivor count, or parameter bit. Toggling the global force flag
    // while other tests run concurrently is safe for exactly the reason
    // this test exists: the two paths are indistinguishable.
    use efficientgrad::util::simd;
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    if !simd::available() {
        eprintln!("SKIP: simd compiled in but not available on this host");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    for comm in [CommMode::Sign, CommMode::Pruned] {
        let mut cfg = small_cfg(3, 3);
        cfg.comm = comm;
        simd::force_scalar(true);
        let scalar = harness::run(&rt, &m, cfg.clone());
        simd::force_scalar(false);
        let scalar = scalar.unwrap();
        let vector = harness::run(&rt, &m, cfg).unwrap();
        assert_twin_parity(
            &format!("scalar vs simd kernels ({comm:?})"),
            &scalar,
            &vector,
            Parity::full(),
        );
    }
}

/// Point `cfg.workers` client threads at a TCP leader on `addr` — each
/// builds its own shard/artifact/runtime state via [`spawn_edge_worker`]
/// and serves rounds, exactly what an `efficientgrad worker --connect`
/// process does (the manifest is re-loaded per thread for the same
/// reason: a remote worker shares no memory with the leader).
fn spawn_fleet(cfg: &FedConfig, addr: &str) -> Vec<thread::JoinHandle<anyhow::Result<()>>> {
    (0..cfg.workers)
        .map(|id| {
            let cfg = cfg.clone();
            let addr = addr.to_string();
            thread::spawn(move || {
                let m = Manifest::load(&efficientgrad::artifacts_dir())?;
                let worker = coordinator::spawn_edge_worker(&m, &cfg, id)?;
                client::serve(
                    &addr,
                    &ClientConfig {
                        worker_id: id,
                        config_hash: runstore::config_hash(&cfg),
                        heartbeat_ms: cfg.heartbeat_ms,
                        round_deadline_ms: cfg.round_deadline_ms,
                        seed: cfg.train.seed,
                        max_connect_attempts: 12,
                    },
                    worker,
                )
            })
        })
        .collect()
}

/// Join a TCP client fleet after the leader is gone. A worker severed
/// in the run's *final* round has no way to learn the run ended — it
/// redials a dead address until its budget runs out, exactly as a real
/// deployment's orphaned worker would — so dial exhaustion is the one
/// tolerated error; anything else fails the test.
fn join_fleet(fleet: Vec<thread::JoinHandle<anyhow::Result<()>>>) {
    for h in fleet {
        if let Err(e) = h.join().unwrap() {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("could not reach") || msg.contains("exhausted"),
                "client failed for a non-teardown reason: {msg}"
            );
        }
    }
}

/// Run a federated config over loopback TCP: bind on an OS-assigned
/// port, bring up the client fleet, run, capture the twin, tear down.
fn run_tcp(rt: &Runtime, m: &Manifest, mut cfg: FedConfig) -> TwinRun {
    cfg.listen = Some("127.0.0.1:0".into());
    let mut leader = Leader::new(rt, m, cfg.clone()).unwrap();
    let addr = leader.listen_addr().expect("tcp leader must bind").to_string();
    let fleet = spawn_fleet(&cfg, &addr);
    let summary = leader.run().unwrap();
    let params = leader.global_params().to_vec();
    leader.shutdown();
    join_fleet(fleet);
    TwinRun { summary, params }
}

#[test]
fn loopback_tcp_run_is_bit_for_bit_the_in_process_run() {
    // the transport tier's headline pin: the same config, seed, and
    // fault plan (live disconnect AND uplink-delay injection) over
    // loopback TCP must reproduce the in-process run bit for bit —
    // params, eval accs, every payload/envelope ledger. Only the
    // transport-plane tax may differ, and it must say what happened:
    // channels are free, sockets are not.
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(3, 5);
    cfg.comm = CommMode::Pruned;
    cfg.max_chain = 3; // comebacks ride chained deltas through the ring
    cfg.faults = Some("disconnect=0.3,delay=0.4,seed=7".parse().unwrap());
    let inproc = harness::run(&rt, &m, cfg.clone()).unwrap();
    let tcp = run_tcp(&rt, &m, cfg);
    // injection must actually have fired, or the test proves little: a
    // disconnected worker sits its round out and resyncs on comeback
    let dropped: usize = inproc.summary.rounds.iter().map(|r| r.dropped.len()).sum();
    assert!(dropped > 0, "disconnect injection produced no dropouts");
    assert_twin_parity("loopback tcp vs in-process", &inproc, &tcp, Parity::full());
    for (a, b) in inproc.summary.rounds.iter().zip(&tcp.summary.rounds) {
        assert_eq!(a.transport_bytes, 0, "round {}: channels pay no plane tax", a.round);
        assert!(
            b.transport_bytes > 0,
            "round {}: TCP framing/handshake/heartbeats went unledgered",
            b.round
        );
    }
}

#[test]
fn loopback_tcp_quantized_run_is_bit_for_bit_the_in_process_run() {
    // wire v2 crossed with the socket transport: the sealed frame is the
    // unit the transport carries, so quantized records and merged chain
    // resyncs (max_chain 3 + disconnect churn makes k ≥ 2 comebacks ride
    // the UPDATE_CHAIN_MERGED record over the wire) must decode to the
    // in-process run bit for bit — params, eval accs, every ledger
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg(3, 5);
    cfg.comm = CommMode::Pruned;
    cfg.wire_quant = WireQuant::Q8;
    cfg.max_chain = 3;
    cfg.faults = Some("disconnect=0.3,delay=0.4,seed=7".parse().unwrap());
    let inproc = harness::run(&rt, &m, cfg.clone()).unwrap();
    let tcp = run_tcp(&rt, &m, cfg);
    let dropped: usize = inproc.summary.rounds.iter().map(|r| r.dropped.len()).sum();
    assert!(dropped > 0, "disconnect injection produced no dropouts");
    assert_twin_parity("loopback tcp vs in-process (q8)", &inproc, &tcp, Parity::full());
}

#[test]
fn tcp_kill_and_resume_reproduces_the_uninterrupted_run() {
    // durability crossed with the wire: kill a loopback-TCP coordinator
    // after round 1, resume it on a fresh port with a fresh client
    // fleet (workers restore their replicas from the run store's
    // snapshots over the wire), and the stitched run must match the
    // *in-process uninterrupted* oracle bit for bit
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let dir = std::env::temp_dir().join(format!("effgrad_tcp_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut base = small_cfg(3, 4);
    base.comm = CommMode::Pruned;

    let x = harness::run(&rt, &m, base.clone()).unwrap();

    let mut killed = base.clone();
    killed.run_store = Some(dir.to_string_lossy().into_owned());
    killed.faults = Some(FaultPlan {
        kill_round: Some(1),
        ..FaultPlan::default()
    });
    let y1 = run_tcp(&rt, &m, killed);
    assert_eq!(y1.summary.rounds.len(), 2, "the kill must halt the run after round 1");

    // the resumed leader's restore blocks until every worker has acked
    // its snapshot, so the fleet must be dialing BEFORE Leader::new —
    // reserve a port, start the clients, let their seeded reconnect
    // backoff ride out the window where nothing is listening yet
    let addr = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
    };
    let mut resumed = base;
    resumed.listen = Some(addr.clone());
    resumed.run_store = Some(dir.to_string_lossy().into_owned());
    resumed.resume = true;
    let fleet = spawn_fleet(&resumed, &addr);
    let mut leader = Leader::new(&rt, &m, resumed).unwrap();
    let summary = leader.run().unwrap();
    let params = leader.global_params().to_vec();
    leader.shutdown();
    for h in fleet {
        h.join().unwrap().unwrap();
    }
    let y2 = TwinRun { summary, params };
    assert_eq!(y2.summary.rounds.len(), 2, "the resume must run exactly rounds 2 and 3");
    assert_eq!(y2.summary.rounds[0].round, 2);

    assert_eq!(x.params, y2.params, "tcp resume forked the trajectory");
    assert_round_parity(
        "tcp kill/resume vs in-process uninterrupted",
        &x.summary.rounds,
        y1.summary.rounds.iter().chain(&y2.summary.rounds),
        Parity::full(),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn preset_stop_flag_halts_gracefully_and_preserves_resumability() {
    // the signal path's pin: the round-boundary stop flag turns a run
    // into a no-op *between* persisted rounds — never mid-fold — so a
    // signalled-and-restarted run is bit-for-bit the uninterrupted one.
    // The flag is a leaked test-local AtomicBool (never the process-wide
    // signal flag, which would poison every other test's leader).
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let dir = std::env::temp_dir().join(format!("effgrad_stop_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut base = small_cfg(3, 4);
    base.comm = CommMode::Pruned;

    let x = harness::run(&rt, &m, base.clone()).unwrap();

    // rounds 0-1 complete and persist, then the injected kill halts
    let mut killed = base.clone();
    killed.run_store = Some(dir.to_string_lossy().into_owned());
    killed.faults = Some(FaultPlan {
        kill_round: Some(1),
        ..FaultPlan::default()
    });
    let y1 = harness::run(&rt, &m, killed).unwrap();

    // an operator signal lands before the restarted run's first round:
    // the leader restores, runs zero rounds, returns Ok (not an error),
    // and leaves the store exactly as it found it
    let mut resumed = base;
    resumed.run_store = Some(dir.to_string_lossy().into_owned());
    resumed.resume = true;
    let mut leader = Leader::new(&rt, &m, resumed.clone()).unwrap();
    let stopped: &'static AtomicBool = Box::leak(Box::new(AtomicBool::new(true)));
    leader.set_stop_flag(stopped);
    let sum = leader.run().unwrap();
    leader.shutdown();
    assert_eq!(sum.rounds.len(), 0, "a pre-set stop flag must halt before any round");

    // ...and the next restart picks up rounds 2-3 exactly
    let y2 = harness::run(&rt, &m, resumed).unwrap();
    assert_eq!(y2.summary.rounds.len(), 2);
    assert_eq!(x.params, y2.params, "the signalled stop forked the trajectory");
    assert_round_parity(
        "stop/restart/resume",
        &x.summary.rounds,
        y1.summary.rounds.iter().chain(&y2.summary.rounds),
        Parity::full(),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sampled_kill_and_resume_reproduces_the_cohort_sequence() {
    // the sample stream's durability pin: the run store persists the
    // cohort RNG state alongside the fault streams, so a kill after
    // round 1 and a resume must redraw rounds 2–3's cohorts exactly —
    // if resume re-derived the stream from the seed, the stitched run's
    // cohorts (and everything downstream) would fork here
    let Some(m) = manifest() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let dir =
        std::env::temp_dir().join(format!("effgrad_fed_sampled_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut base = small_cfg(3, 4);
    base.comm = CommMode::Pruned;
    base.sample_m = 2;

    let x = harness::run(&rt, &m, base.clone()).unwrap();
    let mut killed = base.clone();
    killed.run_store = Some(dir.to_string_lossy().into_owned());
    killed.faults = Some(FaultPlan {
        kill_round: Some(1),
        ..FaultPlan::default()
    });
    let y1 = harness::run(&rt, &m, killed).unwrap();
    assert_eq!(y1.summary.rounds.len(), 2, "the kill must halt the run after round 1");
    let mut resumed = base;
    resumed.run_store = Some(dir.to_string_lossy().into_owned());
    resumed.resume = true;
    let y2 = harness::run(&rt, &m, resumed).unwrap();
    assert_eq!(y2.summary.rounds.len(), 2);

    assert_eq!(x.params, y2.params, "sampled resume forked the trajectory");
    for r in x.summary.rounds.iter() {
        assert_eq!(r.cohort.len(), 2, "round {}: cohort size", r.round);
    }
    assert_round_parity(
        "sampled kill/resume",
        &x.summary.rounds,
        y1.summary.rounds.iter().chain(&y2.summary.rounds),
        Parity::full(),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
