//! Cross-module integration: accelerator simulator driven by *measured*
//! sparsity from a live training run, plus figure-generation smoke tests.

use efficientgrad::accel::config::{efficientgrad as eg_cfg, eyeriss_v2_bp};
use efficientgrad::accel::report::compare;
use efficientgrad::accel::workload::{resnet18_cifar, Workload};
use efficientgrad::data::batcher::Batcher;
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{Runtime, TrainState};
use efficientgrad::sparsity;

#[test]
fn simulator_with_measured_sparsity_matches_analytic_band() {
    let Some(m) = Manifest::load(&efficientgrad::artifacts_dir()).ok() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let state = TrainState::new(
        rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap(),
        model,
    )
    .unwrap();
    let mut store = ParamStore::init(model, 1);
    let ds = generate(&SynthConfig {
        n: 64,
        seed: 2,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&ds, model.batch, 3);
    let mut sparsities = Vec::new();
    for _ in 0..6 {
        let out = state.step(&mut store, &batcher.next_batch(), 0.05, 0.9).unwrap();
        sparsities.push(efficientgrad::util::stats::mean(&out.sparsity));
    }
    let measured_zero = sparsities.iter().sum::<f64>() / sparsities.len() as f64;
    let analytic_zero = sparsity::expected_zero_fraction(m.prune_rate);
    // live gradients are not exactly gaussian, but the realized sparsity
    // should sit within +-0.15 of the gaussian-model expectation (Fig 3a)
    assert!(
        (measured_zero - analytic_zero).abs() < 0.15,
        "measured {measured_zero} vs analytic {analytic_zero}"
    );

    // feed the measured survivor fraction into the Fig. 5b comparison
    let wl = resnet18_cifar(16);
    let rows = compare(&[&eyeriss_v2_bp(), &eg_cfg()], &wl, 1.0 - measured_zero);
    assert!(rows[1].norm_throughput > 1.5);
    assert!(rows[1].norm_power < 0.8);
}

#[test]
fn fig5b_stable_across_batch_sizes() {
    for batch in [1, 4, 16, 64] {
        let wl = resnet18_cifar(batch);
        let rows = compare(
            &[&eyeriss_v2_bp(), &eg_cfg()],
            &wl,
            sparsity::expected_survivor_fraction(0.9),
        );
        assert!(
            rows[1].norm_throughput > 1.4,
            "batch {batch}: {}",
            rows[1].norm_throughput
        );
        assert!(
            rows[1].norm_efficiency > 2.0,
            "batch {batch}: {}",
            rows[1].norm_efficiency
        );
    }
}

#[test]
fn prune_rate_sweep_monotone_speedup() {
    // ablation: higher pruning rate -> no slower on EfficientGrad
    let wl: Workload = resnet18_cifar(16);
    let mut prev = f64::MAX;
    for p in [0.0, 0.5, 0.8, 0.9, 0.95] {
        let surv = sparsity::expected_survivor_fraction(p);
        let r = efficientgrad::accel::simulate_training(&eg_cfg(), &wl, surv);
        let t = r.step_seconds();
        assert!(t <= prev + 1e-12, "P={p}: {t} > {prev}");
        prev = t;
    }
}

#[test]
fn figures_fig1_and_fig5b_generate() {
    let rep = efficientgrad::figures::fig1::generate(0.9);
    let dir = std::env::temp_dir();
    rep.save_csv(&dir.join("fig1_it.csv")).unwrap();
    let out = efficientgrad::figures::fig5b::generate(&resnet18_cifar(16), 0.9, None);
    out.report.save_csv(&dir.join("fig5b_it.csv")).unwrap();
    let text = std::fs::read_to_string(dir.join("fig5b_it.csv")).unwrap();
    assert!(text.contains("EfficientGrad"));
    std::fs::remove_file(dir.join("fig1_it.csv")).ok();
    std::fs::remove_file(dir.join("fig5b_it.csv")).ok();
}
