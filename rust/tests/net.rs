//! Transport-tier integration tests: artifact-free, loopback TCP vs the
//! in-process transport, driven by [`LiteWorker`] fleets (no PJRT, no
//! exported HLO — these run everywhere, unlike tests/federated.rs).
//!
//! What is pinned here: admission control (schema version, config hash,
//! half-open peers), reconnect-and-resume after a severed link,
//! graceful goodbye, and the core parity claim — the report frames a
//! TCP round produces are byte-for-byte the frames the in-process
//! transport produces from the same seed.

use std::net::TcpStream;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use efficientgrad::comm::envelope::{encode_update, SCHEMA_VERSION};
use efficientgrad::comm::{Frame, FrameKind, ModelUpdate};
use efficientgrad::config::{CommMode, CommPruner};
use efficientgrad::coordinator::{CommSetup, LiteWorker, WorkerTask};
use efficientgrad::net::client::{self, ClientConfig};
use efficientgrad::net::proto::{self, MsgReader};
use efficientgrad::net::tcp::TcpTransport;
use efficientgrad::net::Transport;
use efficientgrad::tensor::Tensor;

const SEED: u64 = 7;
const HASH: u64 = 0xC0FFEE;
const HEARTBEAT_MS: u64 = 20;
const DEADLINE_MS: u64 = 5_000;

fn setup() -> CommSetup {
    CommSetup {
        mode: CommMode::Pruned,
        rate: 0.3,
        pruner: CommPruner::Stochastic,
    }
}

fn client_cfg(worker_id: usize) -> ClientConfig {
    ClientConfig {
        worker_id,
        config_hash: HASH,
        heartbeat_ms: HEARTBEAT_MS,
        round_deadline_ms: DEADLINE_MS,
        seed: SEED,
        max_connect_attempts: 32,
    }
}

/// Spawn a lite worker serving the coordinator at `addr`.
fn spawn_client(addr: String, worker_id: usize) -> thread::JoinHandle<anyhow::Result<()>> {
    thread::spawn(move || {
        client::serve(&addr, &client_cfg(worker_id), LiteWorker::new(worker_id, SEED, setup()))
    })
}

fn model_params() -> Vec<Tensor> {
    vec![
        Tensor::new(vec![4], vec![0.5, -1.0, 2.0, 0.25]),
        Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, -1.0, -2.0, -3.0]),
    ]
}

/// One dense-downlink round over any transport: dispatch to every
/// worker, gather the reply frames, return them in worker-id order.
fn dense_round(t: &mut dyn Transport, round: usize) -> Vec<(usize, Frame)> {
    let update = ModelUpdate::Dense(model_params());
    let (tx, rx) = mpsc::channel();
    for wid in 0..t.workers() {
        t.submit(
            wid,
            WorkerTask {
                round,
                version: round as u64 + 1,
                frame: Frame::seal(FrameKind::Update, &encode_update(&update)),
                local_steps: 2,
                slowdown: 1.0,
                sleep: false,
                reply: tx.clone(),
            },
        )
        .unwrap();
    }
    drop(tx);
    let mut got: Vec<(usize, Frame)> = rx.iter().collect();
    got.sort_by_key(|&(wid, _)| wid);
    got
}

/// Read one length-prefixed frame off a raw socket, or `None` if the
/// peer closes / `within` elapses first.
fn await_frame(stream: &mut TcpStream, within: Duration) -> Option<Frame> {
    stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .unwrap();
    let mut rd = MsgReader::new();
    let deadline = Instant::now() + within;
    while Instant::now() < deadline {
        match rd.poll(stream) {
            Ok(Some(f)) => return Some(f),
            Ok(None) => {}
            Err(_) => return None,
        }
    }
    None
}

#[test]
fn tcp_handshake_rejects_a_wrong_schema_version() {
    let t = TcpTransport::bind("127.0.0.1:0", 1, HASH, HEARTBEAT_MS, DEADLINE_MS).unwrap();
    let addr = t.local_addr().unwrap();
    let mut stream = TcpStream::connect(addr).unwrap();
    // a well-formed hello from a build speaking the NEXT schema: the
    // version field is checked before the checksum, so this exercises
    // the version refusal specifically
    let mut hello = Frame::seal(FrameKind::Hello, &proto::encode_hello(0, HASH));
    let v = (SCHEMA_VERSION + 1).to_le_bytes();
    hello.bytes_mut()[4] = v[0];
    hello.bytes_mut()[5] = v[1];
    proto::send_msg(&mut stream, &hello).unwrap();
    let reply = await_frame(&mut stream, Duration::from_secs(10))
        .expect("coordinator must answer, not hang");
    assert_eq!(
        proto::peek_kind(&reply),
        Some(FrameKind::Goodbye),
        "a schema mismatch is refused with a goodbye, never admitted"
    );
}

#[test]
fn tcp_handshake_rejects_a_wrong_config_hash() {
    let t = TcpTransport::bind("127.0.0.1:0", 1, HASH, HEARTBEAT_MS, DEADLINE_MS).unwrap();
    let addr = t.local_addr().unwrap().to_string();
    let h = thread::spawn(move || {
        let mut cfg = client_cfg(0);
        cfg.config_hash = HASH ^ 1; // trained under different hyperparameters
        client::serve(&addr, &cfg, LiteWorker::new(0, SEED, setup()))
    });
    let err = h.join().unwrap().expect_err("mismatched config must be refused");
    assert!(
        err.to_string().contains("refused"),
        "refusal should be terminal, not a reconnect loop: {err:#}"
    );
    drop(t);
}

#[test]
fn tcp_half_open_connection_is_refused_and_rounds_proceed() {
    // a short deadline so the mute peer's refusal lands quickly
    let mut t = TcpTransport::bind("127.0.0.1:0", 1, HASH, HEARTBEAT_MS, 2_000).unwrap();
    let addr = t.local_addr().unwrap();
    // a peer that connects and never says hello
    let mut half_open = TcpStream::connect(addr).unwrap();
    // ...while a real worker joins and a full round completes: the
    // half-open socket stalls only its own transient handshake thread
    let worker = spawn_client(addr.to_string(), 0);
    let reports = dense_round(&mut t, 0);
    assert_eq!(reports.len(), 1, "the admitted worker's round must complete");
    assert_eq!(reports[0].1.open().unwrap().0, FrameKind::Report);
    // the mute peer is cut off with a goodbye once the deadline passes
    let reply = await_frame(&mut half_open, Duration::from_secs(10))
        .expect("half-open connections are refused, not leaked");
    assert_eq!(proto::peek_kind(&reply), Some(FrameKind::Goodbye));
    t.shutdown();
    worker.join().unwrap().unwrap();
}

#[test]
fn tcp_severed_worker_reconnects_and_resumes_the_round_loop() {
    let mut t = TcpTransport::bind("127.0.0.1:0", 1, HASH, HEARTBEAT_MS, DEADLINE_MS).unwrap();
    let addr = t.local_addr().unwrap();
    let worker = spawn_client(addr.to_string(), 0);
    let first = dense_round(&mut t, 0);
    assert_eq!(first.len(), 1);
    // the fault site: hard-kill the link between rounds
    t.sever(0);
    // the next submit blocks until the worker's seeded backoff brings
    // it back through a fresh handshake — the round then completes as
    // if nothing happened (its replica is re-synced by the dense frame)
    let second = dense_round(&mut t, 1);
    assert_eq!(second.len(), 1, "a reconnected worker must resume serving rounds");
    assert_eq!(second[0].1.open().unwrap().0, FrameKind::Report);
    t.shutdown();
    worker.join().unwrap().unwrap();
}

#[test]
fn tcp_round_reports_match_the_in_process_transport_bit_for_bit() {
    // twin fleets from the same seed: LiteWorker's round is a pure
    // function of (seed, id, round), so any transport-induced change
    // to what workers receive or send shows up as a byte diff here
    let mut inproc = efficientgrad::net::InProcess::new(
        (0..3).map(|i| LiteWorker::new(i, SEED, setup())).collect::<Vec<_>>(),
    );
    let mut tcp = TcpTransport::bind("127.0.0.1:0", 3, HASH, HEARTBEAT_MS, DEADLINE_MS).unwrap();
    let addr = tcp.local_addr().unwrap();
    let fleet: Vec<_> = (0..3).map(|i| spawn_client(addr.to_string(), i)).collect();
    for round in 0..2 {
        let a = dense_round(&mut inproc, round);
        let b = dense_round(&mut tcp, round);
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 3);
        for ((wa, fa), (wb, fb)) in a.iter().zip(&b) {
            assert_eq!(wa, wb, "round {round}: reply order by worker id");
            assert_eq!(
                fa.as_bytes(),
                fb.as_bytes(),
                "round {round} worker {wa}: report frames must be byte-identical"
            );
        }
    }
    // the transports differ only in the separately-ledgered plane tax
    assert_eq!(inproc.plane_bytes(), 0);
    assert!(
        tcp.plane_bytes() > 0,
        "TCP pays a handshake/heartbeat/framing tax and must ledger it"
    );
    tcp.shutdown();
    for h in fleet {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn tcp_graceful_shutdown_says_goodbye_to_the_fleet() {
    let mut t = TcpTransport::bind("127.0.0.1:0", 2, HASH, HEARTBEAT_MS, DEADLINE_MS).unwrap();
    let addr = t.local_addr().unwrap();
    let fleet: Vec<_> = (0..2).map(|i| spawn_client(addr.to_string(), i)).collect();
    let reports = dense_round(&mut t, 0);
    assert_eq!(reports.len(), 2);
    // capture/restore round-trips work over the wire (run-store path)
    let snap = t.capture(0).unwrap();
    assert!(!snap.reference.is_empty(), "the dense round synced a replica");
    t.restore(0, snap).unwrap();
    // shutdown sends goodbyes: every client returns Ok, not a
    // reconnect-exhaustion error
    t.shutdown();
    for h in fleet {
        h.join().unwrap().unwrap();
    }
}
