//! Resident-path parity tests: the device-resident step backend must be
//! bit-for-bit identical to the literal path it replaces — same
//! executable, same seeds, same batches, so the only difference is where
//! the state lives between steps.
//!
//! Like the other artifact-backed suites, these skip (not fail) when
//! `make artifacts` has not run.

use efficientgrad::config::ResidencyMode;
use efficientgrad::data::batcher::Batcher;
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{DeviceState, Runtime, StepDriver, TrainState};

fn manifest() -> Option<Manifest> {
    Manifest::load(&efficientgrad::artifacts_dir()).ok()
}

macro_rules! require_artifacts {
    () => {
        match manifest() {
            Some(m) => m,
            None => {
                eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn resident_matches_literal_bit_for_bit_after_10_steps() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();

    let mut lit_store = ParamStore::init(model, 21);
    let mut res_store = lit_store.clone();
    let literal = TrainState::new(exe.clone(), model).unwrap();
    let mut resident = DeviceState::new(&rt, exe, model, &res_store).unwrap();

    let ds = generate(&SynthConfig {
        n: 64,
        seed: 13,
        ..Default::default()
    });
    // two independent batchers with one seed: identical batch sequences
    let mut ba = Batcher::new(&ds, model.batch, 99);
    let mut bb = Batcher::new(&ds, model.batch, 99);
    for step in 0..10 {
        let a = literal.step(&mut lit_store, &ba.next_batch(), 0.05, 0.9).unwrap();
        let b = resident.step(&bb.next_batch(), 0.05, 0.9).unwrap();
        // scalars must already agree every step (same artifact, same seed
        // input — the step counter — on both paths)
        assert_eq!(a.loss, b.loss, "loss diverged at step {step}");
        assert_eq!(a.acc, b.acc, "acc diverged at step {step}");
        assert_eq!(a.sparsity, b.sparsity, "sparsity diverged at step {step}");
    }

    assert!(resident.host_stale());
    resident.sync_to_host(&mut res_store).unwrap();
    assert!(!resident.host_stale());

    assert_eq!(res_store.step, lit_store.step);
    assert_eq!(res_store.params, lit_store.params, "params diverged");
    assert_eq!(res_store.momenta, lit_store.momenta, "momenta diverged");
    assert_eq!(res_store.feedback, lit_store.feedback); // never touched

    // per-step state traffic: scalars only (the whole point)
    let stats = resident.transfer_stats();
    // 10 steps downloaded scalar tails + one full sync at the end
    assert_eq!(
        stats.state_down,
        10 * resident.scalar_tail_bytes() + res_store.mutable_state_bytes()
    );
}

#[test]
fn device_state_checkpoint_roundtrip() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_bp").unwrap()).unwrap();

    let mut store = ParamStore::init(model, 31);
    let mut dev = DeviceState::new(&rt, exe.clone(), model, &store).unwrap();
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 2,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    for _ in 0..3 {
        dev.step(&batch, 0.05, 0.9).unwrap();
    }

    // sync -> checkpoint -> restore -> re-upload must resume identically
    dev.sync_to_host(&mut store).unwrap();
    assert_eq!(store.step, 3);
    let path = std::env::temp_dir().join("effgrad_residency.ckpt");
    store.save(&path).unwrap();
    let restored = ParamStore::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    restored.check_compatible(model).unwrap();

    let mut dev2 = DeviceState::new(&rt, exe, model, &restored).unwrap();
    let a = dev.step(&batch, 0.05, 0.9).unwrap();
    let b = dev2.step(&batch, 0.05, 0.9).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.acc, b.acc);

    let mut s1 = store.clone();
    let mut s2 = restored;
    dev.sync_to_host(&mut s1).unwrap();
    dev2.sync_to_host(&mut s2).unwrap();
    assert_eq!(s1.params, s2.params);
    assert_eq!(s1.momenta, s2.momenta);
    assert_eq!(s1.step, s2.step);
}

#[test]
fn step_driver_broadcast_parity_across_modes() {
    // one FedAvg-style round through StepDriver on both backends:
    // load_params -> k steps -> sync must agree bit-for-bit
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();

    let broadcast = ParamStore::init(model, 77).params;
    let ds = generate(&SynthConfig {
        n: 64,
        seed: 5,
        ..Default::default()
    });

    let mut results = Vec::new();
    for mode in [ResidencyMode::Literal, ResidencyMode::Resident] {
        let mut store = ParamStore::init(model, 41);
        let mut driver = StepDriver::new(mode, &rt, exe.clone(), model, &store).unwrap();
        assert_eq!(driver.mode(), mode);
        driver.load_params(&mut store, broadcast.clone()).unwrap();
        let mut batcher = Batcher::new(&ds, model.batch, 7);
        for _ in 0..4 {
            driver.step(&mut store, &batcher.next_batch(), 0.05, 0.9).unwrap();
        }
        assert_eq!(driver.steps_done(&store), 4);
        driver.sync_to_host(&mut store).unwrap();
        results.push(store);
    }
    assert_eq!(results[0].params, results[1].params);
    assert_eq!(results[0].momenta, results[1].momenta);
}
