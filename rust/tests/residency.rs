//! Resident-path parity tests: the device-resident step backend must be
//! bit-for-bit identical to the literal path it replaces — same
//! executable, same seeds, same batches, so the only difference is where
//! the state lives between steps.
//!
//! Like the other artifact-backed suites, these skip (not fail) when
//! `make artifacts` has not run.

use efficientgrad::config::ResidencyMode;
use efficientgrad::data::batcher::Batcher;
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::exec::EvalState;
use efficientgrad::runtime::{
    literal_step_state_bytes, DeviceState, Runtime, StepDriver, TrainState,
};

fn manifest() -> Option<Manifest> {
    Manifest::load(&efficientgrad::artifacts_dir()).ok()
}

macro_rules! require_artifacts {
    () => {
        match manifest() {
            Some(m) => m,
            None => {
                eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn resident_matches_literal_bit_for_bit_after_10_steps() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();

    let mut lit_store = ParamStore::init(model, 21);
    let mut res_store = lit_store.clone();
    let literal = TrainState::new(exe.clone(), model).unwrap();
    let mut resident = DeviceState::new(&rt, exe, model, &res_store).unwrap();

    let ds = generate(&SynthConfig {
        n: 64,
        seed: 13,
        ..Default::default()
    });
    // two independent batchers with one seed: identical batch sequences
    let mut ba = Batcher::new(&ds, model.batch, 99);
    let mut bb = Batcher::new(&ds, model.batch, 99);
    for step in 0..10 {
        let a = literal.step(&mut lit_store, &ba.next_batch(), 0.05, 0.9).unwrap();
        let b = resident.step(&bb.next_batch(), 0.05, 0.9).unwrap();
        // scalars must already agree every step (same artifact, same seed
        // input — the step counter — on both paths)
        assert_eq!(a.loss, b.loss, "loss diverged at step {step}");
        assert_eq!(a.acc, b.acc, "acc diverged at step {step}");
        assert_eq!(a.sparsity, b.sparsity, "sparsity diverged at step {step}");
    }

    assert!(resident.host_stale());
    resident.sync_to_host(&mut res_store).unwrap();
    assert!(!resident.host_stale());

    assert_eq!(res_store.step, lit_store.step);
    assert_eq!(res_store.params, lit_store.params, "params diverged");
    assert_eq!(res_store.momenta, lit_store.momenta, "momenta diverged");
    assert_eq!(res_store.feedback, lit_store.feedback); // never touched

    // per-step state traffic: scalars only (the whole point)
    let stats = resident.transfer_stats();
    // 10 steps downloaded scalar tails + one full sync at the end
    assert_eq!(
        stats.state_down,
        10 * resident.scalar_tail_bytes() + res_store.mutable_state_bytes()
    );

    // the literal oracle's ledger must realize the documented formula
    // (docs/TRANSFER_MODEL.md): 10 x [4(2P+F) up + 4·2P + tail down]
    let lit_stats = literal.transfer_stats();
    assert_eq!(
        lit_stats.state_up + lit_stats.state_down,
        10 * literal_step_state_bytes(
            lit_store.param_elements(),
            lit_store.feedback.iter().map(|t| t.len()).sum(),
            lit_store.feedback.len(),
        )
    );
}

#[test]
fn resident_and_donation_settings_agree_bit_for_bit() {
    // input-buffer donation only changes buffer lifetime, never numerics
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();

    let store = ParamStore::init(model, 61);
    let mut donating = DeviceState::new(&rt, exe.clone(), model, &store).unwrap();
    let mut holding = DeviceState::new(&rt, exe, model, &store).unwrap();
    assert!(donating.donate_inputs()); // donation is the default
    holding.set_donate_inputs(false);

    let ds = generate(&SynthConfig {
        n: 64,
        seed: 17,
        ..Default::default()
    });
    let mut ba = Batcher::new(&ds, model.batch, 3);
    let mut bb = Batcher::new(&ds, model.batch, 3);
    for step in 0..5 {
        let a = donating.step(&ba.next_batch(), 0.05, 0.9).unwrap();
        let b = holding.step(&bb.next_batch(), 0.05, 0.9).unwrap();
        assert_eq!(a.loss, b.loss, "loss diverged at step {step}");
        assert_eq!(a.sparsity, b.sparsity);
    }
    let mut sa = store.clone();
    let mut sb = store;
    donating.sync_to_host(&mut sa).unwrap();
    holding.sync_to_host(&mut sb).unwrap();
    assert_eq!(sa.params, sb.params);
    assert_eq!(sa.momenta, sb.momenta);
    // and both ledgers count the identical transfers
    assert_eq!(donating.transfer_stats(), holding.transfer_stats());
}

#[test]
fn resident_eval_matches_literal_eval_bit_for_bit() {
    // the three eval paths (literal re-upload, cached param buffers,
    // device-resident off the training buffers) must produce identical
    // logits — residency only moves bytes, never values
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let train_exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();
    let fwd_exe = rt.load(model.artifact("fwd").unwrap()).unwrap();

    let mut store = ParamStore::init(model, 23);
    let mut dev = DeviceState::new(&rt, train_exe, model, &store).unwrap();
    let eval_lit = EvalState::new(&rt, fwd_exe.clone(), model, ResidencyMode::Literal).unwrap();
    let eval_res = EvalState::new(&rt, fwd_exe.clone(), model, ResidencyMode::Resident).unwrap();

    let ds = generate(&SynthConfig {
        n: 64,
        seed: 29,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());

    // at init the host store and device buffers hold the same params
    let lit0 = eval_lit.logits(&store, &batch.images).unwrap();
    let res0 = eval_res.logits(&store, &batch.images).unwrap();
    let dev0 = dev.eval_logits(&fwd_exe, &batch.images).unwrap();
    assert_eq!(lit0, res0, "cached eval diverged from literal at init");
    assert_eq!(lit0, dev0, "device eval diverged from literal at init");

    // train a few steps on the device, then compare WITHOUT syncing for
    // the device path — that is the whole point — and against the
    // literal oracle on a synced copy
    let mut batcher = Batcher::new(&ds, model.batch, 11);
    for _ in 0..4 {
        dev.step(&batcher.next_batch(), 0.05, 0.9).unwrap();
    }
    let stats_before = dev.transfer_stats();
    let dev_logits = dev.eval_logits(&fwd_exe, &batch.images).unwrap();
    let stats_after = dev.transfer_stats();
    // device-resident eval moved zero state bytes and one logits tail
    assert_eq!(stats_after.state_up, stats_before.state_up);
    assert_eq!(stats_after.state_down, stats_before.state_down);
    assert_eq!(stats_after.evals, stats_before.evals + 1);
    assert_eq!(
        stats_after.metrics_down - stats_before.metrics_down,
        (model.batch * model.num_classes * 4) as u64
    );

    dev.sync_to_host(&mut store).unwrap();
    let lit_logits = eval_lit.logits(&store, &batch.images).unwrap();
    let res_logits = eval_res.logits(&store, &batch.images).unwrap();
    assert_eq!(lit_logits, dev_logits, "post-training device eval diverged");
    assert_eq!(lit_logits, res_logits, "post-training cached eval diverged");

    // accuracy helpers agree too
    let a = eval_lit.accuracy(&store, &batch).unwrap();
    let b = eval_res.accuracy(&store, &batch).unwrap();
    let c = dev.eval_accuracy(&fwd_exe, &batch).unwrap();
    assert_eq!(a, b);
    assert_eq!(a, c);

    // the cached path re-uploaded params exactly twice: init draw + the
    // post-sync params (one fingerprint change), despite 3 logits calls
    let res_stats = eval_res.transfer_stats();
    assert_eq!(
        res_stats.state_up,
        2 * (store.param_elements() * 4) as u64,
        "param-buffer cache re-uploaded more than once per param change"
    );
}

#[test]
fn sync_to_host_skips_download_when_clean() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_bp").unwrap()).unwrap();

    let mut store = ParamStore::init(model, 43);
    let mut dev = DeviceState::new(&rt, exe, model, &store).unwrap();

    // clean at construction: sync is a no-op, zero bytes downloaded
    let before = dev.transfer_stats();
    dev.sync_to_host(&mut store).unwrap();
    assert_eq!(dev.transfer_stats(), before, "clean sync downloaded bytes");

    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 2,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    dev.step(&batch, 0.05, 0.9).unwrap();
    assert!(dev.host_stale());

    // stale: this one pays the O(model) download…
    dev.sync_to_host(&mut store).unwrap();
    let after_real = dev.transfer_stats();
    assert_eq!(
        after_real.state_down - before.state_down,
        dev.scalar_tail_bytes() + store.mutable_state_bytes()
    );
    // …and an immediate second sync (eval-then-checkpoint boundary) is
    // free: the dirty flag short-circuits the download
    let synced = store.clone();
    dev.sync_to_host(&mut store).unwrap();
    assert_eq!(dev.transfer_stats(), after_real);
    assert_eq!(store.params, synced.params);
    assert!(!dev.host_stale());
}

#[test]
fn device_state_checkpoint_roundtrip() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_bp").unwrap()).unwrap();

    let mut store = ParamStore::init(model, 31);
    let mut dev = DeviceState::new(&rt, exe.clone(), model, &store).unwrap();
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 2,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    for _ in 0..3 {
        dev.step(&batch, 0.05, 0.9).unwrap();
    }

    // sync -> checkpoint -> restore -> re-upload must resume identically
    dev.sync_to_host(&mut store).unwrap();
    assert_eq!(store.step, 3);
    let path = std::env::temp_dir().join("effgrad_residency.ckpt");
    store.save(&path).unwrap();
    let restored = ParamStore::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    restored.check_compatible(model).unwrap();

    let mut dev2 = DeviceState::new(&rt, exe, model, &restored).unwrap();
    let a = dev.step(&batch, 0.05, 0.9).unwrap();
    let b = dev2.step(&batch, 0.05, 0.9).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.acc, b.acc);

    let mut s1 = store.clone();
    let mut s2 = restored;
    dev.sync_to_host(&mut s1).unwrap();
    dev2.sync_to_host(&mut s2).unwrap();
    assert_eq!(s1.params, s2.params);
    assert_eq!(s1.momenta, s2.momenta);
    assert_eq!(s1.step, s2.step);
}

#[test]
fn step_driver_broadcast_parity_across_modes() {
    // one FedAvg-style round through StepDriver on both backends:
    // load_params -> k steps -> sync must agree bit-for-bit
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();

    let broadcast = ParamStore::init(model, 77).params;
    let ds = generate(&SynthConfig {
        n: 64,
        seed: 5,
        ..Default::default()
    });

    let mut results = Vec::new();
    for mode in [ResidencyMode::Literal, ResidencyMode::Resident] {
        let mut store = ParamStore::init(model, 41);
        let mut driver = StepDriver::new(mode, &rt, exe.clone(), model, &store).unwrap();
        assert_eq!(driver.mode(), mode);
        driver.load_params(&mut store, broadcast.clone()).unwrap();
        let mut batcher = Batcher::new(&ds, model.batch, 7);
        for _ in 0..4 {
            driver.step(&mut store, &batcher.next_batch(), 0.05, 0.9).unwrap();
        }
        assert_eq!(driver.steps_done(&store), 4);
        driver.sync_to_host(&mut store).unwrap();
        results.push(store);
    }
    assert_eq!(results[0].params, results[1].params);
    assert_eq!(results[0].momenta, results[1].momenta);
}
