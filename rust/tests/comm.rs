//! Comm-subsystem invariants: wire-format round trips, byte-formula
//! pins, and the error-feedback contract that makes pruned federated
//! exchange track the dense exchange. Pure host math — runs everywhere,
//! no artifacts needed.

use efficientgrad::comm::envelope::{decode_update, encode_update};
use efficientgrad::comm::wire::{
    bitmap_rle_decode, bitmap_rle_encode, dense_tensor_bytes, presence_bitmap, quantized_tensor_bytes,
    rle_decode_indices, sign_tensor_bytes, sparse_tensor_bytes, support_bytes,
    SPARSE_TENSOR_HEADER_BYTES,
};
use efficientgrad::comm::{
    DeltaCodec, ModelUpdate, QuantBits, QuantTensor, SignTensor, SparseTensor, TensorUpdate,
};
use efficientgrad::config::CommMode;
use efficientgrad::tensor::Tensor;
use efficientgrad::testing::{for_all, for_all2, F64In, NormalVec, UsizeIn};
use efficientgrad::util::rng::Rng;

fn t(v: &[f32]) -> Tensor {
    Tensor::new(vec![v.len()], v.to_vec())
}

// ---------------------------------------------------------------------------
// wire format: round trips + byte formulas over arbitrary inputs
// ---------------------------------------------------------------------------

#[test]
fn prop_sparse_roundtrip_arbitrary_buffers() {
    for_all(
        101,
        &NormalVec {
            max_len: 700,
            sigma: 1.0,
        },
        64,
        |v| {
            // sparsify a copy at an arbitrary cutoff so nnz varies from
            // 0 (full sparsity) to len (no sparsity)
            let mut pruned = v.clone();
            let cut = pruned[0].abs();
            for x in pruned.iter_mut() {
                if x.abs() < cut {
                    *x = 0.0;
                }
            }
            let s = SparseTensor::encode(&pruned);
            if s.wire_bytes() != sparse_tensor_bytes(s.nnz()) {
                return Err("sparse wire bytes != formula".into());
            }
            let u = TensorUpdate::Sparse(s);
            if u.decode_dense() != pruned {
                return Err("sparse decode != encoded buffer".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sign_roundtrip_preserves_support_signs_and_bytes() {
    for_all(
        102,
        &NormalVec {
            max_len: 700,
            sigma: 2.0,
        },
        64,
        |v| {
            let mut pruned = v.clone();
            let cut = pruned[pruned.len() / 2].abs();
            for x in pruned.iter_mut() {
                if x.abs() < cut {
                    *x = 0.0;
                }
            }
            let g = SignTensor::encode(&pruned);
            let nnz = pruned.iter().filter(|&&x| x != 0.0).count();
            if g.nnz as usize != nnz {
                return Err(format!("nnz {} != {}", g.nnz, nnz));
            }
            if g.wire_bytes() != sign_tensor_bytes(pruned.len(), nnz) {
                return Err("sign wire bytes != formula".into());
            }
            let decoded = TensorUpdate::Sign(g).decode_dense();
            for (i, (&d, &p)) in decoded.iter().zip(&pruned).enumerate() {
                if (p == 0.0) != (d == 0.0) {
                    return Err(format!("support changed at {i}"));
                }
                if p != 0.0 && d.signum() != p.signum() {
                    return Err(format!("sign flipped at {i}"));
                }
            }
            Ok(())
        },
    );
}

/// Lengths that straddle the bit-plane codec's u32 word boundaries —
/// the exact shapes where a word-at-a-time (movemask-style) encoder can
/// get partial-word masking wrong.
const PLANE_BOUNDARY_LENS: [usize; 8] = [0, 1, 31, 32, 33, 63, 64, 65];

#[test]
fn prop_sign_planes_roundtrip_at_word_boundaries() {
    // random ± survivor patterns at every boundary length: the planes
    // must survive encode → decode → re-encode unchanged, and the wire
    // bytes must match the documented formula at every nnz
    for_all2(
        105,
        &UsizeIn(0, PLANE_BOUNDARY_LENS.len() - 1),
        &UsizeIn(0, 1 << 20),
        96,
        |&li, &seed| {
            let n = PLANE_BOUNDARY_LENS[li];
            let mut rng = Rng::new(seed as u64);
            let pruned: Vec<f32> = (0..n)
                .map(|_| match rng.below(4) {
                    0 | 1 => 0.0,
                    2 => 0.25,
                    _ => -0.25,
                })
                .collect();
            let g = SignTensor::encode(&pruned);
            let nnz = pruned.iter().filter(|&&x| x != 0.0).count();
            if g.nnz as usize != nnz {
                return Err(format!("n={n}: nnz {} != {nnz}", g.nnz));
            }
            if g.wire_bytes() != sign_tensor_bytes(n, nnz) {
                return Err(format!("n={n} nnz={nnz}: wire bytes != formula"));
            }
            // plane widths: ceil(n/32) presence words, ceil(nnz/32) sign
            // words — the partial-word tails the boundary lengths probe
            if g.presence.len() != n.div_ceil(32) || g.signs.len() != nnz.div_ceil(32) {
                return Err(format!(
                    "n={n} nnz={nnz}: plane widths {}/{}",
                    g.presence.len(),
                    g.signs.len()
                ));
            }
            let decoded = TensorUpdate::Sign(g.clone()).decode_dense();
            for (i, (&d, &p)) in decoded.iter().zip(&pruned).enumerate() {
                if (p == 0.0) != (d == 0.0) {
                    return Err(format!("n={n}: support changed at {i}"));
                }
                if p != 0.0 && d.signum() != p.signum() {
                    return Err(format!("n={n}: sign flipped at {i}"));
                }
            }
            // re-encoding the decode reproduces the planes bit for bit
            let g2 = SignTensor::encode(&decoded);
            if g2.presence != g.presence || g2.signs != g.signs || g2.nnz != g.nnz {
                return Err(format!("n={n}: planes not a fixed point of decode∘encode"));
            }
            Ok(())
        },
    );
}

#[test]
fn sign_planes_all_and_no_survivors_at_word_boundaries() {
    for n in PLANE_BOUNDARY_LENS {
        // no survivors: empty sign plane, zeroed presence, zero decode
        let g = SignTensor::encode(&vec![0.0f32; n]);
        assert_eq!(g.nnz, 0, "n={n}");
        assert_eq!(g.wire_bytes(), sign_tensor_bytes(n, 0), "n={n}");
        assert!(g.signs.is_empty(), "n={n}: sign words for zero survivors");
        assert!(g.presence.iter().all(|&w| w == 0), "n={n}");
        assert_eq!(TensorUpdate::Sign(g).decode_dense(), vec![0.0f32; n]);

        // all survivors, alternating sign: presence saturates every word
        // (partial last word masked, never overrun)
        let pruned: Vec<f32> =
            (0..n).map(|i| if i % 2 == 0 { 0.5 } else { -0.5 }).collect();
        let g = SignTensor::encode(&pruned);
        assert_eq!(g.nnz as usize, n, "n={n}");
        assert_eq!(g.wire_bytes(), sign_tensor_bytes(n, n), "n={n}");
        for (wi, &w) in g.presence.iter().enumerate() {
            let bits_here = (n - wi * 32).min(32);
            let want = if bits_here == 32 { u32::MAX } else { (1u32 << bits_here) - 1 };
            assert_eq!(w, want, "n={n}: presence word {wi}");
        }
        let decoded = TensorUpdate::Sign(g).decode_dense();
        for (i, (&d, &p)) in decoded.iter().zip(&pruned).enumerate() {
            assert_eq!(d.signum(), p.signum(), "n={n}: sign at {i}");
            assert_ne!(d, 0.0, "n={n}: survivor dropped at {i}");
        }
    }
}

#[test]
fn prop_sign_beats_sparse_beats_dense_at_high_sparsity() {
    // at ≤ ~46% survivors (eq. 3 at P=0.9) the byte ordering that
    // motivates the modes must hold for any tensor size
    for_all2(103, &UsizeIn(64, 4096), &F64In(0.05, 0.46), 48, |&n, &frac| {
        let nnz = ((n as f64) * frac) as usize;
        let dense = dense_tensor_bytes(n);
        let sparse = sparse_tensor_bytes(nnz);
        let sign = sign_tensor_bytes(n, nnz);
        if sign >= sparse && nnz > 8 {
            return Err(format!("sign {sign} >= sparse {sparse} at n={n} nnz={nnz}"));
        }
        if sparse >= dense && frac < 0.4 {
            return Err(format!("sparse {sparse} >= dense {dense} at n={n} nnz={nnz}"));
        }
        Ok(())
    });
}

#[test]
fn sign_mode_hits_the_ten_x_wire_cut_at_paper_p() {
    // the headline: at the paper's P=0.9 eq. 3 leaves ~46% survivors,
    // and the sign format's ~1.25 bits/survivor (+bitmap) still cuts
    // ≥10× vs dense f32 — the formula-level version of the bench assert
    let n = 42_000; // convnet_s-scale tensor
    let nnz = (n as f64 * 0.46) as usize;
    assert!(dense_tensor_bytes(n) / sign_tensor_bytes(n, nnz) >= 10);
    // the index+value format is bounded by its 8-byte survivors instead
    assert!(sparse_tensor_bytes(nnz) < dense_tensor_bytes(n));
}

// ---------------------------------------------------------------------------
// wire v2: quantized survivors, RLE supports, merged chains
// ---------------------------------------------------------------------------

#[test]
fn prop_quantize_dequantize_error_within_half_scale() {
    // the v2 quantizer's accuracy contract: every survivor dequantizes
    // to within scale/2 of its exact f32 value (the bound the codec's
    // error-feedback residual then absorbs), the support is preserved
    // exactly, and the wire bytes match the documented formula
    for_all(
        106,
        &NormalVec {
            max_len: 700,
            sigma: 1.5,
        },
        64,
        |v| {
            let mut pruned = v.clone();
            let cut = pruned[0].abs();
            for x in pruned.iter_mut() {
                if x.abs() < cut {
                    *x = 0.0;
                }
            }
            for bits in [QuantBits::Q8, QuantBits::Q4] {
                let q = QuantTensor::encode(&pruned, bits);
                let want =
                    quantized_tensor_bytes(support_bytes(pruned.len(), &q.indices), q.nnz(), bits);
                if q.wire_bytes() != want {
                    return Err(format!("{bits:?}: wire bytes != formula"));
                }
                let tol = (q.scale as f64) / 2.0 + 1e-6;
                let decoded = TensorUpdate::Quantized(q).decode_dense();
                for (i, (&d, &p)) in decoded.iter().zip(&pruned).enumerate() {
                    // a survivor may dequantize to exactly 0.0, but a
                    // pruned lane must stay 0
                    if p == 0.0 && d != 0.0 {
                        return Err(format!("{bits:?}: pruned lane {i} resurrected"));
                    }
                    if p != 0.0 && ((d - p) as f64).abs() > tol {
                        return Err(format!(
                            "{bits:?}: survivor {i} err {} > scale/2 {tol}",
                            (d - p).abs()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rle_and_raw_bitmaps_roundtrip_at_word_boundaries() {
    // random supports at every u32-word-boundary length: the RLE stream
    // must decode back to the exact bitmap AND to the exact index list —
    // the two readers the v2 decode paths use
    for_all2(
        107,
        &UsizeIn(0, PLANE_BOUNDARY_LENS.len() - 1),
        &UsizeIn(0, 1 << 20),
        96,
        |&li, &seed| {
            let n = PLANE_BOUNDARY_LENS[li];
            let mut rng = Rng::new(seed as u64);
            // densities from empty to full so runs of every shape occur
            let keep = rng.below(5);
            let indices: Vec<u32> =
                (0..n as u32).filter(|_| rng.below(4) <= keep).collect();
            let bitmap = presence_bitmap(n, &indices);
            let rle = bitmap_rle_encode(&bitmap, n);
            let back = bitmap_rle_decode(&rle, n).map_err(|e| e.to_string())?;
            if back != bitmap {
                return Err(format!("n={n}: RLE→bitmap roundtrip diverged"));
            }
            let idx_back =
                rle_decode_indices(&rle, n, indices.len()).map_err(|e| e.to_string())?;
            if idx_back != indices {
                return Err(format!("n={n}: RLE→indices roundtrip diverged"));
            }
            Ok(())
        },
    );
}

#[test]
fn merged_chain_decode_matches_sequential_apply_for_k_1_2_3() {
    // the merged-chain contract end to end: a k-link all-quantized chain
    // serialized through the envelope (merged v2 record for k ≥ 2, v1
    // for k = 1) must decode to the exact same links and, applied to a
    // stale replica, land bit-for-bit where applying the k links one at
    // a time would have
    let n = 400;
    let mut rng = Rng::new(61);
    let links: Vec<Vec<TensorUpdate>> = (0..3)
        .map(|_| {
            let mut dense = vec![0f32; n];
            rng.fill_normal(&mut dense, 0.5);
            for x in dense.iter_mut() {
                if rng.below(10) < 7 {
                    *x = 0.0;
                }
            }
            vec![TensorUpdate::Quantized(QuantTensor::encode(&dense, QuantBits::Q8))]
        })
        .collect();
    for k in 1..=3usize {
        let chain = ModelUpdate::Chain(links[3 - k..].to_vec());
        let decoded = decode_update(&encode_update(&chain)).unwrap();
        assert_eq!(decoded, chain, "k={k}: envelope roundtrip diverged");
        let mut via_chain = vec![Tensor::zeros(&[n])];
        decoded.apply(&mut via_chain).unwrap();
        let mut via_links = vec![Tensor::zeros(&[n])];
        for l in &links[3 - k..] {
            ModelUpdate::Delta(l.clone()).apply(&mut via_links).unwrap();
        }
        assert_eq!(
            via_chain, via_links,
            "k={k}: merged decode diverged from sequential per-link apply"
        );
    }
}

// ---------------------------------------------------------------------------
// codec: dense equivalence at rate 0, EF identity, residual boundedness
// ---------------------------------------------------------------------------

#[test]
fn prop_rate_zero_codec_is_dense_equivalent() {
    // τ = 0 ships every nonzero delta coordinate exactly: reference +
    // decode == local bit for bit, and the residual stays empty
    for_all(
        104,
        &NormalVec {
            max_len: 512,
            sigma: 0.5,
        },
        48,
        |delta| {
            // zero reference: delta == local exactly, so the round trip
            // must be bit-for-bit (a nonzero reference only adds float
            // rounding in `local - reference`, outside the codec's
            // contract)
            let reference = vec![Tensor::zeros(&[delta.len()])];
            let local = vec![t(delta)];
            let mut codec = DeltaCodec::new(CommMode::Pruned, 0.0);
            let u = codec
                .encode(&local, &reference, &mut Rng::new(7))
                .map_err(|e| e.to_string())?;
            let mut p = reference.clone();
            u.apply(&mut p).map_err(|e| e.to_string())?;
            if p != local {
                return Err("rate-0 codec not dense-equivalent".into());
            }
            if codec.residual_norm() != 0.0 {
                return Err(format!("rate-0 residual {}", codec.residual_norm()));
            }
            Ok(())
        },
    );
}

/// Drive `rounds` codec rounds over synthetic N(0, sigma) deltas and
/// return the residual norm after each round.
fn residual_trajectory(mode: CommMode, rate: f64, n: usize, rounds: usize) -> Vec<f64> {
    let mut codec = DeltaCodec::new(mode, rate);
    let mut data_rng = Rng::new(42);
    let mut prune_rng = Rng::new(43);
    let reference = vec![Tensor::zeros(&[n])];
    let mut norms = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let mut delta = vec![0f32; n];
        data_rng.fill_normal(&mut delta, 1.0);
        let local = vec![t(&delta)];
        codec.encode(&local, &reference, &mut prune_rng).unwrap();
        norms.push(codec.residual_norm());
    }
    norms
}

#[test]
fn residual_norm_stays_bounded_across_rounds() {
    // error feedback is stable iff the carried residual settles instead
    // of compounding: per-element residual magnitude is bounded by τ for
    // the sparse format, so the norm should plateau at O(σ·√n)
    let n = 4096;
    for mode in [CommMode::Pruned, CommMode::Sign] {
        let norms = residual_trajectory(mode, 0.9, n, 30);
        let bound = 6.0 * (n as f64).sqrt(); // σ = 1; steady state ≈ 1.5·√n
        for (round, &norm) in norms.iter().enumerate() {
            assert!(
                norm < bound,
                "{mode:?}: residual norm {norm} exceeded {bound} at round {round}"
            );
        }
        // no late-run growth: the last third is not meaningfully above
        // the middle third
        let mid: f64 = norms[10..20].iter().sum::<f64>() / 10.0;
        let late: f64 = norms[20..30].iter().sum::<f64>() / 10.0;
        assert!(
            late < mid * 1.5,
            "{mode:?}: residual growing: mid {mid} -> late {late}"
        );
    }
}

#[test]
fn ef_identity_decoded_plus_residual_equals_delta() {
    // the error-feedback identity: decode(update) + residual == delta +
    // previous residual, per element, every round, both modes
    for mode in [CommMode::Pruned, CommMode::Sign] {
        let mut codec = DeltaCodec::new(mode, 0.9);
        let mut data_rng = Rng::new(5);
        let mut prune_rng = Rng::new(6);
        let n = 512;
        let reference = vec![Tensor::zeros(&[n])];
        let mut carried = vec![0f64; n];
        for round in 0..5 {
            let mut delta = vec![0f32; n];
            data_rng.fill_normal(&mut delta, 1.0);
            let u = codec
                .encode(&[t(&delta)], &reference, &mut prune_rng)
                .unwrap();
            let decoded = match &u {
                ModelUpdate::Delta(us) => us[0].decode_dense(),
                _ => panic!("expected delta"),
            };
            // recompute the residual the codec must now hold
            for (c, (&d, &q)) in carried.iter_mut().zip(delta.iter().zip(&decoded)) {
                *c += d as f64 - q as f64;
            }
            let want: f64 = carried.iter().map(|c| c * c).sum::<f64>().sqrt();
            let got = codec.residual_norm();
            assert!(
                (want - got).abs() < 1e-3 * want.max(1.0),
                "{mode:?} round {round}: residual {got} != reconstructed {want}"
            );
        }
    }
}

#[test]
fn codec_encode_is_deterministic_in_the_rng() {
    let local = vec![t(&[0.3, -0.1, 0.8, 0.0, -2.0, 0.05])];
    let reference = vec![Tensor::zeros(&[6])];
    for mode in [CommMode::Pruned, CommMode::Sign] {
        let mut a = DeltaCodec::new(mode, 0.9);
        let mut b = DeltaCodec::new(mode, 0.9);
        let ua = a.encode(&local, &reference, &mut Rng::new(11)).unwrap();
        let ub = b.encode(&local, &reference, &mut Rng::new(11)).unwrap();
        assert_eq!(ua, ub);
    }
}

#[test]
fn leader_and_worker_replicas_stay_bit_identical() {
    // both endpoints apply the same decoded updates; after any number of
    // compressed downlinks their references must agree bit for bit —
    // this is the invariant that lets the leader skip dense resyncs for
    // in-sync workers
    let n = 256;
    let mut leader_ref = vec![Tensor::zeros(&[n])];
    let mut worker_ref = leader_ref.clone();
    let mut codec = DeltaCodec::new(CommMode::Sign, 0.9);
    let mut data_rng = Rng::new(21);
    let mut prune_rng = Rng::new(22);
    for _ in 0..8 {
        // the leader's "global" wanders off the reference each round
        let mut step = vec![0f32; n];
        data_rng.fill_normal(&mut step, 0.1);
        let global = vec![t(&leader_ref[0]
            .data()
            .iter()
            .zip(&step)
            .map(|(&a, &b)| a + b)
            .collect::<Vec<f32>>())];
        let u = codec.encode(&global, &leader_ref, &mut prune_rng).unwrap();
        u.apply(&mut leader_ref).unwrap();
        u.apply(&mut worker_ref).unwrap();
        assert_eq!(leader_ref, worker_ref);
    }
}

#[test]
fn chained_downlink_replays_missed_rounds_bit_for_bit() {
    // the chained-resync contract, driven through the real codec: a
    // worker that missed k ∈ {1, 2, 3} downlinks and applies the chain
    // of the retained per-round deltas must land on EXACTLY the replica
    // an always-on worker holds — and the chain's wire bytes follow the
    // documented `8 + Σ link` formula
    use efficientgrad::comm::wire::chained_model_bytes;
    let n = 300;
    let mut leader_ref = vec![Tensor::zeros(&[n])];
    let mut codec = DeltaCodec::new(CommMode::Sign, 0.9);
    let mut data_rng = Rng::new(51);
    let mut prune_rng = Rng::new(52);
    let mut links: Vec<Vec<TensorUpdate>> = Vec::new();
    let mut snapshots = vec![leader_ref.clone()]; // replica after 0, 1, 2, 3 rounds
    for _ in 0..3 {
        let mut step = vec![0f32; n];
        data_rng.fill_normal(&mut step, 0.1);
        let global = vec![t(&leader_ref[0]
            .data()
            .iter()
            .zip(&step)
            .map(|(&a, &b)| a + b)
            .collect::<Vec<f32>>())];
        let u = codec.encode(&global, &leader_ref, &mut prune_rng).unwrap();
        u.apply(&mut leader_ref).unwrap();
        snapshots.push(leader_ref.clone());
        match u {
            ModelUpdate::Delta(us) => links.push(us),
            _ => panic!("expected delta"),
        }
    }
    for k in 1..=3usize {
        // a worker stuck k rounds back applies the chain of the last k
        // per-round deltas
        let mut replica = snapshots[3 - k].clone();
        let chain = ModelUpdate::Chain(links[3 - k..].to_vec());
        assert_eq!(
            chain.wire_bytes(),
            chained_model_bytes(
                links[3 - k..]
                    .iter()
                    .map(|us| us.iter().map(|u| u.wire_bytes()).sum())
            ),
            "k={k}: chain bytes != documented formula"
        );
        chain.apply(&mut replica).unwrap();
        assert_eq!(
            replica, leader_ref,
            "k={k}: chained replay diverged from the always-on replica"
        );
    }
}

#[test]
fn model_update_wire_bytes_sum_over_tensors() {
    // multi-tensor updates sum the per-tensor formulas — what the
    // leader's per-round ledger relies on
    let a = [1.0f32, 0.0, -2.0];
    let b = [0.0f32; 70];
    let sparse = ModelUpdate::Delta(vec![
        TensorUpdate::Sparse(SparseTensor::encode(&a)),
        TensorUpdate::Sparse(SparseTensor::encode(&b)),
    ]);
    assert_eq!(
        sparse.wire_bytes(),
        sparse_tensor_bytes(2) + sparse_tensor_bytes(0)
    );
    assert_eq!(sparse.survivors(), 2);
    assert_eq!(
        ModelUpdate::Dense(vec![t(&a), t(&b)]).wire_bytes(),
        dense_tensor_bytes(3) + dense_tensor_bytes(70)
    );
    // header constant is part of the documented model
    assert_eq!(sparse_tensor_bytes(0), SPARSE_TENSOR_HEADER_BYTES);
}
