//! Integration tests over the real AOT artifacts (python -> HLO -> PJRT).
//!
//! These need `make artifacts` to have run; they skip (not fail) when the
//! manifest is missing so `cargo test` works in a fresh checkout, and the
//! Makefile's `test` target guarantees artifacts exist first.

use efficientgrad::config::TrainConfig;
use efficientgrad::data::batcher::Batcher;
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::exec::{EvalState, ProbeState};
use efficientgrad::runtime::{Runtime, TrainState};
use efficientgrad::training::Trainer;

fn manifest() -> Option<Manifest> {
    let dir = efficientgrad::artifacts_dir();
    let dir = if dir.is_relative() {
        // cargo test runs from the workspace root already
        dir
    } else {
        dir
    };
    Manifest::load(&dir).ok()
}

macro_rules! require_artifacts {
    () => {
        match manifest() {
            Some(m) => m,
            None => {
                eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn artifacts_validate_against_manifest() {
    let m = require_artifacts!();
    for model in m.models.values() {
        for art in model.artifacts.values() {
            efficientgrad::runtime::check_artifact(model, art)
                .unwrap_or_else(|e| panic!("{e:#}"));
        }
    }
}

#[test]
fn train_step_runs_and_loss_decreases() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let art = model.artifact("train_efficientgrad").unwrap();
    let state = TrainState::new(rt.load(art).unwrap(), model).unwrap();
    let mut store = ParamStore::init(model, 1);

    let ds = generate(&SynthConfig {
        n: 64,
        difficulty: 0.4,
        seed: 3,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&ds, model.batch, 5);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..12 {
        let batch = batcher.next_batch();
        let out = state.step(&mut store, &batch, 0.05, 0.9).unwrap();
        assert!(out.loss.is_finite());
        // efficientgrad must report live sparsity in a plausible band
        let sp = efficientgrad::util::stats::mean(&out.sparsity);
        assert!((0.1..0.97).contains(&sp), "sparsity {sp}");
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    assert!(
        last < first.unwrap(),
        "loss did not decrease: {} -> {last}",
        first.unwrap()
    );
    assert_eq!(store.step, 12);
}

#[test]
fn bp_and_efficientgrad_agree_at_step0_forward() {
    // same params, same batch: the *loss* (computed in the forward pass)
    // must agree across mode artifacts; only the updates differ.
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 11,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    let mut losses = Vec::new();
    for tag in ["train_bp", "train_efficientgrad"] {
        let state =
            TrainState::new(rt.load(model.artifact(tag).unwrap()).unwrap(), model).unwrap();
        let mut store = ParamStore::init(model, 7);
        let out = state.step(&mut store, &batch, 0.01, 0.9).unwrap();
        losses.push(out.loss);
    }
    assert!(
        (losses[0] - losses[1]).abs() < 1e-4,
        "step-0 losses diverge: {losses:?}"
    );
}

#[test]
fn eval_state_logits_shape_and_determinism() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let eval = EvalState::new(
        &rt,
        rt.load(model.artifact("fwd").unwrap()).unwrap(),
        model,
        efficientgrad::config::ResidencyMode::Literal,
    )
    .unwrap();
    let store = ParamStore::init(model, 2);
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 4,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    let l1 = eval.logits(&store, &batch.images).unwrap();
    let l2 = eval.logits(&store, &batch.images).unwrap();
    assert_eq!(l1.shape(), &[model.batch, model.num_classes]);
    assert_eq!(l1, l2);
}

#[test]
fn probe_reports_aligned_angles_after_training() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let train =
        TrainState::new(rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap(), model)
            .unwrap();
    let probe =
        ProbeState::new(rt.load(model.artifact("probe").unwrap()).unwrap(), model).unwrap();
    let mut store = ParamStore::init(model, 5);
    let ds = generate(&SynthConfig {
        n: 64,
        seed: 6,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&ds, model.batch, 8);
    for _ in 0..10 {
        let b = batcher.next_batch();
        train.step(&mut store, &b, 0.05, 0.9).unwrap();
    }
    let batch = batcher.next_batch();
    let out = probe.probe(&store, &batch, 42).unwrap();
    assert_eq!(out.cos_angles.len(), model.params.len());
    // Fig. 3b claim: angles under 90 deg for the conv / fc weights (the
    // tensors whose transport the feedback replaces). BN params see the
    // delta only through batch statistics and can be noisy this early.
    for (i, &c) in out.cos_angles.iter().enumerate() {
        let rank = model.params[i].shape.len();
        if rank >= 2 {
            assert!(
                c > 0.0,
                "param {i} ({}) angle >= 90deg (cos {c})",
                model.params[i].name
            );
        }
    }
    let mean_cos: f32 =
        out.cos_angles.iter().sum::<f32>() / out.cos_angles.len() as f32;
    assert!(mean_cos > 0.1, "mean alignment too weak: {mean_cos}");
    // Fig. 3a: histogram is a normalized, center-heavy distribution
    let sum: f32 = out.hist.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "hist sum {sum}");
    let center: f32 = out.hist[24..40].iter().sum();
    assert!(center > 0.5, "center mass {center}");
    assert!(out.sparsity > 0.2 && out.sparsity < 0.97);
}

#[test]
fn trainer_end_to_end_short_run_beats_chance() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let cfg = TrainConfig {
        model: "convnet_t".into(),
        mode: "efficientgrad".into(),
        steps: 60,
        train_examples: 512,
        test_examples: 128,
        difficulty: 0.4,
        eval_every: 0,
        log_every: 1000,
        ..Default::default()
    };
    let ds = generate(&SynthConfig {
        n: cfg.train_examples + cfg.test_examples,
        difficulty: cfg.difficulty as f32,
        seed: cfg.seed,
        ..Default::default()
    });
    let (train, test) = ds.split(cfg.train_examples);
    let mut trainer = Trainer::new(&rt, &m, cfg).unwrap();
    let acc = trainer.run(&train, &test).unwrap();
    assert!(acc > 0.2, "60-step accuracy {acc} not above chance (0.1)");
    assert!(trainer.log.records.len() == 60);
}

#[test]
fn periodic_checkpoint_persists_mid_run_state() {
    // train.checkpoint_every_steps: a killed run must find a checkpoint
    // at most N steps old. Drive manual steps (no run() completion —
    // that is the point: the final save never happens) and check the
    // cadence writes a loadable, step-stamped checkpoint whose params
    // match the synced device state.
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let path = std::env::temp_dir().join("effgrad_periodic.ckpt");
    std::fs::remove_file(&path).ok();
    let cfg = TrainConfig {
        model: "convnet_t".into(),
        mode: "efficientgrad".into(),
        steps: 10,
        train_examples: 128,
        test_examples: 64,
        difficulty: 0.4,
        log_every: 1000,
        checkpoint: Some(path.to_string_lossy().into_owned()),
        checkpoint_every_steps: 2,
        ..Default::default()
    };
    let ds = generate(&SynthConfig {
        n: cfg.train_examples,
        difficulty: cfg.difficulty as f32,
        seed: cfg.seed,
        ..Default::default()
    });
    let mut trainer = Trainer::new(&rt, &m, cfg).unwrap();
    let mut batcher = Batcher::new(&ds, m.model("convnet_t").unwrap().batch, 3);

    // off-cadence step: nothing written yet
    trainer.manual_step(&batcher.next_batch(), 0.05).unwrap();
    assert!(!trainer.periodic_checkpoint(0).unwrap());
    assert!(!path.exists(), "checkpoint written off-cadence");
    // second step lands on the cadence
    trainer.manual_step(&batcher.next_batch(), 0.05).unwrap();
    assert!(trainer.periodic_checkpoint(1).unwrap());
    let restored = ParamStore::load(&path).unwrap();
    assert_eq!(restored.step, 2, "checkpoint must carry the step count");
    // the checkpoint is the synced mid-run state, bit for bit
    trainer.sync_store().unwrap();
    assert_eq!(restored.params, trainer.store.params);
    assert_eq!(restored.momenta, trainer.store.momenta);
    std::fs::remove_file(&path).ok();
}

#[test]
fn checkpoint_roundtrip_through_runtime() {
    let m = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = m.model("convnet_t").unwrap();
    let state =
        TrainState::new(rt.load(model.artifact("train_bp").unwrap()).unwrap(), model).unwrap();
    let mut store = ParamStore::init(model, 9);
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 1,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    state.step(&mut store, &batch, 0.05, 0.9).unwrap();

    let path = std::env::temp_dir().join("effgrad_integration.ckpt");
    store.save(&path).unwrap();
    let restored = ParamStore::load(&path).unwrap();
    restored.check_compatible(model).unwrap();
    assert_eq!(restored.step, 1);

    // restored state must produce the identical next step
    let mut a = store.clone();
    let mut b = restored;
    let oa = state.step(&mut a, &batch, 0.05, 0.9).unwrap();
    let ob = state.step(&mut b, &batch, 0.05, 0.9).unwrap();
    assert_eq!(oa.loss, ob.loss);
    std::fs::remove_file(&path).ok();
}
