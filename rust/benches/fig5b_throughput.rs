//! Bench: regenerates **Fig. 5b** and the §5 headline table — normalized
//! throughput/power/energy-efficiency of EfficientGrad vs EyerissV2-BP on
//! ResNet-18 training — and times the simulator itself. Also sweeps batch
//! size and pruning rate (ablation of the paper's operating point).
//!
//!     cargo bench --bench fig5b_throughput

use efficientgrad::accel::config::{efficientgrad, efficientgrad_bp_ablation, eyeriss_v2_bp};
use efficientgrad::accel::report::compare;
use efficientgrad::accel::workload::resnet18_cifar;
use efficientgrad::benchlib::{bench_default, fmt_ns, Report};
use efficientgrad::figures::fig5b;
use efficientgrad::sparsity::expected_survivor_fraction;

fn main() {
    // the figure itself
    let out = fig5b::generate(&resnet18_cifar(16), 0.9, None);
    out.report.print();
    out.report
        .save_csv(&efficientgrad::figures::reports_dir().join("fig5b.csv"))
        .unwrap();
    fig5b::headline(0.9).print();

    // batch sweep: where does the advantage move with batch?
    let mut sweep = Report::new(
        "Fig. 5b sweep — batch size vs normalized gains",
        &["batch", "norm throughput", "norm power", "norm energy-eff"],
    );
    for batch in [1, 4, 16, 64, 256] {
        let rows = compare(
            &[&eyeriss_v2_bp(), &efficientgrad()],
            &resnet18_cifar(batch),
            expected_survivor_fraction(0.9),
        );
        sweep.row(vec![
            batch.to_string(),
            format!("{:.2}x", rows[1].norm_throughput),
            format!("{:.2}x", rows[1].norm_power),
            format!("{:.2}x", rows[1].norm_efficiency),
        ]);
    }
    sweep.print();

    // pruning-rate ablation at the paper's network
    let mut ab = Report::new(
        "Ablation — pruning rate P vs gains (resnet18, batch 16)",
        &["P", "survivor", "norm throughput", "norm power"],
    );
    for p in [0.0, 0.5, 0.8, 0.9, 0.95, 0.99] {
        let s = expected_survivor_fraction(p);
        let rows = compare(&[&eyeriss_v2_bp(), &efficientgrad()], &resnet18_cifar(16), s);
        ab.row(vec![
            format!("{p:.2}"),
            format!("{s:.3}"),
            format!("{:.2}x", rows[1].norm_throughput),
            format!("{:.2}x", rows[1].norm_power),
        ]);
    }
    ab.print();

    // dataflow-feature ablation on identical silicon
    let mut feat = Report::new(
        "Ablation — EfficientGrad dataflow vs same-array BP",
        &["config", "step ms", "power W", "norm throughput", "norm power"],
    );
    let rows = compare(
        &[&efficientgrad_bp_ablation(), &efficientgrad()],
        &resnet18_cifar(16),
        expected_survivor_fraction(0.9),
    );
    for r in &rows {
        feat.row(vec![
            r.name.clone(),
            format!("{:.1}", r.step_ms),
            format!("{:.3}", r.power_w),
            format!("{:.2}x", r.norm_throughput),
            format!("{:.2}x", r.norm_power),
        ]);
    }
    feat.print();

    // simulator throughput (it sits on the federated leader's loop)
    let wl = resnet18_cifar(16);
    let s = bench_default("simulate_training(resnet18,b16)", || {
        std::hint::black_box(efficientgrad::accel::simulate_training(
            &efficientgrad(),
            &wl,
            0.585,
        ));
    });
    println!(
        "simulator latency: mean {} (p95 {}) over {} iters",
        fmt_ns(s.mean_ns),
        fmt_ns(s.p95_ns),
        s.iters
    );
}
