//! Bench: regenerates **Fig. 5a** — accuracy convergence per feedback
//! mode — by actually training every exported mode of the target model on
//! the synthetic dataset and asserting the paper's ordering claims:
//!
//!   * efficientgrad ends within a small gap of signsym (pruning is free),
//!   * the signsym family is not worse than binary feedback,
//!   * every mode learns (final accuracy above chance).
//!
//! Budget knobs: FIG5A_STEPS (default 100), FIG5A_MODEL (default
//! convnet_s — the paper's ResNet-18 via FIG5A_MODEL=resnet8/resnet18).
//!
//!     cargo bench --bench fig5a_accuracy

use efficientgrad::figures::fig5a;
use efficientgrad::manifest::Manifest;
use efficientgrad::runtime::Runtime;

fn main() {
    let steps: usize = std::env::var("FIG5A_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    let model = std::env::var("FIG5A_MODEL").unwrap_or_else(|_| "convnet_s".into());

    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP fig5a: artifacts missing (run `make artifacts`)");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT client");
    let exported = manifest.model(&model).expect("model").train_modes();
    let modes: Vec<&str> = exported.iter().map(String::as_str).collect();
    println!("fig5a: training {model} for {steps} steps per mode {modes:?}");

    let t0 = std::time::Instant::now();
    let (rep, results) =
        fig5a::generate(&rt, &manifest, &model, &modes, steps).expect("fig5a");
    println!("trained {} modes in {:.1}s", results.len(), t0.elapsed().as_secs_f64());
    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("fig5a.csv"))
        .unwrap();

    let get = |m: &str| results.iter().find(|r| r.mode == m);
    if let (Some(eg), Some(ss)) = (get("efficientgrad"), get("signsym")) {
        println!(
            "claim: pruning is ~free: efficientgrad {:.4} vs signsym {:.4}",
            eg.final_eval_acc, ss.final_eval_acc
        );
        assert!(
            eg.final_eval_acc > ss.final_eval_acc - 0.12,
            "pruned run lost too much accuracy"
        );
    }
    if let (Some(ss), Some(bin)) = (get("signsym"), get("binary")) {
        println!(
            "claim: signsym >= binary: {:.4} vs {:.4}",
            ss.final_eval_acc, bin.final_eval_acc
        );
        assert!(ss.final_eval_acc > bin.final_eval_acc - 0.05);
    }
    for r in &results {
        assert!(
            r.final_eval_acc > 0.15,
            "mode {} did not learn: {:.4}",
            r.mode,
            r.final_eval_acc
        );
    }
    println!("Fig. 5a ordering claims OK");
}
