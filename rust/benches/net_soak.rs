//! Transport soak: loopback-TCP vs the in-process channel transport.
//!
//! Two things are measured and one is pinned:
//!
//!   * round latency — a full dense-downlink round (dispatch → worker
//!     step → gathered report) over each transport, so the wire tax of
//!     the length-prefixed TCP path is visible next to the channel
//!     baseline;
//!   * plane bytes — the handshake/heartbeat/framing tax the TCP
//!     transport ledgers separately from payload bytes (the in-process
//!     transport must stay at exactly 0);
//!   * parity — before timing anything, a soak loop asserts the report
//!     frames a TCP round produces are byte-for-byte the frames the
//!     in-process transport produces from the same seed. A transport
//!     that perturbs what workers receive or send fails here, not in a
//!     statistics table.
//!
//! Rows land in `BENCH_net.json` (tracked across PRs next to
//! `BENCH_runtime.json` / `BENCH_comm.json`). Set
//! `EFFICIENTGRAD_BENCH_SHORT=1` (CI) for a reduced soak.
//!
//!     cargo bench --bench net_soak

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use efficientgrad::benchlib::{bench, fmt_ns, Report};
use efficientgrad::comm::envelope::encode_update;
use efficientgrad::comm::{Frame, FrameKind, ModelUpdate};
use efficientgrad::config::{CommMode, CommPruner};
use efficientgrad::coordinator::{CommSetup, LiteWorker, WorkerTask};
use efficientgrad::net::client::{self, ClientConfig};
use efficientgrad::net::tcp::TcpTransport;
use efficientgrad::net::{InProcess, Transport};
use efficientgrad::tensor::Tensor;
use efficientgrad::util::rng::Rng;

/// Model size (one tensor, 4·P = 16 KB dense downlink per worker) —
/// big enough that framing overhead is amortised realistically, small
/// enough that the short soak stays inside a CI minute.
const P: usize = 4096;
const N_WORKERS: usize = 3;
const SEED: u64 = 11;
const HASH: u64 = 0x50AC;
const HEARTBEAT_MS: u64 = 25;
const DEADLINE_MS: u64 = 10_000;
const HEADERS: [&str; 6] = ["op", "mean", "p50", "p95", "rounds/s", "plane B"];

fn short_mode() -> bool {
    std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some()
}

fn comm() -> CommSetup {
    CommSetup {
        mode: CommMode::Pruned,
        rate: 0.1,
        pruner: CommPruner::Stochastic,
    }
}

fn head_params() -> Vec<Tensor> {
    let mut rng = Rng::new(SEED);
    let mut data = vec![0f32; P];
    rng.fill_normal(&mut data, 0.5);
    vec![Tensor::new(vec![P], data)]
}

fn spawn_client(addr: String, worker_id: usize) -> thread::JoinHandle<anyhow::Result<()>> {
    thread::spawn(move || {
        let cfg = ClientConfig {
            worker_id,
            config_hash: HASH,
            heartbeat_ms: HEARTBEAT_MS,
            round_deadline_ms: DEADLINE_MS,
            seed: SEED,
            max_connect_attempts: 32,
        };
        client::serve(&addr, &cfg, LiteWorker::new(worker_id, SEED, comm()))
    })
}

/// One dense-downlink round over any transport; replies in worker-id
/// order so twin rounds compare positionally.
fn dense_round(t: &mut dyn Transport, round: usize, frame: &Frame) -> Vec<(usize, Frame)> {
    let (tx, rx) = mpsc::channel();
    for wid in 0..t.workers() {
        t.submit(
            wid,
            WorkerTask {
                round,
                version: round as u64 + 1,
                frame: frame.clone(),
                local_steps: 2,
                slowdown: 1.0,
                sleep: false,
                reply: tx.clone(),
            },
        )
        .unwrap();
    }
    drop(tx);
    let mut got: Vec<(usize, Frame)> = rx.iter().collect();
    got.sort_by_key(|&(wid, _)| wid);
    got
}

fn main() {
    let short = short_mode();
    let soak_rounds = if short { 3 } else { 16 };
    let (warmup, iters) = if short { (1, 5) } else { (2, 20) };

    let frame = Frame::seal(
        FrameKind::Update,
        &encode_update(&ModelUpdate::Dense(head_params())),
    );

    let mut inproc = InProcess::new(
        (0..N_WORKERS)
            .map(|i| LiteWorker::new(i, SEED, comm()))
            .collect::<Vec<_>>(),
    );
    let mut tcp = TcpTransport::bind("127.0.0.1:0", N_WORKERS, HASH, HEARTBEAT_MS, DEADLINE_MS)
        .expect("bind loopback");
    let addr = tcp.local_addr().expect("bound addr");
    let fleet: Vec<_> = (0..N_WORKERS)
        .map(|i| spawn_client(addr.to_string(), i))
        .collect();

    // parity soak first: the statistics below are only worth reading if
    // the two transports are carrying identical traffic
    for round in 0..soak_rounds {
        let a = dense_round(&mut inproc, round, &frame);
        let b = dense_round(&mut tcp, round, &frame);
        assert_eq!(a.len(), N_WORKERS, "round {round}: in-process fleet short");
        assert_eq!(b.len(), N_WORKERS, "round {round}: tcp fleet short");
        for ((wa, fa), (wb, fb)) in a.iter().zip(&b) {
            assert_eq!(wa, wb, "round {round}: reply order by worker id");
            assert_eq!(
                fa.as_bytes(),
                fb.as_bytes(),
                "round {round} worker {wa}: report frames must be byte-identical"
            );
            assert_eq!(fa.open().unwrap().0, FrameKind::Report);
        }
    }
    assert_eq!(inproc.plane_bytes(), 0, "channels pay no plane tax");
    assert!(tcp.plane_bytes() > 0, "TCP must ledger its plane tax");
    println!(
        "parity soak: {soak_rounds} rounds × {N_WORKERS} workers bit-identical across transports"
    );

    let mut rep = Report::new("Transport soak: loopback TCP vs in-process", &HEADERS);

    let mut round = soak_rounds;
    let s = bench(
        "in-process round",
        warmup,
        iters,
        Duration::from_secs(60),
        || {
            let got = dense_round(&mut inproc, round, &frame);
            assert_eq!(got.len(), N_WORKERS);
            round += 1;
        },
    );
    rep.row(vec![
        format!("in-process round ({N_WORKERS}w)"),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p50_ns),
        fmt_ns(s.p95_ns),
        format!("{:.1}", s.throughput(1.0)),
        inproc.plane_bytes().to_string(),
    ]);

    let mut round = soak_rounds;
    let s = bench(
        "loopback-TCP round",
        warmup,
        iters,
        Duration::from_secs(60),
        || {
            let got = dense_round(&mut tcp, round, &frame);
            assert_eq!(got.len(), N_WORKERS);
            round += 1;
        },
    );
    rep.row(vec![
        format!("loopback-TCP round ({N_WORKERS}w)"),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p50_ns),
        fmt_ns(s.p95_ns),
        format!("{:.1}", s.throughput(1.0)),
        tcp.plane_bytes().to_string(),
    ]);

    tcp.shutdown();
    for h in fleet {
        h.join().expect("client thread").expect("client exits Ok");
    }

    rep.print();
    rep.save_json(std::path::Path::new("BENCH_net.json")).unwrap();
    println!("json -> BENCH_net.json");
}
