//! Bench: the L3 request-path hot loop — train and eval steps through the
//! PJRT executable on both residency backends (literal round-trip vs
//! device-resident buffers), plus a mini federated run for the
//! round-level byte ledger. The §Perf claim measured here mirrors the
//! paper's data-movement argument:
//!
//! * the resident path's per-step host transfer of *training state* must
//!   be scalars-only (loss/acc/sparsity = 4·(2+n_feedback) bytes),
//!   against the literal path's full-model round-trip, and its step
//!   latency must be no worse;
//! * the resident eval paths must move **zero** state bytes per eval
//!   (device-resident) or one params upload per param change (cached),
//!   against the literal eval's 4·P upload per batch;
//! * the federated rounds' `RoundReport` device-bus totals must equal
//!   the sum of the per-worker `TransferStats` and match the formulas in
//!   `docs/TRANSFER_MODEL.md`;
//! * the federated *network* tier: per-round wire-byte rows for the
//!   `dense` vs `pruned` vs `sign` comm modes, asserting measured bytes
//!   equal the documented formulas and that the steady-state sign rows
//!   cut ≥5× vs dense at the paper's P=0.9;
//! * allocator traffic on the codec hot path: a counting global
//!   allocator prices `DeltaCodec::encode`'s steady-state allocs/round,
//!   asserting the reusable prune scratch keeps it below the dense
//!   buffer the old code allocated every round (host-only rows — they
//!   run and print even without artifacts).
//!
//! Rows are also emitted to `BENCH_runtime.json` so the trajectory is
//! tracked across PRs. Set `EFFICIENTGRAD_BENCH_SHORT=1` (CI) for a
//! reduced iteration budget — same rows, same asserts, less wall time.
//!
//!     cargo bench --bench runtime_hotpath

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use efficientgrad::benchlib::{bench, bench_default, fmt_ns, Report, Sample};
use efficientgrad::comm::wire::{chained_model_bytes, sign_model_bytes_envelope, sparse_model_bytes};
use efficientgrad::comm::{DeltaCodec, ModelUpdate};
use efficientgrad::config::{CommMode, FedConfig, ResidencyMode, TrainConfig};
use efficientgrad::coordinator::Leader;
use efficientgrad::util::rng::Rng;
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::exec::EvalState;
use efficientgrad::runtime::{
    literal_step_state_bytes, resident_step_state_bytes, tensor_to_literal, DeviceState, Runtime,
    TrainState, TransferStats,
};
use efficientgrad::tensor::Tensor;

/// Counting wrapper over the system allocator: prices allocator traffic
/// on the codec hot path without changing allocation behavior.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

/// Reduced budget for CI (`EFFICIENTGRAD_BENCH_SHORT=1`).
fn short_mode() -> bool {
    std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some()
}

/// Steady-state allocator traffic of `DeltaCodec::encode`: warm two
/// rounds (residual + scratch size themselves there), then measure. The
/// scratch-reuse claim is asserted, not just printed: in sign mode a
/// round's allocations are the wire planes and bookkeeping — a fraction
/// of the dense-size prune buffer the codec used to allocate per round.
/// Synthetic host-only tensors (each ≤ one `util::par` CHUNK, so the
/// encode runs inline and the counter sees only the codec).
fn codec_alloc_rows() -> Vec<Vec<String>> {
    const SHAPES: [usize; 3] = [1 << 16, 1 << 12, 300];
    let elems: usize = SHAPES.iter().sum();
    let dense_bytes = 4 * elems as u64;
    let mut rows = Vec::new();
    for comm in [CommMode::Sign, CommMode::Pruned] {
        let mut codec = DeltaCodec::new(comm, 0.9);
        let reference: Vec<Tensor> = SHAPES.iter().map(|&n| Tensor::zeros(&[n])).collect();
        let mut local = reference.clone();
        let mut data_rng = Rng::new(71);
        let mut prune_rng = Rng::new(72);
        let mut round = |codec: &mut DeltaCodec, local: &mut Vec<Tensor>| {
            for t in local.iter_mut() {
                data_rng.fill_normal(t.data_mut(), 0.02);
            }
            std::hint::black_box(codec.encode(local, &reference, &mut prune_rng).unwrap());
        };
        for _ in 0..2 {
            round(&mut codec, &mut local);
        }
        const ROUNDS: u64 = 20;
        let calls0 = ALLOC_CALLS.load(Ordering::Relaxed);
        let bytes0 = ALLOC_BYTES.load(Ordering::Relaxed);
        for _ in 0..ROUNDS {
            round(&mut codec, &mut local);
        }
        let calls = (ALLOC_CALLS.load(Ordering::Relaxed) - calls0) / ROUNDS;
        let bytes = (ALLOC_BYTES.load(Ordering::Relaxed) - bytes0) / ROUNDS;
        println!(
            "codec alloc/round [{}]: {calls} allocs, {bytes} B (dense prune buffer was {dense_bytes} B)",
            comm.as_str()
        );
        if comm == CommMode::Sign {
            // sign planes are ~E/8 + nnz/8 bytes; with the prune scratch
            // reused, a steady-state round must stay well under the
            // dense-size buffer the pre-scratch codec allocated per round
            assert!(
                bytes < dense_bytes / 2,
                "sign encode allocates {bytes} B/round — scratch reuse regressed \
                 (dense buffer is {dense_bytes} B)"
            );
        }
        rows.push(vec![
            format!("codec alloc/round [{}]: P=0.9, {} tensors ({elems} elems)", comm.as_str(), SHAPES.len()),
            format!("{calls} allocs/round"),
            format!("{bytes} B/round"),
            "-".into(),
            "-".into(),
            format!("dense buffer {dense_bytes} B"),
        ]);
    }
    rows
}

fn main() {
    // host-only: runs (and asserts) before the artifact gate so the
    // allocator rows exist on every platform
    let alloc_rows = codec_alloc_rows();
    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT client");
    let iters = if short_mode() { 8 } else { 30 };
    let step_budget = Duration::from_secs(if short_mode() { 5 } else { 15 });
    let eval_budget = Duration::from_secs(if short_mode() { 3 } else { 10 });
    let mut rep = Report::new(
        "L3 runtime hot path (literal vs device-resident step + eval backends)",
        &["op", "mean", "p50", "p95", "per-image µs", "state B/step"],
    );
    for row in alloc_rows {
        rep.row(row);
    }
    let per_image = |s: &Sample, batch: usize| format!("{:.1}", s.mean_ns / 1e3 / batch as f64);
    let timing_row = |rep: &mut Report, s: &Sample, per_img: String, state: String| {
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            per_img,
            state,
        ]);
    };

    let mut convnet_s_means = (0.0, 0.0); // (literal, resident)
    for model_name in ["convnet_t", "convnet_s"] {
        let model = manifest.model(model_name).unwrap();
        let exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();
        let fwd_exe = rt.load(model.artifact("fwd").unwrap()).unwrap();
        let ds = generate(&SynthConfig {
            n: model.batch,
            seed: 0,
            ..Default::default()
        });
        let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());

        // -- literal path: full state round-trips the host every step --
        let train = TrainState::new(exe.clone(), model).unwrap();
        let mut store = ParamStore::init(model, 1);
        let s = bench(
            &format!("{model_name}: train step (literal)"),
            3,
            iters,
            step_budget,
            || {
                train.step(&mut store, &batch, 0.05, 0.9).unwrap();
            },
        );
        let lit_state_bytes = train.transfer_stats().state_bytes_per_step();
        // the ledger must realize the documented formula exactly
        assert_eq!(
            lit_state_bytes,
            literal_step_state_bytes(
                store.param_elements(),
                store.feedback.iter().map(|t| t.len()).sum(),
                store.feedback.len(),
            ),
            "literal ledger drifted from the documented formula"
        );
        timing_row(&mut rep, &s, per_image(&s, model.batch), lit_state_bytes.to_string());
        let lit_mean = s.mean_ns;

        // -- resident path: state stays in PjRtBuffers; the host sees
        //    only the scalar tail each step. Input donation (default on)
        //    releases the previous step's buffers before the tail
        //    downloads --
        let res_store = ParamStore::init(model, 1);
        let mut dev = DeviceState::new(&rt, exe, model, &res_store).unwrap();
        for _ in 0..3 {
            dev.step(&batch, 0.05, 0.9).unwrap(); // warm outside the ledger
        }
        dev.reset_transfer_stats();
        let s = bench(
            &format!("{model_name}: train step (resident, donate)"),
            0, // already warmed; keep the ledger aligned with the iters
            iters,
            step_budget,
            || {
                dev.step(&batch, 0.05, 0.9).unwrap();
            },
        );
        let stats = dev.transfer_stats();
        let res_state_bytes = stats.state_bytes_per_step();
        // the acceptance claim: per-step state traffic is scalars-only
        assert_eq!(
            res_state_bytes,
            dev.scalar_tail_bytes(),
            "resident path leaked state transfers: {stats:?}"
        );
        assert_eq!(
            dev.scalar_tail_bytes(),
            resident_step_state_bytes(res_store.feedback.len())
        );
        timing_row(&mut rep, &s, per_image(&s, model.batch), res_state_bytes.to_string());
        let res_mean = s.mean_ns;

        // donation off: identical transfers, previous-step buffers held
        // through the tail downloads (the PR-1 error contract)
        dev.set_donate_inputs(false);
        dev.reset_transfer_stats();
        let s = bench(
            &format!("{model_name}: train step (resident, hold inputs)"),
            1,
            iters,
            step_budget,
            || {
                dev.step(&batch, 0.05, 0.9).unwrap();
            },
        );
        assert_eq!(
            dev.transfer_stats().state_bytes_per_step(),
            dev.scalar_tail_bytes(),
            "donation must not change the transfer ledger"
        );
        dev.set_donate_inputs(true);
        timing_row(
            &mut rep,
            &s,
            per_image(&s, model.batch),
            dev.scalar_tail_bytes().to_string(),
        );

        println!(
            "{model_name}: state bytes/step {} -> {} ({}x less), step mean {} -> {}",
            lit_state_bytes,
            res_state_bytes,
            lit_state_bytes / res_state_bytes.max(1),
            fmt_ns(lit_mean),
            fmt_ns(res_mean),
        );
        if model_name == "convnet_s" {
            convnet_s_means = (lit_mean, res_mean);
        }

        // -- eval forward, literal: re-uploads all params every batch --
        let eval_lit =
            EvalState::new(&rt, fwd_exe.clone(), model, ResidencyMode::Literal).unwrap();
        let s = bench(
            &format!("{model_name}: eval fwd (literal)"),
            3,
            iters,
            eval_budget,
            || {
                eval_lit.logits(&store, &batch.images).unwrap();
            },
        );
        let lit_eval_bytes = eval_lit.transfer_stats().state_bytes_per_eval();
        assert_eq!(
            lit_eval_bytes,
            (store.param_elements() * 4) as u64,
            "literal eval should upload 4·P state bytes per batch"
        );
        timing_row(&mut rep, &s, per_image(&s, model.batch), lit_eval_bytes.to_string());

        // -- eval forward, cached buffers: params uploaded once per
        //    param change, zero state bytes per batch after that --
        let eval_res =
            EvalState::new(&rt, fwd_exe.clone(), model, ResidencyMode::Resident).unwrap();
        eval_res.logits(&store, &batch.images).unwrap(); // warm the cache
        eval_res.reset_transfer_stats();
        let s = bench(
            &format!("{model_name}: eval fwd (resident, cached)"),
            0,
            iters,
            eval_budget,
            || {
                eval_res.logits(&store, &batch.images).unwrap();
            },
        );
        let res_eval = eval_res.transfer_stats();
        assert_eq!(
            res_eval.state_up + res_eval.state_down,
            0,
            "cached eval leaked state transfers: {res_eval:?}"
        );
        timing_row(&mut rep, &s, per_image(&s, model.batch), "0".into());

        // -- eval forward, device-resident: fwd runs off the training
        //    param buffers — no upload at all, no sync beforehand --
        dev.reset_transfer_stats();
        let s = bench(
            &format!("{model_name}: eval fwd (device-resident)"),
            2,
            iters,
            eval_budget,
            || {
                dev.eval_logits(&fwd_exe, &batch.images).unwrap();
            },
        );
        let dev_eval = dev.transfer_stats();
        assert_eq!(
            dev_eval.state_up + dev_eval.state_down,
            0,
            "device-resident eval leaked state transfers: {dev_eval:?}"
        );
        assert!(dev_eval.evals > 0 && dev_eval.metrics_down > 0);
        timing_row(&mut rep, &s, per_image(&s, model.batch), "0".into());

        // host->literal conversion overhead (the Rust-side share)
        let s = bench_default(&format!("{model_name}: literals up (params)"), || {
            for t in &store.params {
                std::hint::black_box(tensor_to_literal(t).unwrap());
            }
        });
        timing_row(&mut rep, &s, "-".into(), "-".into());
    }

    // -- federated mini-run: the per-round ledger end-to-end --
    federated_rows(&rt, &manifest, &mut rep);

    // -- leader schedule: pipelined vs sequential round wall time --
    pipeline_rows(&rt, &manifest, &mut rep);

    // -- elastic barrier: quorum vs full-barrier round wall time, and
    //    the chained-downlink byte formula --
    quorum_rows(&rt, &manifest, &mut rep);

    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("runtime_hotpath.csv"))
        .unwrap();
    rep.save_json(std::path::Path::new("BENCH_runtime.json")).unwrap();
    println!("json -> BENCH_runtime.json");

    // resident must not be slower than the path it replaces (5% noise
    // headroom; the transfer assert above is the exact part)
    let (lit, res) = convnet_s_means;
    assert!(
        res <= lit * 1.05,
        "resident step slower than literal on convnet_s: {} vs {}",
        fmt_ns(res),
        fmt_ns(lit)
    );
}

/// Run 2 workers x 3 rounds of federated training per comm mode (dense,
/// pruned, sign at the paper's P=0.9) and emit one row per round with
/// the fleet device-bus and network wire bytes, asserting:
/// * the `RoundReport` device ledger equals the per-worker sum and the
///   resident-path formulas (every mode — comm never touches the bus);
/// * measured network bytes equal the `docs/TRANSFER_MODEL.md` §Network
///   tier formulas applied to the measured survivor counts;
/// * steady state (round 0's downlink is a dense snapshot by design):
///   `pruned` ships fewer bytes than `dense`, and `sign` ships ≤ 1/5 of
///   `dense` both directions combined.
fn federated_rows(rt: &Runtime, manifest: &Manifest, rep: &mut Report) {
    const WORKERS: usize = 2;
    const ROUNDS: usize = 3;
    const LOCAL_STEPS: usize = 3;
    let model = manifest.model("convnet_t").unwrap();
    let probe = ParamStore::init(model, 0);
    let params_bytes = (probe.param_elements() * 4) as u64;
    let n_tensors = probe.params.len() as u64;
    let tail = resident_step_state_bytes(probe.feedback.len());

    // steady-state (rounds 1..) network totals per mode
    let mut steady_net = [0u64; 3];
    for (mode_idx, comm) in [CommMode::Dense, CommMode::Pruned, CommMode::Sign]
        .into_iter()
        .enumerate()
    {
        let cfg = FedConfig {
            workers: WORKERS,
            rounds: ROUNDS,
            local_steps: LOCAL_STEPS,
            iid: true,
            straggler_prob: 0.0,
            straggler_slowdown: 1.0,
            straggler_sleep: false,
            pipeline: false,
            dropout_prob: 0.0,
            comm,
            comm_rate: 0.9, // the paper's P
            train: TrainConfig {
                model: "convnet_t".into(),
                mode: "efficientgrad".into(),
                train_examples: 256,
                test_examples: 64,
                difficulty: 0.4,
                ..Default::default()
            },
            ..FedConfig::default() // full-barrier oracle knobs
        };
        let mut leader = Leader::new(rt, manifest, cfg).expect("leader");
        let summary = leader.run().expect("federated run");
        leader.shutdown();

        for r in &summary.rounds {
            let sum = r
                .worker_transfer
                .iter()
                .fold(TransferStats::default(), |acc, &t| acc + t);
            assert_eq!(r.device_transfer, sum, "round ledger != worker sum");
            for t in &r.worker_transfer {
                // resident round: params broadcast up, per-step tails +
                // one mutable-state sync down — no O(model) per step,
                // and independent of the comm mode
                assert_eq!(t.steps as usize, LOCAL_STEPS);
                assert_eq!(t.state_up, params_bytes);
                assert_eq!(
                    t.state_down,
                    LOCAL_STEPS as u64 * tail + probe.mutable_state_bytes()
                );
            }
            // measured wire bytes == the documented formulas
            match comm {
                CommMode::Dense => {
                    assert_eq!(r.upload_bytes, params_bytes * WORKERS as u64);
                    assert_eq!(r.download_bytes, params_bytes * WORKERS as u64);
                }
                CommMode::Pruned => {
                    assert_eq!(
                        r.upload_bytes,
                        sparse_model_bytes(r.uplink_survivors, WORKERS as u64 * n_tensors),
                        "pruned uplink bytes != formula (round {})",
                        r.round
                    );
                    if r.round > 0 {
                        assert_eq!(
                            r.download_bytes,
                            sparse_model_bytes(
                                r.downlink_survivors,
                                WORKERS as u64 * n_tensors
                            ),
                            "pruned downlink bytes != formula (round {})",
                            r.round
                        );
                    } else {
                        // round 0 broadcasts dense snapshots by design
                        assert_eq!(r.download_bytes, params_bytes * WORKERS as u64);
                    }
                }
                CommMode::Sign => {
                    let (lo, hi) =
                        sign_model_bytes_envelope(probe.params.iter().map(|t| t.len()));
                    let (lo, hi) = (lo * WORKERS as u64, hi * WORKERS as u64);
                    assert!(
                        (lo..=hi).contains(&r.upload_bytes),
                        "sign uplink {} outside formula envelope [{lo}, {hi}]",
                        r.upload_bytes
                    );
                }
            }
            if r.round > 0 {
                steady_net[mode_idx] += r.network_bytes();
            }
            rep.row(vec![
                format!(
                    "federated r{} [{}]: {} workers x {} steps",
                    r.round,
                    comm.as_str(),
                    WORKERS,
                    LOCAL_STEPS
                ),
                format!("{:.2} s", r.wall_secs),
                "-".into(),
                "-".into(),
                format!("net {} B", r.network_bytes()),
                format!("{}/round", r.device_bytes()),
            ]);
        }
        let t = summary.total_device_transfer;
        println!(
            "federated [{}]: {} rounds moved {:.1} KB over the wire \
             ({:.1} KB state + {:.1} KB metrics over the device bus)",
            comm.as_str(),
            summary.rounds.len(),
            (summary.total_upload_bytes + summary.total_download_bytes) as f64 / 1e3,
            (t.state_up + t.state_down) as f64 / 1e3,
            t.metrics_down as f64 / 1e3,
        );
    }

    // the headline cuts at P=0.9, steady state
    let [dense, pruned, sign] = steady_net;
    println!(
        "steady-state net bytes/2 rounds: dense {dense}, pruned {pruned} ({:.2}x), \
         sign {sign} ({:.1}x)",
        dense as f64 / pruned as f64,
        dense as f64 / sign as f64,
    );
    assert!(
        pruned < dense,
        "pruned comm did not cut wire bytes: {pruned} vs dense {dense}"
    );
    assert!(
        sign * 5 <= dense,
        "sign comm missed the 5x wire cut: {sign} vs dense {dense}"
    );
}

/// The schedule claim measured end to end: run the same federated
/// config — straggler injection ON with real wall-clock sleeps
/// (`straggler_sleep`), so one worker genuinely holds each straggled
/// round — under the sequential oracle and the pipelined schedule, and
/// assert the pipelined mean round wall time is no worse. The pipelined
/// leader overlaps its eval sweep (and decode) with worker compute, so
/// the leader drops off the round-critical path; results stay
/// bit-identical (`tests/federated.rs` pins that — here we check the
/// cheap invariants and measure time).
fn pipeline_rows(rt: &Runtime, manifest: &Manifest, rep: &mut Report) {
    let rounds = if short_mode() { 4 } else { 6 };
    let mk = |pipeline: bool| FedConfig {
        workers: 2,
        rounds,
        local_steps: 3,
        iid: true,
        // every round has a sleeping straggler: the sleep dominates the
        // round on both schedules (robust to scheduler noise on small
        // CI runners) and is idle CPU time the pipelined eval overlaps
        straggler_prob: 1.0,
        straggler_slowdown: 2.0,
        straggler_sleep: true, // the straggler holds the round for real
        pipeline,
        dropout_prob: 0.0,
        comm: CommMode::Sign,
        comm_rate: 0.9,
        train: TrainConfig {
            model: "convnet_t".into(),
            mode: "efficientgrad".into(),
            train_examples: 256,
            test_examples: 64,
            difficulty: 0.4,
            ..Default::default()
        },
        ..FedConfig::default() // full-barrier oracle knobs
    };
    let run = |pipeline: bool| {
        let mut leader = Leader::new(rt, manifest, mk(pipeline)).expect("leader");
        let t0 = std::time::Instant::now();
        let summary = leader.run().expect("federated run");
        let total = t0.elapsed().as_secs_f64();
        leader.shutdown();
        (summary, total)
    };
    // sequential first (the oracle), then pipelined on the same machine
    let (seq, seq_total) = run(false);
    let (pipe, pipe_total) = run(true);

    let mean_wall = |s: &efficientgrad::coordinator::FedSummary| {
        s.rounds.iter().map(|r| r.wall_secs).sum::<f64>() / s.rounds.len() as f64
    };
    let mean_leader = |s: &efficientgrad::coordinator::FedSummary| {
        s.rounds.iter().map(|r| r.leader_secs).sum::<f64>() / s.rounds.len() as f64
    };
    let (seq_mean, pipe_mean) = (mean_wall(&seq), mean_wall(&pipe));
    for (label, s, total) in [("sequential", &seq, seq_total), ("pipelined", &pipe, pipe_total)] {
        rep.row(vec![
            format!("federated schedule [{label}]: {rounds} rounds, straggler 1.0x2.0"),
            format!("{:.4} s/round", mean_wall(s)),
            format!("leader {:.4} s/round", mean_leader(s)),
            "-".into(),
            format!("total {total:.3} s"),
            "-".into(),
        ]);
    }
    let speedup = seq_mean / pipe_mean;
    rep.row(vec![
        "federated pipeline speedup (mean round wall, seq/pipe)".into(),
        format!("{speedup:.2}x"),
        format!("total {:.2}x", seq_total / pipe_total),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!(
        "pipelined schedule: {seq_mean:.4} -> {pipe_mean:.4} s/round ({speedup:.2}x), \
         run total {seq_total:.3} -> {pipe_total:.3} s"
    );
    // cheap cross-schedule invariants (the full bit-parity pin lives in
    // tests/federated.rs — timing noise must not mask a wrong result)
    assert_eq!(seq.final_acc.to_bits(), pipe.final_acc.to_bits());
    assert_eq!(seq.total_upload_bytes, pipe.total_upload_bytes);
    assert_eq!(seq.total_download_bytes, pipe.total_download_bytes);
    // the acceptance claim: taking the leader off the round-critical
    // path must not make rounds slower under a straggler — and should
    // make them faster by ~the eval sweep (which hides inside the
    // straggler's idle sleep). The straggler-dominated rounds make the
    // comparison stable; 10% headroom absorbs residual scheduler noise
    // on small shared CI runners.
    assert!(
        pipe_mean <= seq_mean * 1.10,
        "pipelined rounds slower than sequential: {pipe_mean:.4}s vs {seq_mean:.4}s"
    );
}

/// The elastic-barrier claim measured end to end: the same federated
/// config — wall-clock straggler injection ON, so sleeping workers
/// genuinely hold rounds — under the full barrier (`quorum = 1.0`, the
/// oracle) and a quorum schedule (`quorum = 0.5`: with 2 workers the
/// leader folds at the FIRST report and the other folds late with a λ^k
/// discount). Asserts quorum-mode mean round wall time ≤ full-barrier
/// mean, emits both rows plus the speedup into `BENCH_runtime.json` —
/// and prices a real 3-link chained downlink against the dense resync
/// it replaces, asserting the `8 + Σ link` formula from
/// `docs/TRANSFER_MODEL.md` §Model versions & staleness.
fn quorum_rows(rt: &Runtime, manifest: &Manifest, rep: &mut Report) {
    let rounds = if short_mode() { 4 } else { 6 };
    let mk = |quorum: f64| FedConfig {
        workers: 2,
        rounds,
        local_steps: 3,
        iid: true,
        // most rounds have at least one sleeping straggler the quorum
        // schedule does not wait for; identical seeds give both runs the
        // identical straggler pattern
        straggler_prob: 0.75,
        straggler_slowdown: 2.0,
        straggler_sleep: true,
        pipeline: false,
        dropout_prob: 0.0,
        comm: CommMode::Sign,
        comm_rate: 0.9,
        quorum,
        staleness_decay: 0.5,
        pipeline_depth: 2,
        max_chain: 3,
        train: TrainConfig {
            model: "convnet_t".into(),
            mode: "efficientgrad".into(),
            train_examples: 256,
            test_examples: 64,
            difficulty: 0.4,
            ..Default::default()
        },
        ..FedConfig::default()
    };
    let run = |quorum: f64| {
        let mut leader = Leader::new(rt, manifest, mk(quorum)).expect("leader");
        let t0 = std::time::Instant::now();
        let summary = leader.run().expect("federated run");
        let total = t0.elapsed().as_secs_f64();
        leader.shutdown();
        (summary, total)
    };
    let (barrier, barrier_total) = run(1.0);
    let (quorum, quorum_total) = run(0.5);

    let mean_wall = |s: &efficientgrad::coordinator::FedSummary| {
        s.rounds.iter().map(|r| r.wall_secs).sum::<f64>() / s.rounds.len() as f64
    };
    let (barrier_mean, quorum_mean) = (mean_wall(&barrier), mean_wall(&quorum));
    let late_total: usize = quorum.rounds.iter().map(|r| r.late_reports).sum();
    let mass_total: f64 = quorum.rounds.iter().map(|r| r.stale_weight_mass).sum();
    for (label, s, total, extra) in [
        ("full barrier", &barrier, barrier_total, String::new()),
        (
            "quorum 0.5",
            &quorum,
            quorum_total,
            format!("{late_total} late (λ-mass {mass_total:.2})"),
        ),
    ] {
        rep.row(vec![
            format!("federated barrier [{label}]: {rounds} rounds, straggler 0.75x2.0"),
            format!("{:.4} s/round", mean_wall(s)),
            format!("total {total:.3} s"),
            "-".into(),
            extra,
            "-".into(),
        ]);
    }
    let speedup = barrier_mean / quorum_mean;
    rep.row(vec![
        "federated quorum speedup (mean round wall, barrier/quorum)".into(),
        format!("{speedup:.2}x"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!(
        "quorum schedule: {barrier_mean:.4} -> {quorum_mean:.4} s/round ({speedup:.2}x), \
         {late_total} late reports folded"
    );
    // every stashed straggler from a non-final round must eventually
    // fold (the pipeline depth forces resolution) — the quorum schedule
    // must not silently lose reports
    assert!(
        late_total >= rounds.saturating_sub(2),
        "quorum run folded only {late_total} late reports over {rounds} rounds"
    );
    // the adaptive-cutoff acceptance: skipping the barrier must not make
    // rounds slower (and should cut ~the straggler sleep); 10% headroom
    // for scheduler noise on shared CI runners
    assert!(
        quorum_mean <= barrier_mean * 1.10,
        "quorum rounds slower than the full barrier: {quorum_mean:.4}s vs {barrier_mean:.4}s"
    );

    // -- chained downlink vs dense resync: price a real k=3 chain built
    //    by the downlink codec over convnet_t-shaped deltas --
    let model = manifest.model("convnet_t").unwrap();
    let probe = ParamStore::init(model, 3);
    let dense_resync = (probe.param_elements() * 4) as u64;
    let mut codec = DeltaCodec::new(CommMode::Sign, 0.9);
    let mut reference = probe.params.clone();
    let mut drift_rng = Rng::new(17);
    let mut prune_rng = Rng::new(18);
    let mut links = Vec::new();
    for _ in 0..3 {
        let mut global = reference.clone();
        for t in global.iter_mut() {
            let mut d = vec![0f32; t.len()];
            drift_rng.fill_normal(&mut d, 0.02); // a round-sized drift
            for (o, &dv) in t.data_mut().iter_mut().zip(&d) {
                *o += dv;
            }
        }
        let u = codec.encode(&global, &reference, &mut prune_rng).unwrap();
        u.apply(&mut reference).unwrap();
        match u {
            ModelUpdate::Delta(us) => links.push(us),
            _ => unreachable!("compressed codec emits deltas"),
        }
    }
    let chain = ModelUpdate::Chain(links.clone());
    let formula = chained_model_bytes(
        links
            .iter()
            .map(|us| us.iter().map(|u| u.wire_bytes()).sum::<u64>()),
    );
    assert_eq!(
        chain.wire_bytes(),
        formula,
        "chained downlink bytes drifted from the documented 8 + Σ link formula"
    );
    assert!(
        chain.wire_bytes() < dense_resync,
        "k=3 sign chain {} B did not undercut the dense resync {} B",
        chain.wire_bytes(),
        dense_resync
    );
    rep.row(vec![
        "chained downlink k=3 [sign, P=0.9] vs dense resync".into(),
        format!("{} B", chain.wire_bytes()),
        format!("dense {dense_resync} B"),
        format!("{:.1}x", dense_resync as f64 / chain.wire_bytes() as f64),
        format!("{} survivors", chain.survivors()),
        "-".into(),
    ]);
    println!(
        "chained downlink: k=3 chain {} B vs dense resync {} B ({:.1}x)",
        chain.wire_bytes(),
        dense_resync,
        dense_resync as f64 / chain.wire_bytes() as f64
    );
}
