//! Bench: the L3 request-path hot loop — one train step through the PJRT
//! executable, broken into its components (literal upload, execute,
//! download), plus eval-forward latency/throughput. This is the §Perf
//! target for layer 3: the Rust overhead around `execute` should be a
//! small fraction of step time.
//!
//!     cargo bench --bench runtime_hotpath

use std::time::Duration;

use efficientgrad::benchlib::{bench, bench_default, fmt_ns, Report};
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::exec::EvalState;
use efficientgrad::runtime::{tensor_to_literal, Runtime, TrainState};

fn main() {
    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT client");
    let mut rep = Report::new(
        "L3 runtime hot path (convnet_s unless noted)",
        &["op", "mean", "p50", "p95", "per-image µs"],
    );

    for model_name in ["convnet_t", "convnet_s"] {
        let model = manifest.model(model_name).unwrap();
        let train = TrainState::new(
            rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap(),
            model,
        )
        .unwrap();
        let eval =
            EvalState::new(rt.load(model.artifact("fwd").unwrap()).unwrap(), model).unwrap();
        let mut store = ParamStore::init(model, 1);
        let ds = generate(&SynthConfig {
            n: model.batch,
            seed: 0,
            ..Default::default()
        });
        let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());

        // full train step
        let s = bench(
            &format!("{model_name}: train step"),
            3,
            30,
            Duration::from_secs(15),
            || {
                train.step(&mut store, &batch, 0.05, 0.9).unwrap();
            },
        );
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            format!("{:.1}", s.mean_ns / 1e3 / model.batch as f64),
        ]);

        // eval forward
        let s = bench(
            &format!("{model_name}: eval fwd"),
            3,
            30,
            Duration::from_secs(10),
            || {
                eval.logits(&store, &batch.images).unwrap();
            },
        );
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            format!("{:.1}", s.mean_ns / 1e3 / model.batch as f64),
        ]);

        // host->literal conversion overhead (the Rust-side share)
        let s = bench_default(&format!("{model_name}: literals up (params)"), || {
            for t in &store.params {
                std::hint::black_box(tensor_to_literal(t).unwrap());
            }
        });
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            "-".into(),
        ]);
    }
    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("runtime_hotpath.csv"))
        .unwrap();
}
