//! Bench: the L3 request-path hot loop — one train step through the PJRT
//! executable on both step backends (literal round-trip vs
//! device-resident buffers), plus eval-forward latency/throughput.
//! The §Perf claim measured here mirrors the paper's data-movement
//! argument: the resident path's per-step host transfer of *training
//! state* must be scalars-only (loss/acc/sparsity = 4·(2+n_feedback)
//! bytes), against the literal path's full-model round-trip, and its
//! step latency must be no worse. Rows are also emitted to
//! `BENCH_runtime.json` so the trajectory is tracked across PRs.
//!
//!     cargo bench --bench runtime_hotpath

use std::time::Duration;

use efficientgrad::benchlib::{bench, bench_default, fmt_ns, Report, Sample};
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::exec::EvalState;
use efficientgrad::runtime::{tensor_to_literal, DeviceState, Runtime, TrainState};

fn main() {
    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT client");
    let mut rep = Report::new(
        "L3 runtime hot path (literal vs device-resident step backends)",
        &["op", "mean", "p50", "p95", "per-image µs", "state B/step"],
    );
    let per_image = |s: &Sample, batch: usize| format!("{:.1}", s.mean_ns / 1e3 / batch as f64);

    let mut convnet_s_means = (0.0, 0.0); // (literal, resident)
    for model_name in ["convnet_t", "convnet_s"] {
        let model = manifest.model(model_name).unwrap();
        let exe = rt.load(model.artifact("train_efficientgrad").unwrap()).unwrap();
        let eval =
            EvalState::new(rt.load(model.artifact("fwd").unwrap()).unwrap(), model).unwrap();
        let ds = generate(&SynthConfig {
            n: model.batch,
            seed: 0,
            ..Default::default()
        });
        let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());

        // -- literal path: full state round-trips the host every step --
        let train = TrainState::new(exe.clone(), model).unwrap();
        let mut store = ParamStore::init(model, 1);
        let s = bench(
            &format!("{model_name}: train step (literal)"),
            3,
            30,
            Duration::from_secs(15),
            || {
                train.step(&mut store, &batch, 0.05, 0.9).unwrap();
            },
        );
        let lit_state_bytes = train.transfer_stats().state_bytes_per_step();
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            per_image(&s, model.batch),
            lit_state_bytes.to_string(),
        ]);
        let lit_mean = s.mean_ns;

        // -- resident path: state stays in PjRtBuffers; the host sees
        //    only the scalar tail each step --
        let res_store = ParamStore::init(model, 1);
        let mut dev = DeviceState::new(&rt, exe, model, &res_store).unwrap();
        for _ in 0..3 {
            dev.step(&batch, 0.05, 0.9).unwrap(); // warm outside the ledger
        }
        dev.reset_transfer_stats();
        let s = bench(
            &format!("{model_name}: train step (resident)"),
            0, // already warmed; keep the ledger aligned with the iters
            30,
            Duration::from_secs(15),
            || {
                dev.step(&batch, 0.05, 0.9).unwrap();
            },
        );
        let stats = dev.transfer_stats();
        let res_state_bytes = stats.state_bytes_per_step();
        // the acceptance claim: per-step state traffic is scalars-only
        assert_eq!(
            res_state_bytes,
            dev.scalar_tail_bytes(),
            "resident path leaked state transfers: {stats:?}"
        );
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            per_image(&s, model.batch),
            res_state_bytes.to_string(),
        ]);
        println!(
            "{model_name}: state bytes/step {} -> {} ({}x less), step mean {} -> {}",
            lit_state_bytes,
            res_state_bytes,
            lit_state_bytes / res_state_bytes.max(1),
            fmt_ns(lit_mean),
            fmt_ns(s.mean_ns),
        );
        if model_name == "convnet_s" {
            convnet_s_means = (lit_mean, s.mean_ns);
        }

        // -- eval forward (host store; unchanged by residency) --
        let s = bench(
            &format!("{model_name}: eval fwd"),
            3,
            30,
            Duration::from_secs(10),
            || {
                eval.logits(&store, &batch.images).unwrap();
            },
        );
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            per_image(&s, model.batch),
            "-".into(),
        ]);

        // host->literal conversion overhead (the Rust-side share)
        let s = bench_default(&format!("{model_name}: literals up (params)"), || {
            for t in &store.params {
                std::hint::black_box(tensor_to_literal(t).unwrap());
            }
        });
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p50_ns),
            fmt_ns(s.p95_ns),
            "-".into(),
            "-".into(),
        ]);
    }
    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("runtime_hotpath.csv"))
        .unwrap();
    rep.save_json(std::path::Path::new("BENCH_runtime.json")).unwrap();
    println!("json -> BENCH_runtime.json");

    // resident must not be slower than the path it replaces (5% noise
    // headroom; the transfer assert above is the exact part)
    let (lit, res) = convnet_s_means;
    assert!(
        res <= lit * 1.05,
        "resident step slower than literal on convnet_s: {} vs {}",
        fmt_ns(res),
        fmt_ns(lit)
    );
}
