//! Bench: the federated comm codec on the host — encode/decode
//! throughput and wire bytes per mode/rate over an edge-CNN-shaped
//! parameter set (~26k elements). Pure host math: runs (and asserts) without artifacts,
//! so CI always accumulates these rows even where the PJRT-backed
//! `runtime_hotpath` skips.
//!
//! Asserted here, mirroring `docs/TRANSFER_MODEL.md` §Network tier:
//! * measured wire bytes equal the documented formulas applied to the
//!   measured survivor counts (sparse exactly; sign per-tensor exactly);
//! * at the paper's P=0.9, `sign` ships ≤ 1/5 of dense (steady state —
//!   the ≥10× headline lands near 20×) and `pruned` ships less than
//!   dense;
//! * the error-feedback residual norm stays bounded across rounds.
//!
//!     cargo bench --bench comm_bytes        (make bench-comm)

use efficientgrad::benchlib::{bench, fmt_ns, Report};
use efficientgrad::comm::envelope::{encode_update, FRAME_HEADER_BYTES};
use efficientgrad::comm::wire::{
    chained_model_bytes, merged_chain_bytes, quantized_tensor_bytes, sign_tensor_bytes,
    sparse_tensor_bytes, support_bytes,
};
use efficientgrad::comm::{DeltaCodec, Frame, FrameKind, ModelUpdate, TensorUpdate};
use efficientgrad::config::{CommMode, CommPruner, WireQuant};
use efficientgrad::tensor::Tensor;
use efficientgrad::util::rng::Rng;
use std::time::Duration;

/// Edge-CNN-shaped parameter set (a few conv kernels + scale/bias vecs
/// + an fc head, ~26k elements) — sized like the small end of the
/// repo's models, deliberately *not* labeled `convnet_s` (~42k), whose
/// worked numbers live in `docs/TRANSFER_MODEL.md`.
fn model_shapes() -> Vec<Vec<usize>> {
    vec![
        vec![3, 3, 3, 16],
        vec![16],
        vec![16],
        vec![3, 3, 16, 32],
        vec![32],
        vec![32],
        vec![32 * 8 * 8, 10],
        vec![10],
    ]
}

fn randn_like(shapes: &[Vec<usize>], sigma: f32, rng: &mut Rng) -> Vec<Tensor> {
    shapes.iter().map(|s| Tensor::randn(s, sigma, rng)).collect()
}

fn main() {
    let short = std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some();
    let iters = if short { 10 } else { 40 };
    let rounds = if short { 10 } else { 25 };
    let shapes = model_shapes();
    let elems: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let dense_bytes = 4 * elems as u64;

    let mut rep = Report::new(
        "federated comm codec (pruned-delta wire formats, edge-CNN-shaped ~26k params)",
        &["mode/rate", "encode mean", "p95", "wire B/round", "vs dense", "survivors"],
    );

    let mut rng = Rng::new(7);
    let reference = randn_like(&shapes, 0.1, &mut rng);

    // steady-state wire bytes at (Pruned, 0.9) per pruner — the top-k
    // sharpening assert below compares them — plus the v2-quantization
    // rows the §Wire v2 asserts compare
    let mut pruned_stochastic_wire = 0u64;
    let mut pruned_topk_wire = 0u64;
    let mut pruned_q8_wire = 0u64;
    let mut pruned_q4_wire = 0u64;
    let mut sign_topk_wire = 0u64;
    for (mode, rate, pruner, quant) in [
        (CommMode::Dense, 0.0, CommPruner::Stochastic, WireQuant::Off),
        (CommMode::Pruned, 0.5, CommPruner::Stochastic, WireQuant::Off),
        (CommMode::Pruned, 0.9, CommPruner::Stochastic, WireQuant::Off),
        (CommMode::Pruned, 0.99, CommPruner::Stochastic, WireQuant::Off),
        (CommMode::Pruned, 0.9, CommPruner::TopK, WireQuant::Off),
        (CommMode::Pruned, 0.9, CommPruner::TopK, WireQuant::Q8),
        (CommMode::Pruned, 0.9, CommPruner::TopK, WireQuant::Q4),
        (CommMode::Sign, 0.5, CommPruner::Stochastic, WireQuant::Off),
        (CommMode::Sign, 0.9, CommPruner::Stochastic, WireQuant::Off),
        (CommMode::Sign, 0.9, CommPruner::TopK, WireQuant::Off),
        (CommMode::Sign, 0.99, CommPruner::Stochastic, WireQuant::Off),
    ] {
        // drive the codec to its error-feedback steady state over
        // synthetic round deltas, then measure encode latency + bytes
        let mut codec = DeltaCodec::with_pruner(mode, rate, pruner).with_quant(quant);
        let mut delta_rng = Rng::new(11);
        let mut prune_rng = Rng::new(13);
        let mut local = reference.clone();
        let mut update = None;
        let mut wire_total = 0u64;
        let mut surv_total = 0u64;
        for _ in 0..rounds {
            // a fresh round delta on top of the reference
            for (l, r) in local.iter_mut().zip(&reference) {
                let mut d = vec![0f32; r.len()];
                delta_rng.fill_normal(&mut d, 0.02);
                l.data_mut().copy_from_slice(r.data());
                for (o, &dv) in l.data_mut().iter_mut().zip(&d) {
                    *o += dv;
                }
            }
            let u = codec.encode(&local, &reference, &mut prune_rng).unwrap();
            wire_total += u.wire_bytes();
            surv_total += u.survivors();
            update = Some(u);
        }
        let wire = wire_total / rounds as u64;
        let survivors = surv_total / rounds as u64;
        let residual_after = codec.residual_norm();

        // measured bytes == documented formulas on the last update
        let last = update.unwrap();
        match &last {
            ModelUpdate::Dense(_) => assert_eq!(last.wire_bytes(), dense_bytes),
            ModelUpdate::Delta(us) => {
                let formula: u64 = us
                    .iter()
                    .map(|u| match u {
                        TensorUpdate::Sparse(t) => sparse_tensor_bytes(t.nnz()),
                        TensorUpdate::Sign(t) => {
                            sign_tensor_bytes(t.elems as usize, t.nnz as usize)
                        }
                        TensorUpdate::Quantized(t) => quantized_tensor_bytes(
                            support_bytes(t.elems as usize, &t.indices),
                            t.nnz(),
                            t.bits,
                        ),
                    })
                    .sum();
                assert_eq!(last.wire_bytes(), formula, "wire bytes drifted from formula");
            }
            ModelUpdate::Chain(_) => unreachable!("encode never emits chains"),
        }

        // EF stability: residual bounded by a few σ·√n after many rounds
        if mode != CommMode::Dense {
            let bound = 8.0 * 0.02 * (elems as f64).sqrt();
            assert!(
                residual_after < bound,
                "{mode:?}/{rate}: residual {residual_after} exceeded {bound}"
            );
        }

        let mut tag = match pruner {
            CommPruner::Stochastic => String::new(),
            CommPruner::TopK => "/topk".into(),
        };
        if quant != WireQuant::Off {
            tag.push('/');
            tag.push_str(quant.as_str());
        }
        let s = bench(
            &format!("encode {}/{rate}{tag}", mode.as_str()),
            2,
            iters,
            Duration::from_secs(if short { 2 } else { 6 }),
            || {
                let mut c = DeltaCodec::with_pruner(mode, rate, pruner).with_quant(quant);
                std::hint::black_box(
                    c.encode(&local, &reference, &mut Rng::new(3)).unwrap(),
                );
            },
        );
        rep.row(vec![
            format!("{}/{rate}{tag}", mode.as_str()),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p95_ns),
            wire.to_string(),
            format!("{:.1}x", dense_bytes as f64 / wire as f64),
            survivors.to_string(),
        ]);
        if rate == 0.9 {
            match (mode, pruner, quant) {
                (CommMode::Pruned, CommPruner::Stochastic, WireQuant::Off) => {
                    pruned_stochastic_wire = wire
                }
                (CommMode::Pruned, CommPruner::TopK, WireQuant::Off) => pruned_topk_wire = wire,
                (CommMode::Pruned, CommPruner::TopK, WireQuant::Q8) => pruned_q8_wire = wire,
                (CommMode::Pruned, CommPruner::TopK, WireQuant::Q4) => pruned_q4_wire = wire,
                (CommMode::Sign, CommPruner::TopK, WireQuant::Off) => sign_topk_wire = wire,
                _ => {}
            }
        }

        // the headline asserts at the paper's operating point
        if rate == 0.9 {
            match mode {
                CommMode::Pruned => assert!(
                    wire < dense_bytes,
                    "pruned wire {wire} not below dense {dense_bytes}"
                ),
                CommMode::Sign => assert!(
                    wire * 5 <= dense_bytes,
                    "sign wire {wire} missed the 5x cut vs {dense_bytes}"
                ),
                CommMode::Dense => {}
            }
        }
    }

    // top-k sharpening (ROADMAP PR 3 follow-up): exact ⌈(1−P)·E⌉
    // survivors vs eq. 3's ≈46% promotion floor — at P=0.9 the pruned
    // format's wire must drop to well under half the stochastic row's
    println!(
        "pruned/0.9 wire: stochastic {pruned_stochastic_wire} B -> topk {pruned_topk_wire} B \
         ({:.1}x sharper)",
        pruned_stochastic_wire as f64 / pruned_topk_wire as f64
    );
    assert!(
        pruned_topk_wire * 2 <= pruned_stochastic_wire,
        "top-k failed to sharpen the pruned cut: {pruned_topk_wire} vs {pruned_stochastic_wire}"
    );

    // wire v2 (docs/TRANSFER_MODEL.md §Wire v2): quantizing the topk
    // survivors drops the f32 payload 8 B → 1 B (q8) / 0.5 B (q4) + the
    // shared support, so at P=0.9 q8 must cut the f32 row ≥ 2x, land
    // within 2x of the sign format (which ships ~1.25 bits/survivor but
    // no magnitudes), and q4 must undercut q8
    println!(
        "pruned/0.9/topk wire: f32 {pruned_topk_wire} B -> q8 {pruned_q8_wire} B -> q4 \
         {pruned_q4_wire} B (sign/topk {sign_topk_wire} B)"
    );
    assert!(
        pruned_q8_wire * 2 <= pruned_topk_wire,
        "q8 failed to cut the f32 pruned wire: {pruned_q8_wire} vs {pruned_topk_wire}"
    );
    assert!(
        pruned_q8_wire <= 2 * sign_topk_wire,
        "q8 wire {pruned_q8_wire} not within 2x of sign {sign_topk_wire}"
    );
    assert!(
        pruned_q4_wire < pruned_q8_wire,
        "q4 wire {pruned_q4_wire} not below q8 {pruned_q8_wire}"
    );

    // merged-chain resync (k = 3): three steady-state q8 links merged
    // into the UPDATE_CHAIN_MERGED record must ship ≤ 0.6x the bytes of
    // the legacy per-link f32-sparse chain carrying the same survivors
    {
        let mut codec =
            DeltaCodec::with_pruner(CommMode::Pruned, 0.9, CommPruner::TopK).with_quant(WireQuant::Q8);
        let mut delta_rng = Rng::new(17);
        let mut prune_rng = Rng::new(19);
        let mut local = reference.clone();
        let mut links = Vec::new();
        for _ in 0..3 {
            for (l, r) in local.iter_mut().zip(&reference) {
                let mut d = vec![0f32; r.len()];
                delta_rng.fill_normal(&mut d, 0.02);
                l.data_mut().copy_from_slice(r.data());
                for (o, &dv) in l.data_mut().iter_mut().zip(&d) {
                    *o += dv;
                }
            }
            match codec.encode(&local, &reference, &mut prune_rng).unwrap() {
                ModelUpdate::Delta(us) => links.push(us),
                _ => unreachable!("pruned encode emits deltas"),
            }
        }
        let chain = ModelUpdate::Chain(links.clone());
        let merged = chain.wire_bytes();
        assert_eq!(merged, merged_chain_bytes(&links), "merged bytes drifted from formula");
        let legacy = chained_model_bytes(links.iter().map(|l| {
            l.iter()
                .map(|u| match u {
                    TensorUpdate::Quantized(t) => sparse_tensor_bytes(t.nnz()),
                    _ => unreachable!("q8 encode emits quantized tensors"),
                })
                .sum()
        }));
        println!(
            "merged k=3 chain: {merged} B vs legacy per-link f32 chain {legacy} B ({:.2}x)",
            merged as f64 / legacy as f64
        );
        assert!(
            merged * 10 <= legacy * 6,
            "merged chain {merged} B missed the 0.6x cut vs legacy {legacy} B"
        );
        rep.row(vec![
            "chain/k=3/merged-q8".into(),
            "-".into(),
            "-".into(),
            merged.to_string(),
            format!("{:.1}x", dense_bytes as f64 / merged as f64),
            chain.survivors().to_string(),
        ]);
    }

    // integrity envelope (docs/TRANSFER_MODEL.md §Integrity & recovery):
    // sealing a payload adds a flat FRAME_HEADER_BYTES of header —
    // magic, schema version, kind, length, FNV-1a checksum — so the
    // integrity tax per round is 24 B × frames, independent of P
    let payload = encode_update(&ModelUpdate::Dense(reference.clone()));
    let sealed = Frame::seal(FrameKind::Update, &payload);
    assert_eq!(
        sealed.wire_bytes(),
        payload.len() as u64 + FRAME_HEADER_BYTES,
        "envelope overhead drifted from the documented flat header"
    );
    assert!(sealed.open().is_ok(), "a clean seal must verify");
    rep.row(vec![
        "envelope/frame".into(),
        "-".into(),
        "-".into(),
        FRAME_HEADER_BYTES.to_string(),
        format!("{:.4}x", FRAME_HEADER_BYTES as f64 / dense_bytes as f64),
        "-".into(),
    ]);

    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("comm_bytes.csv"))
        .unwrap();
    rep.save_json(std::path::Path::new("BENCH_comm.json")).unwrap();
    println!("json -> BENCH_comm.json");
}
