//! Bench: the federated comm codec on the host — encode/decode
//! throughput and wire bytes per mode/rate over an edge-CNN-shaped
//! parameter set (~26k elements). Pure host math: runs (and asserts) without artifacts,
//! so CI always accumulates these rows even where the PJRT-backed
//! `runtime_hotpath` skips.
//!
//! Asserted here, mirroring `docs/TRANSFER_MODEL.md` §Network tier:
//! * measured wire bytes equal the documented formulas applied to the
//!   measured survivor counts (sparse exactly; sign per-tensor exactly);
//! * at the paper's P=0.9, `sign` ships ≤ 1/5 of dense (steady state —
//!   the ≥10× headline lands near 20×) and `pruned` ships less than
//!   dense;
//! * the error-feedback residual norm stays bounded across rounds.
//!
//!     cargo bench --bench comm_bytes        (make bench-comm)

use efficientgrad::benchlib::{bench, fmt_ns, Report};
use efficientgrad::comm::envelope::{encode_update, FRAME_HEADER_BYTES};
use efficientgrad::comm::wire::{sign_tensor_bytes, sparse_tensor_bytes};
use efficientgrad::comm::{DeltaCodec, Frame, FrameKind, ModelUpdate, TensorUpdate};
use efficientgrad::config::{CommMode, CommPruner};
use efficientgrad::tensor::Tensor;
use efficientgrad::util::rng::Rng;
use std::time::Duration;

/// Edge-CNN-shaped parameter set (a few conv kernels + scale/bias vecs
/// + an fc head, ~26k elements) — sized like the small end of the
/// repo's models, deliberately *not* labeled `convnet_s` (~42k), whose
/// worked numbers live in `docs/TRANSFER_MODEL.md`.
fn model_shapes() -> Vec<Vec<usize>> {
    vec![
        vec![3, 3, 3, 16],
        vec![16],
        vec![16],
        vec![3, 3, 16, 32],
        vec![32],
        vec![32],
        vec![32 * 8 * 8, 10],
        vec![10],
    ]
}

fn randn_like(shapes: &[Vec<usize>], sigma: f32, rng: &mut Rng) -> Vec<Tensor> {
    shapes.iter().map(|s| Tensor::randn(s, sigma, rng)).collect()
}

fn main() {
    let short = std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some();
    let iters = if short { 10 } else { 40 };
    let rounds = if short { 10 } else { 25 };
    let shapes = model_shapes();
    let elems: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let dense_bytes = 4 * elems as u64;

    let mut rep = Report::new(
        "federated comm codec (pruned-delta wire formats, edge-CNN-shaped ~26k params)",
        &["mode/rate", "encode mean", "p95", "wire B/round", "vs dense", "survivors"],
    );

    let mut rng = Rng::new(7);
    let reference = randn_like(&shapes, 0.1, &mut rng);

    // steady-state wire bytes at (Pruned, 0.9) per pruner — the top-k
    // sharpening assert below compares them
    let mut pruned_stochastic_wire = 0u64;
    let mut pruned_topk_wire = 0u64;
    for (mode, rate, pruner) in [
        (CommMode::Dense, 0.0, CommPruner::Stochastic),
        (CommMode::Pruned, 0.5, CommPruner::Stochastic),
        (CommMode::Pruned, 0.9, CommPruner::Stochastic),
        (CommMode::Pruned, 0.99, CommPruner::Stochastic),
        (CommMode::Pruned, 0.9, CommPruner::TopK),
        (CommMode::Sign, 0.5, CommPruner::Stochastic),
        (CommMode::Sign, 0.9, CommPruner::Stochastic),
        (CommMode::Sign, 0.9, CommPruner::TopK),
        (CommMode::Sign, 0.99, CommPruner::Stochastic),
    ] {
        // drive the codec to its error-feedback steady state over
        // synthetic round deltas, then measure encode latency + bytes
        let mut codec = DeltaCodec::with_pruner(mode, rate, pruner);
        let mut delta_rng = Rng::new(11);
        let mut prune_rng = Rng::new(13);
        let mut local = reference.clone();
        let mut update = None;
        let mut wire_total = 0u64;
        let mut surv_total = 0u64;
        for _ in 0..rounds {
            // a fresh round delta on top of the reference
            for (l, r) in local.iter_mut().zip(&reference) {
                let mut d = vec![0f32; r.len()];
                delta_rng.fill_normal(&mut d, 0.02);
                l.data_mut().copy_from_slice(r.data());
                for (o, &dv) in l.data_mut().iter_mut().zip(&d) {
                    *o += dv;
                }
            }
            let u = codec.encode(&local, &reference, &mut prune_rng).unwrap();
            wire_total += u.wire_bytes();
            surv_total += u.survivors();
            update = Some(u);
        }
        let wire = wire_total / rounds as u64;
        let survivors = surv_total / rounds as u64;
        let residual_after = codec.residual_norm();

        // measured bytes == documented formulas on the last update
        let last = update.unwrap();
        match &last {
            ModelUpdate::Dense(_) => assert_eq!(last.wire_bytes(), dense_bytes),
            ModelUpdate::Delta(us) => {
                let formula: u64 = us
                    .iter()
                    .map(|u| match u {
                        TensorUpdate::Sparse(t) => sparse_tensor_bytes(t.nnz()),
                        TensorUpdate::Sign(t) => {
                            sign_tensor_bytes(t.elems as usize, t.nnz as usize)
                        }
                    })
                    .sum();
                assert_eq!(last.wire_bytes(), formula, "wire bytes drifted from formula");
            }
            ModelUpdate::Chain(_) => unreachable!("encode never emits chains"),
        }

        // EF stability: residual bounded by a few σ·√n after many rounds
        if mode != CommMode::Dense {
            let bound = 8.0 * 0.02 * (elems as f64).sqrt();
            assert!(
                residual_after < bound,
                "{mode:?}/{rate}: residual {residual_after} exceeded {bound}"
            );
        }

        let tag = match pruner {
            CommPruner::Stochastic => String::new(),
            CommPruner::TopK => "/topk".into(),
        };
        let s = bench(
            &format!("encode {}/{rate}{tag}", mode.as_str()),
            2,
            iters,
            Duration::from_secs(if short { 2 } else { 6 }),
            || {
                let mut c = DeltaCodec::with_pruner(mode, rate, pruner);
                std::hint::black_box(
                    c.encode(&local, &reference, &mut Rng::new(3)).unwrap(),
                );
            },
        );
        rep.row(vec![
            format!("{}/{rate}{tag}", mode.as_str()),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p95_ns),
            wire.to_string(),
            format!("{:.1}x", dense_bytes as f64 / wire as f64),
            survivors.to_string(),
        ]);
        if mode == CommMode::Pruned && rate == 0.9 {
            match pruner {
                CommPruner::Stochastic => pruned_stochastic_wire = wire,
                CommPruner::TopK => pruned_topk_wire = wire,
            }
        }

        // the headline asserts at the paper's operating point
        if rate == 0.9 {
            match mode {
                CommMode::Pruned => assert!(
                    wire < dense_bytes,
                    "pruned wire {wire} not below dense {dense_bytes}"
                ),
                CommMode::Sign => assert!(
                    wire * 5 <= dense_bytes,
                    "sign wire {wire} missed the 5x cut vs {dense_bytes}"
                ),
                CommMode::Dense => {}
            }
        }
    }

    // top-k sharpening (ROADMAP PR 3 follow-up): exact ⌈(1−P)·E⌉
    // survivors vs eq. 3's ≈46% promotion floor — at P=0.9 the pruned
    // format's wire must drop to well under half the stochastic row's
    println!(
        "pruned/0.9 wire: stochastic {pruned_stochastic_wire} B -> topk {pruned_topk_wire} B \
         ({:.1}x sharper)",
        pruned_stochastic_wire as f64 / pruned_topk_wire as f64
    );
    assert!(
        pruned_topk_wire * 2 <= pruned_stochastic_wire,
        "top-k failed to sharpen the pruned cut: {pruned_topk_wire} vs {pruned_stochastic_wire}"
    );

    // integrity envelope (docs/TRANSFER_MODEL.md §Integrity & recovery):
    // sealing a payload adds a flat FRAME_HEADER_BYTES of header —
    // magic, schema version, kind, length, FNV-1a checksum — so the
    // integrity tax per round is 24 B × frames, independent of P
    let payload = encode_update(&ModelUpdate::Dense(reference.clone()));
    let sealed = Frame::seal(FrameKind::Update, &payload);
    assert_eq!(
        sealed.wire_bytes(),
        payload.len() as u64 + FRAME_HEADER_BYTES,
        "envelope overhead drifted from the documented flat header"
    );
    assert!(sealed.open().is_ok(), "a clean seal must verify");
    rep.row(vec![
        "envelope/frame".into(),
        "-".into(),
        "-".into(),
        FRAME_HEADER_BYTES.to_string(),
        format!("{:.4}x", FRAME_HEADER_BYTES as f64 / dense_bytes as f64),
        "-".into(),
    ]);

    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("comm_bytes.csv"))
        .unwrap();
    rep.save_json(std::path::Path::new("BENCH_comm.json")).unwrap();
    println!("json -> BENCH_comm.json");
}
