//! Chaos soak: a federated run under a seeded [`FaultPlan`] firing every
//! fault class at once — uplink corruption, truncation, duplication,
//! reordering, and worker crashes — must *complete*, detect every
//! injected fault at the envelope (never applying a damaged frame), and
//! land within the pinned accuracy band of the clean twin run. A second
//! drill kills the coordinator mid-run and resumes it from the durable
//! run store, asserting the stitched trajectory reproduces the
//! uninterrupted one bit for bit.
//!
//! Skips politely without `make artifacts` (it drives real PJRT
//! workers). `EFFICIENTGRAD_BENCH_SHORT=1` shrinks the soak for CI.
//!
//!     cargo bench --bench chaos_soak

use efficientgrad::benchlib::Report;
use efficientgrad::config::{CommMode, FedConfig, TrainConfig};
use efficientgrad::coordinator::{FedSummary, Leader};
use efficientgrad::faults::FaultPlan;
use efficientgrad::manifest::Manifest;
use efficientgrad::runtime::Runtime;
use efficientgrad::tensor::Tensor;
use std::time::Instant;

fn soak_cfg(workers: usize, rounds: usize) -> FedConfig {
    FedConfig {
        workers,
        rounds,
        local_steps: 3,
        comm: CommMode::Pruned,
        train: TrainConfig {
            model: "convnet_t".into(),
            mode: "efficientgrad".into(),
            train_examples: 256,
            test_examples: 64,
            difficulty: 0.4,
            ..Default::default()
        },
        ..FedConfig::default()
    }
}

fn run(rt: &Runtime, m: &Manifest, cfg: FedConfig) -> (FedSummary, Vec<Tensor>, f64) {
    let t0 = Instant::now();
    let mut leader = Leader::new(rt, m, cfg).expect("leader construction");
    let summary = leader.run().expect("a faulted run must complete, not die");
    let params = leader.global_params().to_vec();
    leader.shutdown();
    (summary, params, t0.elapsed().as_secs_f64())
}

fn main() {
    let Ok(m) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        println!("SKIP: artifacts missing (run `make artifacts` first)");
        return;
    };
    let rt = Runtime::cpu().expect("CPU PJRT runtime");
    let short = std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some();
    let (workers, rounds) = if short { (3, 6) } else { (4, 10) };

    let mut rep = Report::new(
        "federated chaos soak (seeded FaultPlan, every class at once)",
        &[
            "run", "final acc", "mean loss", "net KB", "corrupt", "rejected", "retries",
            "dropped", "secs",
        ],
    );
    let mut row = |tag: &str, s: &FedSummary, secs: f64| {
        let net: u64 = s.rounds.iter().map(|r| r.network_bytes()).sum();
        rep.row(vec![
            tag.into(),
            format!("{:.4}", s.final_acc),
            format!("{:.4}", s.mean_round_loss()),
            format!("{:.1}", net as f64 / 1e3),
            s.rounds.iter().map(|r| r.corrupt_frames).sum::<usize>().to_string(),
            s.rounds.iter().map(|r| r.rejected_reports).sum::<usize>().to_string(),
            s.rounds.iter().map(|r| r.downlink_retries).sum::<usize>().to_string(),
            s.rounds.iter().map(|r| r.dropped.len()).sum::<usize>().to_string(),
            format!("{secs:.2}"),
        ]);
    };

    // the clean twin: same seeds, no plan
    let (clean, _, clean_secs) = run(&rt, &m, soak_cfg(workers, rounds));
    row("clean", &clean, clean_secs);

    // every fault class at once, heavily — the soak proper
    let mut chaos_cfg = soak_cfg(workers, rounds);
    chaos_cfg.faults = Some(
        "corrupt=0.25,truncate=0.15,dup=0.3,reorder=0.3,crash=0.2,seed=1234"
            .parse()
            .expect("chaos spec"),
    );
    let (chaos, _, chaos_secs) = run(&rt, &m, chaos_cfg);
    row("chaos", &chaos, chaos_secs);

    // the plan must actually have fired...
    let detected: usize = chaos
        .rounds
        .iter()
        .map(|r| r.corrupt_frames + r.downlink_retries + r.dropped.len())
        .sum();
    assert!(detected > 0, "chaos soak injected nothing (seed drift?)");
    // ...and every detection was contained: the run completed all its
    // rounds and stayed inside the accuracy band of the clean twin
    assert_eq!(chaos.rounds.len(), rounds, "the soak must run every round");
    assert!(
        (chaos.final_acc - clean.final_acc).abs() <= 0.25,
        "chaos final acc {} strayed from clean {} by more than 0.25",
        chaos.final_acc,
        clean.final_acc
    );

    // durability drill: kill the coordinator halfway, resume from the
    // run store, and pin the stitched run against the uninterrupted one
    let store = std::env::temp_dir().join(format!("effgrad_chaos_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store);
    let kill_at = rounds / 2;
    let mut killed_cfg = soak_cfg(workers, rounds);
    killed_cfg.run_store = Some(store.to_string_lossy().into_owned());
    killed_cfg.faults = Some(FaultPlan {
        kill_round: Some(kill_at),
        ..FaultPlan::default()
    });
    let (killed, _, killed_secs) = run(&rt, &m, killed_cfg);
    assert_eq!(killed.rounds.len(), kill_at + 1, "the kill must halt the run");
    row("kill", &killed, killed_secs);

    let mut resumed_cfg = soak_cfg(workers, rounds);
    resumed_cfg.run_store = Some(store.to_string_lossy().into_owned());
    resumed_cfg.resume = true;
    let (resumed, resumed_params, resumed_secs) = run(&rt, &m, resumed_cfg);
    row("resume", &resumed, resumed_secs);
    let _ = std::fs::remove_dir_all(&store);

    let (_, clean_params, _) = run(&rt, &m, soak_cfg(workers, rounds));
    assert_eq!(
        resumed_params, clean_params,
        "resume forked the trajectory from the uninterrupted run"
    );
    assert_eq!(
        resumed.final_acc.to_bits(),
        clean.final_acc.to_bits(),
        "resumed final acc {} != clean {}",
        resumed.final_acc,
        clean.final_acc
    );

    rep.print();
    rep.save_json(std::path::Path::new("BENCH_chaos.json")).unwrap();
    println!("json -> BENCH_chaos.json");
}
