//! Bench: regenerates **Fig. 1** — the throughput-vs-power hierarchy
//! scatter (literature devices + our simulated EfficientGrad/EyerissV2-BP
//! training points), and checks the paper's positioning claim: the
//! simulated EfficientGrad point must sit inside the edge power envelope
//! with the best GOP/s/W among the listed devices' *training* points.
//!
//!     cargo bench --bench fig1_hierarchy

use efficientgrad::accel::config::{efficientgrad, eyeriss_v2_bp};
use efficientgrad::accel::sim::simulate_training;
use efficientgrad::accel::workload::{fig1_devices, resnet18_cifar};
use efficientgrad::figures::fig1;
use efficientgrad::sparsity::expected_survivor_fraction;

fn main() {
    let rep = fig1::generate(0.9);
    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("fig1.csv"))
        .unwrap();

    // positioning claims
    let wl = resnet18_cifar(16);
    let surv = expected_survivor_fraction(0.9);
    let eg_cfg = efficientgrad();
    let eg = simulate_training(&eg_cfg, &wl, surv);
    let eg_power = eg.avg_power_w(&eg_cfg);
    let dense_gops = 2.0 * 3.0 * wl.fwd_macs() as f64 / eg.step_seconds() / 1e9;
    let eg_eff = dense_gops / eg_power;

    let bp_cfg = eyeriss_v2_bp();
    let bp = simulate_training(&bp_cfg, &wl, surv);
    let bp_eff =
        2.0 * 3.0 * wl.fwd_macs() as f64 / bp.step_seconds() / 1e9 / bp.avg_power_w(&bp_cfg);

    println!("\nclaims:");
    println!("  edge power envelope (< 2 W): EfficientGrad = {eg_power:.3} W -> {}", eg_power < 2.0);
    println!("  efficiency {eg_eff:.0} GOP/s/W vs EyerissV2-BP {bp_eff:.0} GOP/s/W");
    assert!(eg_power < 2.0, "outside edge envelope");
    assert!(eg_eff > bp_eff, "not more efficient than baseline");
    // and better GOP/s/W than every cloud/mobile device in the table
    for d in fig1_devices() {
        let dev_eff = d.gops / d.power_w;
        if d.class != "edge" {
            assert!(
                eg_eff > dev_eff,
                "{} has better efficiency ({dev_eff:.0}) than simulated EfficientGrad ({eg_eff:.0})",
                d.name
            );
        }
    }
    println!("  beats all non-edge devices on GOP/s/W: true");
    println!("\nFig. 1 OK");
}
