//! Bench: fleet scale — cohort-sampled federated rounds over 1k / 10k /
//! 100k in-process [`LiteWorker`]s, flat and two-tier aggregation.
//!
//! The fleet claim measured here: one process hosts 100k workers because
//! live O(model) state scales with the workers actually *sampled* (the
//! cohort), not the fleet size — unsampled workers hold an empty (or
//! `Arc`-shared) replica. Every round is protocol-real end to end:
//! sealed downlink [`Frame`]s, the worker-side open/validate/apply path,
//! error-feedback [`DeltaCodec`] uplinks, sealed report frames, and a
//! [`Hierarchy`] fold (edge aggregators absorbed into the root). Only
//! the training inside each worker is synthetic drift.
//!
//! Rows emitted (and merged into `BENCH_runtime.json`, which
//! `runtime_hotpath` SKIPs without artifacts — in CI this bench is the
//! file's writer):
//! * `fleet round` — mean round wall time + rounds/sec per (N, m, g),
//!   with the live-replica byte count in the state column;
//! * `fleet agg throughput` — reports/sec through accept+finish, flat vs
//!   two-tier;
//! * `fleet resync` — `Arc`-shared dense resyncs/sec across the whole
//!   fleet (one params allocation for all N workers).
//!
//! Asserts: every round folds exactly the cohort; live replicas stay
//! ≤ rounds·m (« N at 100k); a two-tier fold of a real cohort's reports
//! is bit-identical to the flat fold. No PJRT artifacts needed — this
//! bench always runs. `EFFICIENTGRAD_BENCH_SHORT=1` (CI) shrinks rounds
//! and iterations, same rows, same asserts.
//!
//!     cargo bench --bench fleet_scale

use std::sync::mpsc;
use std::time::Duration;

use efficientgrad::benchlib::{bench, fmt_ns, Report};
use efficientgrad::comm::envelope::encode_update;
use efficientgrad::comm::{Frame, FrameKind, ModelUpdate};
use efficientgrad::config::{CommMode, CommPruner};
use efficientgrad::coordinator::{CommSetup, Hierarchy, LiteWorker, Worker, WorkerReport, WorkerTask};
use efficientgrad::tensor::Tensor;
use efficientgrad::util::json::{arr, Json};
use efficientgrad::util::rng::Rng;

/// Model size per lite worker (one tensor, 4·P = 16 KB dense) — big
/// enough that an all-synced 100k fleet would need ~1.6 GB, so the
/// cohort-bounded live set is the only way the bench fits.
const P: usize = 4096;
const SEED: u64 = 42;
const HEADERS: [&str; 6] = ["op", "mean", "p50", "p95", "per-image µs", "state B/step"];

fn short_mode() -> bool {
    std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some()
}

fn comm() -> CommSetup {
    CommSetup {
        mode: CommMode::Pruned,
        rate: 0.1,
        pruner: CommPruner::Stochastic,
    }
}

fn initial_params() -> Vec<Tensor> {
    let mut rng = Rng::new(SEED);
    let mut data = vec![0f32; P];
    rng.fill_normal(&mut data, 0.5);
    vec![Tensor::new(vec![P], data)]
}

/// One protocol-real round: sample a cohort (the leader's `--sample-m`
/// draw, same dedicated stream), dense-downlink the head to each member
/// through a sealed frame, gather + decode the sealed reports, fold
/// through a `g`-edge [`Hierarchy`]. Returns (reports folded, tier
/// uplink bytes).
fn fleet_round(
    workers: &mut [LiteWorker],
    head: &mut Vec<Tensor>,
    round: usize,
    sample_rng: &mut Rng,
    m: usize,
    g: usize,
) -> (usize, u64) {
    let n = workers.len();
    let mut cohort: Vec<usize> = sample_rng
        .permutation(n)
        .into_iter()
        .take(m)
        .map(|i| i as usize)
        .collect();
    cohort.sort_unstable();
    // one seal per round; each task carries a cheap clone of the frame
    let frame = Frame::seal(FrameKind::Update, &encode_update(&ModelUpdate::Dense(head.clone())));
    let (tx, rx) = mpsc::channel();
    for &wid in &cohort {
        workers[wid]
            .submit(WorkerTask {
                round,
                version: round as u64 + 1,
                frame: frame.clone(),
                local_steps: 2,
                slowdown: 1.0,
                sleep: false,
                reply: tx.clone(),
            })
            .unwrap();
    }
    drop(tx);
    let mut h = Hierarchy::new(CommMode::Pruned, n, g);
    while let Ok((wid, f)) = rx.recv() {
        let (kind, payload) = f.open().unwrap();
        assert_eq!(kind, FrameKind::Report, "lite worker {wid} nacked");
        let r = WorkerReport::decode(payload).unwrap();
        assert_eq!(r.worker_id, wid);
        h.accept(r.base_version, r.worker_id, r.examples as f64, r.update)
            .unwrap();
    }
    let folded = h.accepted();
    let (params, stats) = h.finish(head).unwrap();
    if let Some(p) = params {
        *head = p;
    }
    (folded, stats.tier_upload_bytes)
}

/// Gather one real cohort's decoded reports, then fold them flat and
/// through 8 edges — the end-to-end twin of the `hierarchy` unit pin:
/// the bits must match on reports a live fleet actually produced.
fn parity_guard() {
    let n = 1_000;
    let m = 64;
    let mut workers: Vec<LiteWorker> = (0..n).map(|i| LiteWorker::new(i, SEED, comm())).collect();
    let head = initial_params();
    let frame = Frame::seal(FrameKind::Update, &encode_update(&ModelUpdate::Dense(head.clone())));
    let (tx, rx) = mpsc::channel();
    let mut sample_rng = Rng::new(SEED ^ 0xC0807);
    let cohort: Vec<usize> = sample_rng
        .permutation(n)
        .into_iter()
        .take(m)
        .map(|i| i as usize)
        .collect();
    for &wid in &cohort {
        workers[wid]
            .submit(WorkerTask {
                round: 0,
                version: 1,
                frame: frame.clone(),
                local_steps: 2,
                slowdown: 1.0,
                sleep: false,
                reply: tx.clone(),
            })
            .unwrap();
    }
    drop(tx);
    let mut reports = Vec::new();
    while let Ok((_, f)) = rx.recv() {
        let (_, payload) = f.open().unwrap();
        reports.push(WorkerReport::decode(payload).unwrap());
    }
    assert_eq!(reports.len(), m);
    let fold = |g: usize| {
        let mut h = Hierarchy::new(CommMode::Pruned, n, g);
        for r in &reports {
            h.accept(r.base_version, r.worker_id, r.examples as f64, r.update.clone())
                .unwrap();
        }
        h.finish(&head).unwrap().0.unwrap()
    };
    assert_eq!(fold(1), fold(8), "two-tier fold diverged from flat on live reports");
    println!("parity guard: 8-edge fold of {m} live reports == flat fold, bit for bit");
}

/// Merge this bench's rows into `BENCH_runtime.json`. `runtime_hotpath`
/// owns the file when artifacts exist (it rewrites it wholesale and runs
/// first); this bench appends — replacing any of its own rows from a
/// prior run — so both sets survive locally, and in artifact-less CI the
/// file still exists for upload.
fn save_merged(path: &std::path::Path, title: &str, rows: &[Vec<String>]) -> anyhow::Result<()> {
    let fresh_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::Obj(
                HEADERS
                    .iter()
                    .map(|h| h.to_string())
                    .zip(r.iter().map(|c| Json::Str(c.clone())))
                    .collect(),
            )
        })
        .collect();
    let merged = match std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok()) {
        Some(existing) => {
            let mut rows: Vec<Json> = existing
                .get("rows")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter(|row| {
                    !row.get("op")
                        .and_then(Json::as_str)
                        .is_some_and(|op| op.starts_with("fleet "))
                })
                .cloned()
                .collect();
            rows.extend(fresh_rows);
            let mut obj = std::collections::BTreeMap::new();
            obj.insert(
                "title".to_string(),
                existing.get("title").cloned().unwrap_or(Json::Str(title.to_string())),
            );
            obj.insert(
                "headers".to_string(),
                arr(HEADERS.iter().map(|h| Json::Str(h.to_string()))),
            );
            obj.insert("rows".to_string(), arr(rows));
            Json::Obj(obj)
        }
        None => {
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("title".to_string(), Json::Str(title.to_string()));
            obj.insert(
                "headers".to_string(),
                arr(HEADERS.iter().map(|h| Json::Str(h.to_string()))),
            );
            obj.insert("rows".to_string(), arr(fresh_rows));
            Json::Obj(obj)
        }
    };
    efficientgrad::util::fs::atomic_write(path, format!("{merged}\n").as_bytes())
}

fn main() {
    let short = short_mode();
    let rounds = if short { 2 } else { 5 };
    let title = "fleet scale (cohort-sampled rounds over LiteWorkers, flat vs two-tier)";
    let mut rep = Report::new(title, &HEADERS);
    let mut json_rows: Vec<Vec<String>> = Vec::new();
    let mut emit = |rep: &mut Report, rows: &mut Vec<Vec<String>>, row: Vec<String>| {
        rep.row(row.clone());
        rows.push(row);
    };

    parity_guard();

    // -- cohort-sampled rounds at fleet scale --
    for &n in &[1_000usize, 10_000, 100_000] {
        let tiers: &[usize] = if n == 100_000 { &[1, 16] } else { &[1] };
        for &g in tiers {
            let mut workers: Vec<LiteWorker> =
                (0..n).map(|i| LiteWorker::new(i, SEED, comm())).collect();
            let mut head = initial_params();
            let mut sample_rng = Rng::new(SEED ^ 0xC0807);
            let m = 256.min(n / 2);
            let mut round = 0usize;
            let mut tier_bytes = 0u64;
            let s = bench(
                &format!("fleet round: N={n} m={m} g={g}"),
                0,
                rounds,
                Duration::from_secs(60),
                || {
                    let (folded, tb) =
                        fleet_round(&mut workers, &mut head, round, &mut sample_rng, m, g);
                    assert_eq!(folded, m, "round folded {folded} of {m} cohort reports");
                    round += 1;
                    tier_bytes += tb;
                },
            );
            assert!(head[0].data().iter().all(|v| v.is_finite()));
            if g > 1 {
                assert!(tier_bytes > 0, "two-tier rounds must price edge uplinks");
            }
            // the memory-bound claim: live O(model) replicas are the
            // sampled set, not the fleet
            let live = workers.iter().filter(|w| w.synced()).count();
            assert!(
                live <= round * m,
                "{live} live replicas exceeds the {round}x{m} sampled bound"
            );
            if n == 100_000 {
                assert!(live * 10 < n, "live set {live} not « fleet {n}");
            }
            emit(
                &mut rep,
                &mut json_rows,
                vec![
                    format!("fleet round: N={n} m={m} g={g}"),
                    fmt_ns(s.mean_ns),
                    fmt_ns(s.p50_ns),
                    fmt_ns(s.p95_ns),
                    format!("{:.2} rounds/s", s.throughput(1.0)),
                    format!("{} live ({} B)", live, live * P * 4),
                ],
            );
            println!(
                "fleet N={n} m={m} g={g}: {:.2} rounds/s, {live} live replicas after {round} rounds",
                s.throughput(1.0)
            );
        }
    }

    // -- aggregator throughput: accept+finish over one cohort's reports,
    //    flat vs two-tier (same decoded updates each iteration) --
    {
        let n = 10_000;
        let m = 256;
        let mut workers: Vec<LiteWorker> =
            (0..n).map(|i| LiteWorker::new(i, SEED, comm())).collect();
        let head = initial_params();
        let frame =
            Frame::seal(FrameKind::Update, &encode_update(&ModelUpdate::Dense(head.clone())));
        let (tx, rx) = mpsc::channel();
        for wid in 0..m {
            workers[wid]
                .submit(WorkerTask {
                    round: 0,
                    version: 1,
                    frame: frame.clone(),
                    local_steps: 2,
                    slowdown: 1.0,
                    sleep: false,
                    reply: tx.clone(),
                })
                .unwrap();
        }
        drop(tx);
        let mut reports = Vec::new();
        while let Ok((_, f)) = rx.recv() {
            reports.push(WorkerReport::decode(f.open().unwrap().1).unwrap());
        }
        assert_eq!(reports.len(), m);
        let iters = if short { 3 } else { 10 };
        for g in [1usize, 16] {
            let s = bench(
                &format!("fleet agg throughput: m={m} g={g}"),
                1,
                iters,
                Duration::from_secs(30),
                || {
                    let mut h = Hierarchy::new(CommMode::Pruned, n, g);
                    for r in &reports {
                        h.accept(r.base_version, r.worker_id, r.examples as f64, r.update.clone())
                            .unwrap();
                    }
                    let (params, _) = h.finish(&head).unwrap();
                    std::hint::black_box(params);
                },
            );
            emit(
                &mut rep,
                &mut json_rows,
                vec![
                    format!("fleet agg throughput: m={m} g={g}"),
                    fmt_ns(s.mean_ns),
                    fmt_ns(s.p50_ns),
                    fmt_ns(s.p95_ns),
                    format!("{:.0} reports/s", s.throughput(m as f64)),
                    "-".into(),
                ],
            );
            println!("agg throughput m={m} g={g}: {:.0} reports/s", s.throughput(m as f64));
        }
    }

    // -- Arc-shared dense resync: the whole fleet lands on one version
    //    with ONE params allocation --
    {
        let n = 100_000;
        let mut workers: Vec<LiteWorker> =
            (0..n).map(|i| LiteWorker::new(i, SEED, comm())).collect();
        let cache = std::sync::Arc::new(initial_params());
        let s = bench(
            &format!("fleet resync (shared Arc): N={n}"),
            1,
            if short { 3 } else { 8 },
            Duration::from_secs(30),
            || {
                for w in workers.iter_mut() {
                    w.resync_shared(cache.clone());
                }
            },
        );
        assert_eq!(std::sync::Arc::strong_count(&cache), n + 1, "resync copied params");
        assert!(workers.iter().all(LiteWorker::synced));
        emit(
            &mut rep,
            &mut json_rows,
            vec![
                format!("fleet resync (shared Arc): N={n}"),
                fmt_ns(s.mean_ns),
                fmt_ns(s.p50_ns),
                fmt_ns(s.p95_ns),
                format!("{:.2e} workers/s", s.throughput(n as f64)),
                format!("{} B shared", P * 4),
            ],
        );
        println!("shared resync: {n} workers on one {}-byte replica", P * 4);
    }

    rep.print();
    save_merged(std::path::Path::new("BENCH_runtime.json"), title, &json_rows).unwrap();
    println!("json -> BENCH_runtime.json (merged)");
}
