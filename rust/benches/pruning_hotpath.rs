//! Bench: the gradient-pruning math on both sides of the stack —
//! (a) the Rust host-side mirror (used by the simulator, the comm codec
//! and verification) across tensor sizes, including the kernels the
//! federated leader now chunks across the scoped-thread pool
//! (`stochastic_prune_into_partitioned`, `std_dev`, `Tensor::axpy`),
//! and (b) the pruning threshold's effect measured through the real AOT
//! train step: efficientgrad's step vs signsym's (identical transport,
//! no pruning) vs bp. On CPU-XLA the pruned step is NOT expected to be
//! faster (dense kernels); the assertion is that the pruning overhead
//! is bounded — the *hardware* win is quantified by the fig5b simulator
//! bench.
//!
//! Host-kernel rows land in `BENCH_pruning.json` (tracked across PRs
//! next to `BENCH_runtime.json` / `BENCH_comm.json`); set
//! `EFFICIENTGRAD_BENCH_SHORT=1` (CI) for a reduced iteration budget —
//! same rows, same asserts.
//!
//!     cargo bench --bench pruning_hotpath

use std::time::Duration;

use efficientgrad::benchlib::{bench, fmt_ns, Report};
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{Runtime, TrainState};
use efficientgrad::sparsity;
use efficientgrad::tensor::Tensor;
use efficientgrad::util::rng::Rng;
use efficientgrad::util::stats::{std_dev, zero_fraction};

/// Reduced budget for CI (`EFFICIENTGRAD_BENCH_SHORT=1`).
fn short_mode() -> bool {
    std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some()
}

fn main() {
    let iters = if short_mode() { 8 } else { 20 };
    let budget = Duration::from_secs(if short_mode() { 2 } else { 5 });
    let mut rep = Report::new(
        "Host-side pruning mirror (eq. 3 + eq. 5) and leader hot kernels",
        &["kernel", "mean", "per-elem ns", "realized sparsity"],
    );
    let mut rng = Rng::new(0);
    let sizes: &[usize] = if short_mode() {
        &[1 << 12, 1 << 20]
    } else {
        &[1 << 12, 1 << 16, 1 << 20]
    };
    for &n in sizes {
        let mut delta = vec![0f32; n];
        rng.fill_normal(&mut delta, 0.02);
        let sigma = std_dev(&delta);
        let tau = sparsity::tau_from_rate(sigma, 0.9);
        // in-place variant: one buffer reused across iterations, so the
        // bench times the pruning math, not the allocator
        let mut out = vec![0f32; n];
        let s = bench(&format!("prune n={n}"), 2, iters, budget, || {
            let mut r = Rng::new(1);
            sparsity::stochastic_prune_into(&delta, tau, &mut r, &mut out);
        });
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            format!("{:.2}", s.mean_ns / n as f64),
            format!("{:.3}", zero_fraction(&out)),
        ]);

        // the deterministic-partition variant the comm codec runs: fixed
        // chunks, per-chunk RNG streams, chunks across the thread pool —
        // bit-identical output regardless of thread count
        let base = Rng::new(1);
        let s = bench(&format!("prune partitioned n={n}"), 2, iters, budget, || {
            sparsity::stochastic_prune_into_partitioned(&delta, tau, &base, &mut out);
        });
        let mut again = vec![0f32; n];
        sparsity::stochastic_prune_into_partitioned(&delta, tau, &base, &mut again);
        assert_eq!(out, again, "partitioned prune must be reproducible");
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            format!("{:.2}", s.mean_ns / n as f64),
            format!("{:.3}", zero_fraction(&out)),
        ]);
    }

    // the leader-fold kernels this PR chunks across the pool, at the
    // largest size (σ feeds eq. 5 on the codec path; axpy is the dense
    // FedAvg accumulate)
    let n = 1 << 20;
    let mut big = vec![0f32; n];
    rng.fill_normal(&mut big, 1.0);
    let s = bench("std_dev n=1048576 (chunked)", 2, iters, budget, || {
        std::hint::black_box(std_dev(&big));
    });
    rep.row(vec![
        s.name.clone(),
        fmt_ns(s.mean_ns),
        format!("{:.2}", s.mean_ns / n as f64),
        "-".into(),
    ]);
    let src = Tensor::new(vec![n], big.clone());
    let mut acc = Tensor::zeros(&[n]);
    let s = bench("tensor axpy n=1048576 (chunked)", 2, iters, budget, || {
        acc.axpy(0.5, &src);
    });
    rep.row(vec![
        s.name.clone(),
        fmt_ns(s.mean_ns),
        format!("{:.2}", s.mean_ns / n as f64),
        "-".into(),
    ]);

    // threshold math microbench
    let s = bench("tau_from_rate", 10, 1000, Duration::from_secs(2), || {
        std::hint::black_box(sparsity::tau_from_rate(0.02, 0.9));
    });
    rep.row(vec![s.name.clone(), fmt_ns(s.mean_ns), "-".into(), "-".into()]);
    println!("tau_from_rate (ndtri): {}", fmt_ns(s.mean_ns));

    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("pruning_hotpath.csv"))
        .unwrap();
    rep.save_json(std::path::Path::new("BENCH_pruning.json")).unwrap();
    println!("json -> BENCH_pruning.json");

    // through the real artifacts (skips without `make artifacts` — the
    // host-kernel rows above are already saved either way)
    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP artifact half: run `make artifacts`");
        return;
    };
    let rt = Runtime::cpu().expect("client");
    let model = manifest.model("convnet_s").unwrap();
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 0,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    let mut rep2 = Report::new(
        "Train-step latency by mode (convnet_s, CPU-XLA — see fig5b for the hardware claim)",
        &["mode", "mean", "p95"],
    );
    let step_iters = if short_mode() { 8 } else { 25 };
    let step_budget = Duration::from_secs(if short_mode() { 5 } else { 12 });
    let mut eg_mean = 0.0;
    let mut ss_mean = 0.0;
    for mode in ["bp", "signsym", "efficientgrad"] {
        let state = TrainState::new(
            rt.load(model.artifact(&format!("train_{mode}")).unwrap()).unwrap(),
            model,
        )
        .unwrap();
        let mut store = ParamStore::init(model, 2);
        let s = bench(mode, 3, step_iters, step_budget, || {
            state.step(&mut store, &batch, 0.05, 0.9).unwrap();
        });
        if mode == "efficientgrad" {
            eg_mean = s.mean_ns;
        }
        if mode == "signsym" {
            ss_mean = s.mean_ns;
        }
        rep2.row(vec![mode.into(), fmt_ns(s.mean_ns), fmt_ns(s.p95_ns)]);
    }
    rep2.print();
    let overhead = eg_mean / ss_mean;
    println!("pruning overhead on CPU-XLA: {overhead:.2}x signsym (bounded < 2x expected)");
    assert!(overhead < 2.5, "pruning overhead exploded: {overhead}");
}
