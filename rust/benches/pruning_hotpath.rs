//! Bench: the gradient-pruning math on both sides of the stack —
//! (a) the Rust host-side mirror (used by the simulator + verification)
//! across tensor sizes, and (b) the pruning threshold's effect measured
//! through the real AOT train step: efficientgrad's step vs signsym's
//! (identical transport, no pruning) vs bp. On CPU-XLA the pruned step is
//! NOT expected to be faster (dense kernels); the assertion is that the
//! pruning overhead is bounded — the *hardware* win is quantified by the
//! fig5b simulator bench.
//!
//!     cargo bench --bench pruning_hotpath

use std::time::Duration;

use efficientgrad::benchlib::{bench, fmt_ns, Report};
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{Runtime, TrainState};
use efficientgrad::sparsity;
use efficientgrad::util::rng::Rng;

fn main() {
    let mut rep = Report::new(
        "Host-side pruning mirror (eq. 3 + eq. 5)",
        &["n elements", "mean", "per-elem ns", "realized sparsity"],
    );
    let mut rng = Rng::new(0);
    for n in [1 << 12, 1 << 16, 1 << 20] {
        let mut delta = vec![0f32; n];
        rng.fill_normal(&mut delta, 0.02);
        let sigma = efficientgrad::util::stats::std_dev(&delta);
        let tau = sparsity::tau_from_rate(sigma, 0.9);
        // in-place variant: one buffer reused across iterations, so the
        // bench times the pruning math, not the allocator
        let mut out = vec![0f32; n];
        let s = bench(
            &format!("prune n={n}"),
            2,
            20,
            Duration::from_secs(5),
            || {
                let mut r = Rng::new(1);
                sparsity::stochastic_prune_into(&delta, tau, &mut r, &mut out);
            },
        );
        rep.row(vec![
            n.to_string(),
            fmt_ns(s.mean_ns),
            format!("{:.2}", s.mean_ns / n as f64),
            format!("{:.3}", efficientgrad::util::stats::zero_fraction(&out)),
        ]);
    }
    rep.print();

    // threshold math microbench
    let s = bench("tau_from_rate", 10, 1000, Duration::from_secs(2), || {
        std::hint::black_box(sparsity::tau_from_rate(0.02, 0.9));
    });
    println!("tau_from_rate (ndtri): {}", fmt_ns(s.mean_ns));

    // through the real artifacts
    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP artifact half: run `make artifacts`");
        return;
    };
    let rt = Runtime::cpu().expect("client");
    let model = manifest.model("convnet_s").unwrap();
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 0,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    let mut rep2 = Report::new(
        "Train-step latency by mode (convnet_s, CPU-XLA — see fig5b for the hardware claim)",
        &["mode", "mean", "p95"],
    );
    let mut eg_mean = 0.0;
    let mut ss_mean = 0.0;
    for mode in ["bp", "signsym", "efficientgrad"] {
        let state = TrainState::new(
            rt.load(model.artifact(&format!("train_{mode}")).unwrap()).unwrap(),
            model,
        )
        .unwrap();
        let mut store = ParamStore::init(model, 2);
        let s = bench(mode, 3, 25, Duration::from_secs(12), || {
            state.step(&mut store, &batch, 0.05, 0.9).unwrap();
        });
        if mode == "efficientgrad" {
            eg_mean = s.mean_ns;
        }
        if mode == "signsym" {
            ss_mean = s.mean_ns;
        }
        rep2.row(vec![mode.into(), fmt_ns(s.mean_ns), fmt_ns(s.p95_ns)]);
    }
    rep2.print();
    let overhead = eg_mean / ss_mean;
    println!("pruning overhead on CPU-XLA: {overhead:.2}x signsym (bounded < 2x expected)");
    assert!(overhead < 2.5, "pruning overhead exploded: {overhead}");
}
