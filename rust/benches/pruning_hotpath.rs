//! Bench: the gradient-pruning math on both sides of the stack —
//! (a) the Rust host-side mirror (used by the simulator, the comm codec
//! and verification) across tensor sizes, including the kernels the
//! federated leader now chunks across the scoped-thread pool
//! (`stochastic_prune_into_partitioned`, `std_dev`, `Tensor::axpy`),
//! and (b) the pruning threshold's effect measured through the real AOT
//! train step: efficientgrad's step vs signsym's (identical transport,
//! no pruning) vs bp. On CPU-XLA the pruned step is NOT expected to be
//! faster (dense kernels); the assertion is that the pruning overhead
//! is bounded — the *hardware* win is quantified by the fig5b simulator
//! bench.
//!
//! Host-kernel rows land in `BENCH_pruning.json` (tracked across PRs
//! next to `BENCH_runtime.json` / `BENCH_comm.json`); set
//! `EFFICIENTGRAD_BENCH_SHORT=1` (CI) for a reduced iteration budget —
//! same rows, same asserts.
//!
//!     cargo bench --bench pruning_hotpath

use std::time::Duration;

use efficientgrad::benchlib::{bench, fmt_ns, Report, Sample};
use efficientgrad::comm::{SignTensor, SparseTensor, TensorUpdate};
use efficientgrad::data::synthetic::{generate, SynthConfig};
use efficientgrad::manifest::Manifest;
use efficientgrad::params::ParamStore;
use efficientgrad::runtime::{Runtime, TrainState};
use efficientgrad::sparsity;
use efficientgrad::tensor::Tensor;
use efficientgrad::util::rng::Rng;
use efficientgrad::util::simd;
use efficientgrad::util::stats::{std_dev, zero_fraction};

/// Reduced budget for CI (`EFFICIENTGRAD_BENCH_SHORT=1`).
fn short_mode() -> bool {
    std::env::var_os("EFFICIENTGRAD_BENCH_SHORT").is_some()
}

/// Time one kernel down both dispatch paths: scalar oracle first
/// (force flag on), then whatever `simd::active()` selects. Without the
/// `simd` feature (or on a host without AVX2) both columns time the
/// same scalar code — the matrix says so in its title.
fn matrix_pair<F: FnMut()>(
    name: &str,
    iters: usize,
    budget: Duration,
    mut f: F,
) -> (Sample, Sample) {
    simd::force_scalar(true);
    let s = bench(&format!("{name} [scalar]"), 2, iters, budget, &mut f);
    simd::force_scalar(false);
    let v = bench(&format!("{name} [simd]"), 2, iters, budget, &mut f);
    (s, v)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Append the kernel-matrix rows (their own header set) to the JSON
/// report `save_json` just wrote, keeping the host-kernel rows — same
/// merge idiom as `fleet_scale`'s `BENCH_runtime.json` rows.
fn merge_rows_into_json(
    path: &std::path::Path,
    headers: &[&str],
    rows: &[Vec<String>],
) -> anyhow::Result<()> {
    use efficientgrad::util::json::{arr, Json};
    let text = std::fs::read_to_string(path)?;
    let existing = Json::parse(&text)?;
    let mut out_rows: Vec<Json> = existing
        .get("rows")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .to_vec();
    out_rows.extend(rows.iter().map(|r| {
        Json::Obj(
            headers
                .iter()
                .map(|h| h.to_string())
                .zip(r.iter().map(|c| Json::Str(c.clone())))
                .collect(),
        )
    }));
    let mut o = std::collections::BTreeMap::new();
    for key in ["title", "headers"] {
        if let Some(v) = existing.get(key) {
            o.insert(key.to_string(), v.clone());
        }
    }
    o.insert("rows".to_string(), arr(out_rows));
    efficientgrad::util::fs::atomic_write(path, format!("{}\n", Json::Obj(o)).as_bytes())
}

fn main() {
    let iters = if short_mode() { 8 } else { 20 };
    let budget = Duration::from_secs(if short_mode() { 2 } else { 5 });
    let mut rep = Report::new(
        "Host-side pruning mirror (eq. 3 + eq. 5) and leader hot kernels",
        &["kernel", "mean", "per-elem ns", "realized sparsity"],
    );
    let mut rng = Rng::new(0);
    let sizes: &[usize] = if short_mode() {
        &[1 << 12, 1 << 20]
    } else {
        &[1 << 12, 1 << 16, 1 << 20]
    };
    for &n in sizes {
        let mut delta = vec![0f32; n];
        rng.fill_normal(&mut delta, 0.02);
        let sigma = std_dev(&delta);
        let tau = sparsity::tau_from_rate(sigma, 0.9);
        // in-place variant: one buffer reused across iterations, so the
        // bench times the pruning math, not the allocator
        let mut out = vec![0f32; n];
        let s = bench(&format!("prune n={n}"), 2, iters, budget, || {
            let mut r = Rng::new(1);
            sparsity::stochastic_prune_into(&delta, tau, &mut r, &mut out);
        });
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            format!("{:.2}", s.mean_ns / n as f64),
            format!("{:.3}", zero_fraction(&out)),
        ]);

        // the deterministic-partition variant the comm codec runs: fixed
        // chunks, per-chunk RNG streams, chunks across the thread pool —
        // bit-identical output regardless of thread count
        let base = Rng::new(1);
        let s = bench(&format!("prune partitioned n={n}"), 2, iters, budget, || {
            sparsity::stochastic_prune_into_partitioned(&delta, tau, &base, &mut out);
        });
        let mut again = vec![0f32; n];
        sparsity::stochastic_prune_into_partitioned(&delta, tau, &base, &mut again);
        assert_eq!(out, again, "partitioned prune must be reproducible");
        rep.row(vec![
            s.name.clone(),
            fmt_ns(s.mean_ns),
            format!("{:.2}", s.mean_ns / n as f64),
            format!("{:.3}", zero_fraction(&out)),
        ]);
    }

    // the leader-fold kernels this PR chunks across the pool, at the
    // largest size (σ feeds eq. 5 on the codec path; axpy is the dense
    // FedAvg accumulate)
    let n = 1 << 20;
    let mut big = vec![0f32; n];
    rng.fill_normal(&mut big, 1.0);
    let s = bench("std_dev n=1048576 (chunked)", 2, iters, budget, || {
        std::hint::black_box(std_dev(&big));
    });
    rep.row(vec![
        s.name.clone(),
        fmt_ns(s.mean_ns),
        format!("{:.2}", s.mean_ns / n as f64),
        "-".into(),
    ]);
    let src = Tensor::new(vec![n], big.clone());
    let mut acc = Tensor::zeros(&[n]);
    let s = bench("tensor axpy n=1048576 (chunked)", 2, iters, budget, || {
        acc.axpy(0.5, &src);
    });
    rep.row(vec![
        s.name.clone(),
        fmt_ns(s.mean_ns),
        format!("{:.2}", s.mean_ns / n as f64),
        "-".into(),
    ]);

    // threshold math microbench
    let s = bench("tau_from_rate", 10, 1000, Duration::from_secs(2), || {
        std::hint::black_box(sparsity::tau_from_rate(0.02, 0.9));
    });
    rep.row(vec![s.name.clone(), fmt_ns(s.mean_ns), "-".into(), "-".into()]);
    println!("tau_from_rate (ndtri): {}", fmt_ns(s.mean_ns));

    rep.print();
    rep.save_csv(&efficientgrad::figures::reports_dir().join("pruning_hotpath.csv"))
        .unwrap();
    rep.save_json(std::path::Path::new("BENCH_pruning.json")).unwrap();
    println!("json -> BENCH_pruning.json");

    // ------------------------------------------------------------------
    // SIMD kernel matrix: scalar vs vectorized columns at n = one
    // `util::par` CHUNK (1<<16), the inline no-thread-spawn path, so the
    // columns time the kernel and nothing else. Outputs are asserted
    // bit-identical before anything is trusted, and with the feature
    // active the three tentpole kernels must clear the 2x elements/sec
    // floor — asserted, not just printed.
    // ------------------------------------------------------------------
    let kn = 1 << 16;
    let simd_on = cfg!(feature = "simd") && simd::available();
    let mut kd = vec![0f32; kn];
    rng.fill_normal(&mut kd, 0.02);
    let ktau = sparsity::tau_from_rate(std_dev(&kd), 0.9);
    let kbase = Rng::new(5);
    let mut kpruned = vec![0f32; kn];
    sparsity::stochastic_prune_into_partitioned(&kd, ktau, &kbase, &mut kpruned);
    let kup = TensorUpdate::Sign(SignTensor::encode(&kpruned));

    // parity gate: both dispatch paths must agree bit for bit on every
    // kernel the matrix times (the e2e twin pin lives in tests/federated)
    let ksp = SparseTensor::encode(&kpruned); // survivor values the v2 quantizer runs over
    let (klo, khi) = simd::minmax(&ksp.values);
    let kscale8 = (khi - klo) / 255.0;
    let kscale4 = (khi - klo) / 15.0;
    {
        let run = |force: bool| {
            simd::force_scalar(force);
            let mut ax = kd.clone();
            simd::axpy(&mut ax, 0.5, &kpruned);
            let mut pr = vec![0f32; kn];
            sparsity::stochastic_prune_into_partitioned(&kd, ktau, &kbase, &mut pr);
            let enc = SignTensor::encode(&pr);
            let mut acc = vec![0f64; kn];
            kup.axpy_into_f64(0.25, &mut acc);
            let mut dec = vec![0f32; kn];
            kup.decode_into(&mut dec);
            let mm = simd::minmax(&ksp.values);
            let mut q8 = Vec::new();
            simd::quantize_q8_into(&ksp.values, klo, kscale8, &mut q8);
            let mut dq8 = Vec::new();
            simd::dequantize_q8_into(&q8, ksp.values.len(), klo, kscale8, &mut dq8);
            let mut q4 = Vec::new();
            simd::quantize_q4_into(&ksp.values, klo, kscale4, &mut q4);
            let mut dq4 = Vec::new();
            simd::dequantize_q4_into(&q4, ksp.values.len(), klo, kscale4, &mut dq4);
            simd::force_scalar(false);
            (bits(&ax), bits(&pr), enc, acc, bits(&dec), (mm, q8, bits(&dq8), q4, bits(&dq4)))
        };
        let (ax_s, pr_s, enc_s, acc_s, dec_s, qt_s) = run(true);
        let (ax_v, pr_v, enc_v, acc_v, dec_v, qt_v) = run(false);
        assert_eq!(ax_s, ax_v, "axpy: scalar and simd paths disagree");
        assert_eq!(pr_s, pr_v, "threshold pass: scalar and simd paths disagree");
        assert_eq!(
            (qt_s.0 .0.to_bits(), qt_s.0 .1.to_bits()),
            (qt_v.0 .0.to_bits(), qt_v.0 .1.to_bits()),
            "minmax: scalar and simd paths disagree"
        );
        assert_eq!(qt_s.1, qt_v.1, "quantize q8: scalar and simd paths disagree");
        assert_eq!(qt_s.2, qt_v.2, "dequantize q8: scalar and simd paths disagree");
        assert_eq!(qt_s.3, qt_v.3, "quantize q4: scalar and simd paths disagree");
        assert_eq!(qt_s.4, qt_v.4, "dequantize q4: scalar and simd paths disagree");
        assert_eq!(
            (&enc_s.presence, &enc_s.signs, enc_s.nnz, enc_s.magnitude.to_bits()),
            (&enc_v.presence, &enc_v.signs, enc_v.nnz, enc_v.magnitude.to_bits()),
            "sign encode: scalar and simd paths disagree"
        );
        let acc_s: Vec<u64> = acc_s.iter().map(|x| x.to_bits()).collect();
        let acc_v: Vec<u64> = acc_v.iter().map(|x| x.to_bits()).collect();
        assert_eq!(acc_s, acc_v, "sign fold axpy f64: scalar and simd paths disagree");
        assert_eq!(dec_s, dec_v, "sign decode: scalar and simd paths disagree");
        println!("kernel matrix parity: scalar == simd bit for bit on all timed kernels");
    }

    const MATRIX_HEADERS: [&str; 6] =
        ["kernel", "scalar", "simd", "scalar Melem/s", "simd Melem/s", "speedup"];
    let mut matrix = Report::new(
        &format!(
            "SIMD kernel matrix, n={kn} ({})",
            if simd_on { "simd active" } else { "simd unavailable: both columns scalar" }
        ),
        &MATRIX_HEADERS,
    );
    let mut matrix_rows: Vec<Vec<String>> = Vec::new();
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    {
        // `ne` is the element count the kernel actually touches (dense
        // kernels: kn; the v2 quantizer: the survivor count)
        let mut emit = |name: &str, s: &Sample, v: &Sample, ne: f64| {
            let speedup = s.mean_ns / v.mean_ns;
            let row = vec![
                format!("matrix {name}"),
                fmt_ns(s.mean_ns),
                fmt_ns(v.mean_ns),
                format!("{:.0}", s.throughput(ne) / 1e6),
                format!("{:.0}", v.throughput(ne) / 1e6),
                format!("{speedup:.2}x"),
            ];
            matrix.row(row.clone());
            matrix_rows.push(row);
            speedup
        };

        // dense f32 axpy: memory-bound and already autovectorized by the
        // compiler on the scalar path — a column for honesty, no floor
        let mut dst = kd.clone();
        let (s, v) = matrix_pair("axpy f32", iters, budget, || {
            simd::axpy(&mut dst, 0.5, &kpruned);
        });
        emit("axpy f32 (dense)", &s, &v, kn as f64);

        // the leader's O(nnz) fold of a sign update into the f64
        // accumulator — the per-worker per-round aggregation kernel
        let mut acc = vec![0f64; kn];
        let (s, v) = matrix_pair("fold axpy sign->f64", iters, budget, || {
            kup.axpy_into_f64(0.25, &mut acc);
        });
        speedups.push(("fold axpy sign->f64", emit("fold axpy (sign->f64)", &s, &v, kn as f64)));

        // eq. 3 threshold/survivor-select pass, the codec's per-tensor
        // prune (deterministic partitioned variant)
        let mut out = vec![0f32; kn];
        let (s, v) = matrix_pair("threshold pass", iters, budget, || {
            sparsity::stochastic_prune_into_partitioned(&kd, ktau, &kbase, &mut out);
        });
        speedups.push(("threshold pass", emit("threshold pass (eq. 3 partitioned)", &s, &v, kn as f64)));

        // sign bit-plane encode: word-at-a-time movemask pack vs the old
        // per-element bit pushes
        let (s, v) = matrix_pair("sign encode", iters, budget, || {
            std::hint::black_box(SignTensor::encode(&kpruned));
        });
        speedups.push(("sign encode", emit("sign encode (bit-planes)", &s, &v, kn as f64)));

        // sign bit-plane decode into a dense buffer (no floor: the
        // scalar walk is already cheap next to the encode)
        let mut dec = vec![0f32; kn];
        let (s, v) = matrix_pair("sign decode", iters, budget, || {
            kup.decode_into(&mut dec);
        });
        emit("sign decode (bit-planes)", &s, &v, kn as f64);

        // the wire-v2 quantizer over the survivor values (codes packed
        // 4/word at q8, 8/word at q4) and its decode-side inverse — the
        // kernels `QuantTensor::{from_survivors, dequantize_values}`
        // dispatch (no floor: survivor buffers are small next to the
        // dense kernels, the e2e win is bytes, not nanoseconds)
        let knnz = ksp.values.len() as f64;
        let mut qc = Vec::new();
        let (s, v) = matrix_pair("quantize q8", iters, budget, || {
            simd::quantize_q8_into(&ksp.values, klo, kscale8, &mut qc);
        });
        emit("quantize q8 (affine pack)", &s, &v, knnz);
        let mut dq = Vec::new();
        let (s, v) = matrix_pair("dequantize q8", iters, budget, || {
            simd::dequantize_q8_into(&qc, ksp.values.len(), klo, kscale8, &mut dq);
        });
        emit("dequantize q8 (unpack)", &s, &v, knnz);
        let mut qc4 = Vec::new();
        let (s, v) = matrix_pair("quantize q4", iters, budget, || {
            simd::quantize_q4_into(&ksp.values, klo, kscale4, &mut qc4);
        });
        emit("quantize q4 (affine pack)", &s, &v, knnz);
        let (s, v) = matrix_pair("dequantize q4", iters, budget, || {
            simd::dequantize_q4_into(&qc4, ksp.values.len(), klo, kscale4, &mut dq);
        });
        emit("dequantize q4 (unpack)", &s, &v, knnz);
    }
    matrix.print();
    matrix
        .save_csv(&efficientgrad::figures::reports_dir().join("pruning_kernel_matrix.csv"))
        .unwrap();
    merge_rows_into_json(std::path::Path::new("BENCH_pruning.json"), &MATRIX_HEADERS, &matrix_rows)
        .unwrap();
    println!("json -> BENCH_pruning.json (kernel matrix merged)");

    // the acceptance floor: with the feature compiled in and the host
    // able to run it, the tentpole kernels must be >= 2x elements/sec
    if simd_on {
        for (name, speedup) in &speedups {
            assert!(
                *speedup >= 2.0,
                "{name}: simd speedup {speedup:.2}x below the 2x acceptance floor"
            );
        }
        println!("simd acceptance floor: all three tentpole kernels >= 2x");
    } else {
        println!("simd inactive: kernel matrix recorded, 2x floor not enforced");
    }

    // through the real artifacts (skips without `make artifacts` — the
    // host-kernel rows above are already saved either way)
    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP artifact half: run `make artifacts`");
        return;
    };
    let rt = Runtime::cpu().expect("client");
    let model = manifest.model("convnet_s").unwrap();
    let ds = generate(&SynthConfig {
        n: model.batch,
        seed: 0,
        ..Default::default()
    });
    let batch = ds.gather(&(0..model.batch as u32).collect::<Vec<_>>());
    let mut rep2 = Report::new(
        "Train-step latency by mode (convnet_s, CPU-XLA — see fig5b for the hardware claim)",
        &["mode", "mean", "p95"],
    );
    let step_iters = if short_mode() { 8 } else { 25 };
    let step_budget = Duration::from_secs(if short_mode() { 5 } else { 12 });
    let mut eg_mean = 0.0;
    let mut ss_mean = 0.0;
    for mode in ["bp", "signsym", "efficientgrad"] {
        let state = TrainState::new(
            rt.load(model.artifact(&format!("train_{mode}")).unwrap()).unwrap(),
            model,
        )
        .unwrap();
        let mut store = ParamStore::init(model, 2);
        let s = bench(mode, 3, step_iters, step_budget, || {
            state.step(&mut store, &batch, 0.05, 0.9).unwrap();
        });
        if mode == "efficientgrad" {
            eg_mean = s.mean_ns;
        }
        if mode == "signsym" {
            ss_mean = s.mean_ns;
        }
        rep2.row(vec![mode.into(), fmt_ns(s.mean_ns), fmt_ns(s.p95_ns)]);
    }
    rep2.print();
    let overhead = eg_mean / ss_mean;
    println!("pruning overhead on CPU-XLA: {overhead:.2}x signsym (bounded < 2x expected)");
    assert!(overhead < 2.5, "pruning overhead exploded: {overhead}");
}
