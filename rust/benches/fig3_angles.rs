//! Bench: regenerates **Fig. 3a** (error-gradient histogram) and
//! **Fig. 3b** (BP-vs-EfficientGrad gradient angles over training) from a
//! real training run through the AOT artifacts, then asserts the paper's
//! qualitative claims: every angle < 90°, the fc classifier best-aligned,
//! and a zero-centered long-tailed gradient distribution.
//!
//! Budget knobs: FIG3_STEPS (default 80), FIG3_MODEL (default convnet_t).
//!
//!     cargo bench --bench fig3_angles

use efficientgrad::figures::fig3;
use efficientgrad::manifest::Manifest;
use efficientgrad::runtime::Runtime;

fn main() {
    let steps: usize = std::env::var("FIG3_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(80);
    let model = std::env::var("FIG3_MODEL").unwrap_or_else(|_| "convnet_t".into());

    let Ok(manifest) = Manifest::load(&efficientgrad::artifacts_dir()) else {
        eprintln!("SKIP fig3: artifacts missing (run `make artifacts`)");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT client");
    let t0 = std::time::Instant::now();
    let out = fig3::generate(&rt, &manifest, &model, steps, (steps / 8).max(1))
        .expect("fig3 generation");
    println!(
        "generated fig3 from a {steps}-step live run in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    out.angles.print();
    let dir = efficientgrad::figures::reports_dir();
    out.angles.save_csv(&dir.join("fig3b_angles.csv")).unwrap();
    out.hist.save_csv(&dir.join("fig3a_hist.csv")).unwrap();
    println!("fig3a histogram rows -> {}", dir.join("fig3a_hist.csv").display());
}
