//! Federated communication: pruned, sign-compressed model deltas.
//!
//! PRs 1–2 reduced the host↔device bus to scalars per step; after that,
//! the dominant byte mover in the federated deployment is the *network*
//! tier — the per-round exchange of dense fp32 models between leader and
//! workers. This module applies the paper's own compression math to that
//! exchange:
//!
//! * Workers ship **deltas** (`local − broadcast`), not snapshots.
//! * Deltas are pruned with eq. 3 (the deterministic-partition variant
//!   `sparsity::stochastic_prune_into_partitioned` — chunk-parallel, τ
//!   from eq. 5 at the tensor's measured σ) under an **error-feedback
//!   residual** ([`DeltaCodec`]) so pruned mass is carried into the next
//!   round instead of lost — the compressed run tracks the dense run's
//!   accuracy.
//! * Survivors travel in a compact wire format ([`wire`]): u32 indices +
//!   f32 values (`pruned`), or — mirroring the paper's sign-symmetric
//!   trick — a presence bitmap + one sign bit per survivor + a shared
//!   per-tensor magnitude (`sign`), which is where the ≥10× cut lives.
//! * The leader never materializes per-worker dense tensors: FedAvg
//!   grows a sparse-accumulate path
//!   ([`crate::coordinator::weighted_sparse_fedavg`] over
//!   [`crate::tensor::Tensor::axpy_sparse`]) folding each delta into the
//!   global params in O(nnz), and the downlink broadcasts the global
//!   delta through the same codec. The first round falls back to a dense
//!   snapshot; a worker that missed `k ≤ federated.max_chain` downlinks
//!   is resynced with the **chain** of the retained per-round deltas
//!   ([`ModelUpdate::Chain`] — bit-identical to catching every round,
//!   `8 + Σ link` bytes instead of dense `4·P`), dense only beyond the
//!   retained window.
//! * Survivor selection is pluggable ([`crate::config::CommPruner`]):
//!   eq. 3 stochastic promotion (default, unbiased, ≈46% survivors at
//!   P=0.9) or exact top-k by |δ| (`topk` — exactly `1−P` survivors,
//!   bias carried by the error-feedback residual).
//!
//! The motivation tracks the sparse-feedback / local-learning line
//! (Crafton et al., arXiv:1903.02083) and communication-bound edge-
//! cluster training (Rama et al., arXiv:2409.09083): both identify the
//! dense update exchange as the scaling bottleneck. Byte formulas are
//! normative in `docs/TRANSFER_MODEL.md` §Network tier, doc-tested in
//! [`wire`], and asserted against the measured per-round ledger by
//! `cargo bench --bench runtime_hotpath` and `--bench comm_bytes`.

//! PR 6 adds the byte layer under the structs: every update/report is
//! sealed into an integrity-checked [`envelope::Frame`] (24-byte header:
//! magic, schema version, kind, length, FNV-1a-64 checksum) at the
//! channel boundary, so corruption injected by [`crate::faults`] — or a
//! real flaky link, once a socket transport lands — is detected and
//! rejected, never folded.

pub mod codec;
pub mod envelope;
pub mod wire;

pub use codec::DeltaCodec;
pub use envelope::{Frame, FrameKind};
pub use wire::{ModelUpdate, QuantBits, QuantTensor, SignTensor, SparseTensor, TensorUpdate};
