//! Wire formats for federated model exchange.
//!
//! Three encodings, one per [`crate::config::CommMode`]:
//!
//! * **dense** — the legacy format: every f32 of every param tensor,
//!   `4·P` bytes. No header (matches the pre-comm accounting exactly).
//! * **sparse** — pruned-delta survivors as `u32` element offsets +
//!   `f32` values: `8 + 8·nnz` bytes per tensor.
//! * **sign** — the paper's sign-symmetric trick applied to the wire:
//!   a presence bitmap over all elements (1 bit each), one sign bit per
//!   survivor, and a single shared per-tensor magnitude:
//!   `12 + 4·⌈E/32⌉ + 4·⌈nnz/32⌉` bytes per tensor. This is the format
//!   that survives eq. 3's stochastic promotion: promoted survivors all
//!   sit at `±τ`, so a shared magnitude loses almost nothing while the
//!   per-survivor cost drops from 8 bytes to ~1.25 bits + amortized
//!   bitmap.
//!
//! The byte functions below are the *normative* size model
//! (`docs/TRANSFER_MODEL.md` §Network tier); `wire_bytes()` on the
//! structs computes sizes through them, so the ledger the federated
//! leader reports is the documented formula by construction, and the
//! doc-tests pin the arithmetic.
//!
//! Workers are threads in this simulation, so updates travel as these
//! structs rather than a byte stream — but the bitmaps and sign planes
//! are genuinely bit-packed (`Vec<u32>` words), and encode/decode are
//! real, round-trip-tested transforms, so `wire_bytes()` is what a
//! serialized message would actually cost.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Per-tensor header of the sparse format: element count + nnz (u32 each).
pub const SPARSE_TENSOR_HEADER_BYTES: u64 = 8;

/// Per-tensor header of the sign format: element count + nnz (u32 each)
/// + the shared f32 magnitude.
pub const SIGN_TENSOR_HEADER_BYTES: u64 = 12;

/// Per-message header of a chained downlink: base version + link count
/// (u32 each). The links themselves are ordinary per-round delta
/// payloads, so a chain costs exactly the header plus what the receiver
/// would have paid had it caught every round's downlink individually.
pub const CHAIN_HEADER_BYTES: u64 = 8;

/// Wire bytes of one dense f32 tensor: `4·E`.
///
/// ```
/// use efficientgrad::comm::wire::dense_tensor_bytes;
/// assert_eq!(dense_tensor_bytes(42_000), 168_000);
/// assert_eq!(dense_tensor_bytes(0), 0);
/// ```
pub fn dense_tensor_bytes(elems: usize) -> u64 {
    4 * elems as u64
}

/// Wire bytes of one sparse tensor: `8 + 8·nnz` (header + u32 index +
/// f32 value per survivor).
///
/// ```
/// use efficientgrad::comm::wire::sparse_tensor_bytes;
/// assert_eq!(sparse_tensor_bytes(0), 8); // header only
/// assert_eq!(sparse_tensor_bytes(1_000), 8 + 8_000);
/// ```
pub fn sparse_tensor_bytes(nnz: usize) -> u64 {
    SPARSE_TENSOR_HEADER_BYTES + 8 * nnz as u64
}

/// Wire bytes of one sign-magnitude tensor: `12 + 4·⌈E/32⌉ + 4·⌈nnz/32⌉`
/// (header, presence bitmap over all `E` elements, one sign bit per
/// survivor, both bit planes padded to u32 words).
///
/// ```
/// use efficientgrad::comm::wire::{dense_tensor_bytes, sign_tensor_bytes};
/// assert_eq!(sign_tensor_bytes(64, 0), 12 + 8);
/// assert_eq!(sign_tensor_bytes(64, 33), 12 + 8 + 8);
/// // ~42k elements at ~46% survivors (eq. 3 at P=0.9 on N(0,σ) deltas):
/// // the presence+sign planes cost ~0.18 bytes/element vs 4 dense
/// let sign = sign_tensor_bytes(42_000, 19_320);
/// assert!(dense_tensor_bytes(42_000) / sign >= 20);
/// ```
pub fn sign_tensor_bytes(elems: usize, nnz: usize) -> u64 {
    SIGN_TENSOR_HEADER_BYTES + 4 * elems.div_ceil(32) as u64 + 4 * nnz.div_ceil(32) as u64
}

/// Wire bytes of one sparse-mode model message given its total survivor
/// count: `8·nnz + n_tensors·8`. The sparse per-tensor cost is linear in
/// `nnz`, so (unlike sign mode) the model total *is* a function of the
/// summed survivors — integration tests and benches assert measured
/// sparse messages against this exactly.
///
/// ```
/// use efficientgrad::comm::wire::{sparse_model_bytes, sparse_tensor_bytes};
/// assert_eq!(sparse_model_bytes(100, 3),
///            sparse_tensor_bytes(50) + sparse_tensor_bytes(30) + sparse_tensor_bytes(20));
/// ```
pub fn sparse_model_bytes(total_nnz: u64, n_tensors: u64) -> u64 {
    8 * total_nnz + n_tensors * SPARSE_TENSOR_HEADER_BYTES
}

/// `[min, max]` wire bytes of one sign-mode model message over tensors
/// of the given element counts: the empty (nnz = 0 everywhere) and full
/// (nnz = E everywhere) envelopes of [`sign_tensor_bytes`]. The per-
/// tensor `⌈nnz/32⌉` padding keeps the exact total from being a function
/// of the *summed* survivors, so integration tests/benches pin measured
/// sign messages inside this envelope (the per-tensor formula itself is
/// pinned exactly by unit tests).
///
/// ```
/// use efficientgrad::comm::wire::{sign_model_bytes_envelope, sign_tensor_bytes};
/// let (lo, hi) = sign_model_bytes_envelope([64usize, 10].iter().copied());
/// assert_eq!(lo, sign_tensor_bytes(64, 0) + sign_tensor_bytes(10, 0));
/// assert_eq!(hi, sign_tensor_bytes(64, 64) + sign_tensor_bytes(10, 10));
/// ```
pub fn sign_model_bytes_envelope(tensor_elems: impl Iterator<Item = usize>) -> (u64, u64) {
    tensor_elems.fold((0, 0), |(lo, hi), e| {
        (lo + sign_tensor_bytes(e, 0), hi + sign_tensor_bytes(e, e))
    })
}

/// Wire bytes of a chained downlink over per-link payload sizes:
/// `8 + Σ link_bytes` — the normative formula for resyncing a worker
/// `k` versions behind from the `k` retained per-round deltas
/// (`docs/TRANSFER_MODEL.md` §Model versions). Against a dense resync's
/// `4·P`, a chain wins whenever the retained deltas are sparse enough —
/// at the paper's P=0.9 in sign mode, ~k·0.18·P̃ bytes vs 4·P̃ dense
/// (P̃ = param elements).
///
/// ```
/// use efficientgrad::comm::wire::{chained_model_bytes, CHAIN_HEADER_BYTES};
/// assert_eq!(chained_model_bytes([100u64, 250].into_iter()), 8 + 350);
/// assert_eq!(chained_model_bytes(std::iter::empty()), CHAIN_HEADER_BYTES);
/// ```
pub fn chained_model_bytes(link_bytes: impl Iterator<Item = u64>) -> u64 {
    CHAIN_HEADER_BYTES + link_bytes.sum::<u64>()
}

/// Edge→root uplink bytes of one two-tier round: each *active* edge
/// aggregator (one that heard from ≥ 1 worker) seals ONE pre-folded
/// sparse delta whose support is the union of its cohort slice's
/// survivors, so the tier costs
/// `Σ_e (sparse_model_bytes(nnz_e, T) + 24)` — O(nnz) per tier plus the
/// flat 24 B frame envelope per edge, never O(P·edges)
/// (`docs/TRANSFER_MODEL.md` §Fleet tier). Silent edges ship nothing
/// and cost nothing.
///
/// ```
/// use efficientgrad::comm::wire::{fleet_tier_bytes, sparse_model_bytes};
/// use efficientgrad::comm::envelope::FRAME_HEADER_BYTES;
/// // two active edges over a 3-tensor model, 50 and 20 union-survivors
/// assert_eq!(fleet_tier_bytes(3, [50u64, 20].into_iter()),
///            sparse_model_bytes(50, 3) + sparse_model_bytes(20, 3)
///                + 2 * FRAME_HEADER_BYTES);
/// // a round where every edge was silent ships no tier traffic at all
/// assert_eq!(fleet_tier_bytes(3, std::iter::empty()), 0);
/// ```
pub fn fleet_tier_bytes(n_tensors: u64, edge_nnz: impl Iterator<Item = u64>) -> u64 {
    edge_nnz
        .map(|nnz| sparse_model_bytes(nnz, n_tensors) + crate::comm::envelope::FRAME_HEADER_BYTES)
        .sum()
}

/// Pruned-delta survivors of one tensor: `u32` element offsets (sorted,
/// ascending — encode walks the buffer in order) + exact `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseTensor {
    /// element count of the dense tensor this update applies to
    pub elems: u32,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseTensor {
    /// Encode the nonzero coordinates of a (pruned) dense buffer.
    pub fn encode(pruned: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if crate::util::simd::active() {
            crate::util::simd::sparse_encode_into(pruned, &mut indices, &mut values);
            return Self {
                elems: pruned.len() as u32,
                indices,
                values,
            };
        }
        for (i, &v) in pruned.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        Self {
            elems: pruned.len() as u32,
            indices,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn wire_bytes(&self) -> u64 {
        sparse_tensor_bytes(self.nnz())
    }
}

/// Sign-magnitude survivors of one tensor: presence bitmap over all
/// elements, one sign bit per survivor (1 = negative) in survivor order,
/// and the shared magnitude (mean |value| of the survivors — the L2-best
/// single scale for the sign plane).
#[derive(Clone, Debug, PartialEq)]
pub struct SignTensor {
    /// element count of the dense tensor this update applies to
    pub elems: u32,
    /// survivor count (redundant with the bitmap popcount; shipped so a
    /// decoder can size buffers before touching the planes)
    pub nnz: u32,
    /// presence bitmap, bit `i % 32` of word `i / 32` set iff element
    /// `i` survived
    pub presence: Vec<u32>,
    /// sign bits in survivor order, 1 = negative
    pub signs: Vec<u32>,
    /// shared decoded magnitude
    pub magnitude: f32,
}

impl SignTensor {
    /// Encode the nonzero coordinates of a (pruned) dense buffer as
    /// presence + sign planes with a shared magnitude.
    ///
    /// Under `--features simd` the planes are built a word at a time
    /// (movemask-style: 32 lanes per u32, BMI2 `pext` sign compaction);
    /// [`SignTensor::encode_scalar`] is the bit-for-bit oracle the vector
    /// path is pinned against.
    pub fn encode(pruned: &[f32]) -> Self {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if crate::util::simd::active() {
            let (presence, signs, nnz) = crate::util::simd::sign_encode_planes(pruned);
            return Self::assemble(pruned, presence, signs, nnz);
        }
        Self::encode_scalar(pruned)
    }

    /// The scalar oracle: per-element plane pushes, exactly the loop the
    /// word-at-a-time encoder must reproduce bit for bit.
    pub(crate) fn encode_scalar(pruned: &[f32]) -> Self {
        let mut presence = vec![0u32; pruned.len().div_ceil(32)];
        let mut signs = Vec::new();
        let mut nnz = 0u32;
        for (i, &v) in pruned.iter().enumerate() {
            if v != 0.0 {
                presence[i / 32] |= 1 << (i % 32);
                let j = nnz as usize;
                if j % 32 == 0 {
                    signs.push(0);
                }
                if v < 0.0 {
                    signs[j / 32] |= 1 << (j % 32);
                }
                nnz += 1;
            }
        }
        Self::assemble(pruned, presence, signs, nnz)
    }

    /// Shared magnitude + header assembly. Mean |survivor| is computed as
    /// the striped Σ|x| over *all* elements (non-survivors are exactly
    /// ±0.0 and contribute +0.0), so scalar and simd builds — and both
    /// encode paths — produce identical magnitude bytes.
    fn assemble(pruned: &[f32], presence: Vec<u32>, signs: Vec<u32>, nnz: u32) -> Self {
        let magnitude = if nnz == 0 {
            0.0
        } else {
            (crate::util::simd::abs_sum_striped(pruned) / nnz as f64) as f32
        };
        Self {
            elems: pruned.len() as u32,
            nnz,
            presence,
            signs,
            magnitude,
        }
    }

    pub fn wire_bytes(&self) -> u64 {
        sign_tensor_bytes(self.elems as usize, self.nnz as usize)
    }

    /// `dst[i] += alpha · value` over survivors, non-survivor lanes
    /// untouched — the slice-level sign fold shared by
    /// [`TensorUpdate::axpy_into`] and the codec's residual update
    /// (`alpha = −1`: `x + (−1)·v` is bit-identical to `x − v`).
    /// Dispatches to the word-at-a-time AVX2 fold under `--features
    /// simd`; the [`SignTensor::for_each_survivor`] walk is the oracle.
    pub fn axpy_into_slice(&self, alpha: f32, dst: &mut [f32]) {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if crate::util::simd::active() {
            crate::util::simd::sign_axpy_f32(&self.presence, &self.signs, self.magnitude, alpha, dst);
            return;
        }
        self.for_each_survivor(|i, v| dst[i] += alpha * v);
    }

    /// Visit `(element_index, decoded_value)` for every survivor, in
    /// index order. The decode primitive behind `axpy_into` and the
    /// codec's residual update.
    pub fn for_each_survivor(&self, mut f: impl FnMut(usize, f32)) {
        let mut ordinal = 0usize;
        for (w, &word) in self.presence.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let idx = w * 32 + b;
                let neg = (self.signs[ordinal / 32] >> (ordinal % 32)) & 1 == 1;
                f(idx, if neg { -self.magnitude } else { self.magnitude });
                ordinal += 1;
            }
        }
        debug_assert_eq!(ordinal, self.nnz as usize);
    }
}

/// One tensor's delta on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorUpdate {
    Sparse(SparseTensor),
    Sign(SignTensor),
}

impl TensorUpdate {
    /// Element count of the dense tensor this update applies to.
    pub fn elems(&self) -> usize {
        match self {
            TensorUpdate::Sparse(t) => t.elems as usize,
            TensorUpdate::Sign(t) => t.elems as usize,
        }
    }

    /// Survivor (nonzero) count.
    pub fn survivors(&self) -> usize {
        match self {
            TensorUpdate::Sparse(t) => t.nnz(),
            TensorUpdate::Sign(t) => t.nnz as usize,
        }
    }

    pub fn wire_bytes(&self) -> u64 {
        match self {
            TensorUpdate::Sparse(t) => t.wire_bytes(),
            TensorUpdate::Sign(t) => t.wire_bytes(),
        }
    }

    /// `dst += alpha · decode(self)` in O(nnz) — the FedAvg accumulation
    /// primitive. Panics (via [`Tensor::axpy_sparse`] / indexing) if the
    /// update addresses elements outside `dst`.
    pub fn axpy_into(&self, alpha: f32, dst: &mut Tensor) {
        assert_eq!(
            self.elems(),
            dst.len(),
            "update for {} elements applied to tensor of {}",
            self.elems(),
            dst.len()
        );
        match self {
            TensorUpdate::Sparse(t) => dst.axpy_sparse(alpha, &t.indices, &t.values),
            TensorUpdate::Sign(t) => t.axpy_into_slice(alpha, dst.data_mut()),
        }
    }

    /// `dst[i] += alpha · decode(self)[i]` into an **f64** accumulator —
    /// the precision the FedAvg fold now carries so that per-worker
    /// contributions combine without f32 rounding drift
    /// ([`crate::coordinator::weighted_sparse_fedavg`]). Same O(nnz)
    /// walk and survivor order as [`TensorUpdate::axpy_into`].
    pub fn axpy_into_f64(&self, alpha: f64, dst: &mut [f64]) {
        assert_eq!(
            self.elems(),
            dst.len(),
            "update for {} elements applied to accumulator of {}",
            self.elems(),
            dst.len()
        );
        match self {
            TensorUpdate::Sparse(t) => {
                for (&i, &v) in t.indices.iter().zip(&t.values) {
                    dst[i as usize] += alpha * v as f64;
                }
            }
            TensorUpdate::Sign(t) => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if crate::util::simd::active() {
                    crate::util::simd::sign_axpy_f64(&t.presence, &t.signs, t.magnitude, alpha, dst);
                    return;
                }
                t.for_each_survivor(|i, v| dst[i] += alpha * v as f64)
            }
        }
    }

    /// Whether every shipped value is finite. A NaN/Inf survivor in an
    /// otherwise well-formed update would fold straight into the global
    /// model; the leader rejects such reports at the fold boundary
    /// (`RoundReport::rejected_reports`).
    pub fn all_finite(&self) -> bool {
        match self {
            TensorUpdate::Sparse(t) => t.values.iter().all(|v| v.is_finite()),
            TensorUpdate::Sign(t) => t.magnitude.is_finite(),
        }
    }

    /// Decode to a dense buffer (tests / residual bookkeeping). Allocates;
    /// per-round paths should hold a scratch buffer and use
    /// [`TensorUpdate::decode_into`] instead.
    pub fn decode_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.elems()];
        self.decode_into(&mut out);
        out
    }

    /// Decode into a caller-provided dense scratch, overwriting every lane
    /// (`out.len()` must equal `self.elems()`). This is the allocation-free
    /// decode the leader threads one reusable buffer through instead of
    /// allocating a dense-size `Vec` per worker per round.
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(
            self.elems(),
            out.len(),
            "update for {} elements decoded into scratch of {}",
            self.elems(),
            out.len()
        );
        match self {
            TensorUpdate::Sparse(t) => {
                out.fill(0.0);
                for (&i, &v) in t.indices.iter().zip(&t.values) {
                    out[i as usize] = v;
                }
            }
            TensorUpdate::Sign(t) => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if crate::util::simd::active() {
                    crate::util::simd::sign_decode_into(&t.presence, &t.signs, t.magnitude, out);
                    return;
                }
                out.fill(0.0);
                t.for_each_survivor(|i, v| out[i] = v);
            }
        }
    }
}

/// One full model exchange (uplink or downlink).
#[derive(Clone, Debug, PartialEq)]
pub enum ModelUpdate {
    /// Full parameter snapshot — the legacy format, still used by
    /// `comm = dense`, by the first round of every compressed run, and to
    /// resync a worker that missed a downlink.
    Dense(Vec<Tensor>),
    /// Pruned delta, one [`TensorUpdate`] per param tensor in store order.
    Delta(Vec<TensorUpdate>),
    /// Chained downlink: the retained per-round deltas a worker missed,
    /// oldest first. Applying the chain replays exactly the per-round
    /// downlinks (same float ops, same order), so the receiver's replica
    /// lands bit-identical to a peer that caught every round — at
    /// `8 + Σ link` wire bytes ([`chained_model_bytes`]) instead of a
    /// dense `4·P` resync. Downlink-only; never a valid uplink.
    Chain(Vec<Vec<TensorUpdate>>),
}

impl ModelUpdate {
    /// Bytes this message occupies on the wire (normative formulas above;
    /// the dense variant is headerless `4·P`, matching the pre-comm
    /// network accounting bit for bit).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            ModelUpdate::Dense(ts) => ts.iter().map(|t| dense_tensor_bytes(t.len())).sum(),
            ModelUpdate::Delta(us) => us.iter().map(TensorUpdate::wire_bytes).sum(),
            ModelUpdate::Chain(links) => chained_model_bytes(
                links
                    .iter()
                    .map(|us| us.iter().map(TensorUpdate::wire_bytes).sum()),
            ),
        }
    }

    /// Total survivors across tensors (0 for the dense variant — every
    /// element travels, "survivor" is a delta-format notion; a chain
    /// sums its links).
    pub fn survivors(&self) -> u64 {
        match self {
            ModelUpdate::Dense(_) => 0,
            ModelUpdate::Delta(us) => us.iter().map(|u| u.survivors() as u64).sum(),
            ModelUpdate::Chain(links) => links
                .iter()
                .flat_map(|us| us.iter())
                .map(|u| u.survivors() as u64)
                .sum(),
        }
    }

    /// True for the dense-snapshot variant.
    pub fn is_dense(&self) -> bool {
        matches!(self, ModelUpdate::Dense(_))
    }

    /// Whether every value in the message is finite (see
    /// [`TensorUpdate::all_finite`]).
    pub fn all_finite(&self) -> bool {
        match self {
            ModelUpdate::Dense(ts) => ts.iter().all(|t| t.data().iter().all(|v| v.is_finite())),
            ModelUpdate::Delta(us) => us.iter().all(TensorUpdate::all_finite),
            ModelUpdate::Chain(links) => links
                .iter()
                .all(|us| us.iter().all(TensorUpdate::all_finite)),
        }
    }

    /// True for the chained-downlink variant.
    pub fn is_chain(&self) -> bool {
        matches!(self, ModelUpdate::Chain(_))
    }

    /// Materialize this update into `params`: a dense snapshot replaces
    /// them (an empty `params` bootstraps from any snapshot), a delta
    /// accumulates into them (`alpha = 1`). Leader and workers apply
    /// every *delta* downlink through this one function, which is what
    /// keeps their reference replicas bit-identical; dense snapshots may
    /// also move directly into a replica (the worker's dense-mode path
    /// does, to skip the clone) — replacement has no float math, so the
    /// lockstep guarantee is unaffected.
    pub fn apply(&self, params: &mut Vec<Tensor>) -> Result<()> {
        match self {
            ModelUpdate::Dense(ts) => {
                if !params.is_empty() && params.len() != ts.len() {
                    bail!("dense update has {} tensors, store {}", ts.len(), params.len());
                }
                *params = ts.clone();
            }
            ModelUpdate::Delta(us) => {
                validate_delta(us, params)?;
                apply_delta(us, params);
            }
            ModelUpdate::Chain(links) => {
                // validate every link before mutating anything: a chain
                // that fails halfway would leave the replica at an
                // intermediate version its peer has no record of
                for us in links {
                    validate_delta(us, params)?;
                }
                // oldest first — exactly the per-round downlink replay
                for us in links {
                    apply_delta(us, params);
                }
            }
        }
        Ok(())
    }
}

/// Shape-check one per-round delta against the replica it would mutate
/// (a half-applied delta would silently desync the replica from its
/// peer, so callers validate everything before touching anything).
fn validate_delta(us: &[TensorUpdate], params: &[Tensor]) -> Result<()> {
    if params.len() != us.len() {
        bail!("delta update has {} tensors, store {}", us.len(), params.len());
    }
    for (u, p) in us.iter().zip(params.iter()) {
        if u.elems() != p.len() {
            bail!("delta tensor sized {} applied to {}", u.elems(), p.len());
        }
    }
    Ok(())
}

fn apply_delta(us: &[TensorUpdate], params: &mut [Tensor]) {
    for (u, p) in us.iter().zip(params.iter_mut()) {
        u.axpy_into(1.0, p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_encode_decode_roundtrip() {
        let pruned = [0.0f32, 1.5, 0.0, -2.0, 0.0, 0.25];
        let t = SparseTensor::encode(&pruned);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.indices, vec![1, 3, 5]);
        assert_eq!(t.wire_bytes(), sparse_tensor_bytes(3));
        let u = TensorUpdate::Sparse(t);
        assert_eq!(u.decode_dense(), pruned.to_vec());
    }

    #[test]
    fn sign_encode_preserves_support_and_signs() {
        let pruned = [0.0f32, 2.0, 0.0, -2.0, 2.0];
        let t = SignTensor::encode(&pruned);
        assert_eq!(t.nnz, 3);
        assert_eq!(t.magnitude, 2.0);
        let decoded = TensorUpdate::Sign(t).decode_dense();
        assert_eq!(decoded, pruned.to_vec()); // equal magnitudes: exact
    }

    #[test]
    fn sign_shared_magnitude_is_mean_abs() {
        let pruned = [1.0f32, -3.0, 0.0];
        let t = SignTensor::encode(&pruned);
        assert_eq!(t.magnitude, 2.0);
        let decoded = TensorUpdate::Sign(t).decode_dense();
        assert_eq!(decoded, vec![2.0, -2.0, 0.0]);
    }

    #[test]
    fn sign_bit_planes_cross_word_boundaries() {
        // 70 elements, all surviving, alternating signs: exercises both
        // planes past one u32 word
        let pruned: Vec<f32> = (0..70)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let t = SignTensor::encode(&pruned);
        assert_eq!(t.nnz, 70);
        assert_eq!(t.presence.len(), 3);
        assert_eq!(t.signs.len(), 3);
        assert_eq!(t.wire_bytes(), sign_tensor_bytes(70, 70));
        assert_eq!(TensorUpdate::Sign(t).decode_dense(), pruned);
    }

    #[test]
    fn empty_and_full_sparsity_edges() {
        // all-zero buffer: headers only, decode is all zeros
        let z = [0.0f32; 40];
        let s = SparseTensor::encode(&z);
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.wire_bytes(), SPARSE_TENSOR_HEADER_BYTES);
        let g = SignTensor::encode(&z);
        assert_eq!(g.nnz, 0);
        assert_eq!(g.magnitude, 0.0);
        assert_eq!(TensorUpdate::Sign(g).decode_dense(), z.to_vec());
        // zero-length tensor
        let e = SparseTensor::encode(&[]);
        assert_eq!(e.elems, 0);
        assert_eq!(TensorUpdate::Sparse(e).decode_dense(), Vec::<f32>::new());
    }

    #[test]
    fn axpy_into_accumulates_weighted() {
        let mut dst = Tensor::ones(&[4]);
        let u = TensorUpdate::Sparse(SparseTensor::encode(&[0.0, 2.0, 0.0, -4.0]));
        u.axpy_into(0.5, &mut dst);
        assert_eq!(dst.data(), &[1.0, 2.0, 1.0, -1.0]);
    }

    #[test]
    fn model_update_apply_dense_and_delta() {
        let mut params = vec![Tensor::zeros(&[3])];
        let dense = ModelUpdate::Dense(vec![Tensor::full(&[3], 2.0)]);
        dense.apply(&mut params).unwrap();
        assert_eq!(params[0].data(), &[2.0, 2.0, 2.0]);
        let delta =
            ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[0.0, 1.0, 0.0]))]);
        delta.apply(&mut params).unwrap();
        assert_eq!(params[0].data(), &[2.0, 3.0, 2.0]);
        // shape mismatch is an error, not corruption
        let bad = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[0.0]))]);
        assert!(bad.apply(&mut params).is_err());
        let bad_count = ModelUpdate::Delta(vec![]);
        assert!(bad_count.apply(&mut params).is_err());
    }

    #[test]
    fn chain_applies_links_in_order_and_prices_the_header() {
        let mut params = vec![Tensor::zeros(&[3])];
        let l1 = vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0, 0.0, 0.0]))];
        let l2 = vec![TensorUpdate::Sparse(SparseTensor::encode(&[0.0, 2.0, -1.0]))];
        let chain = ModelUpdate::Chain(vec![l1.clone(), l2.clone()]);
        assert!(chain.is_chain() && !chain.is_dense());
        // bytes: the documented formula — header + each link priced as
        // the per-round delta it replays
        assert_eq!(
            chain.wire_bytes(),
            chained_model_bytes(
                [sparse_tensor_bytes(1), sparse_tensor_bytes(2)].into_iter()
            )
        );
        assert_eq!(chain.survivors(), 3);
        chain.apply(&mut params).unwrap();
        // == applying l1 then l2 individually
        let mut replay = vec![Tensor::zeros(&[3])];
        ModelUpdate::Delta(l1).apply(&mut replay).unwrap();
        ModelUpdate::Delta(l2).apply(&mut replay).unwrap();
        assert_eq!(params, replay);
        // a bad link anywhere rejects the whole chain without mutating
        let before = params.clone();
        let bad = ModelUpdate::Chain(vec![
            vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0, 0.0, 0.0]))],
            vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0]))], // wrong size
        ]);
        assert!(bad.apply(&mut params).is_err());
        assert_eq!(params, before, "failed chain must not half-apply");
    }

    #[test]
    fn all_finite_flags_nan_and_inf_payloads() {
        let ok = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0, 0.0]))]);
        assert!(ok.all_finite());
        let nan_sparse = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor {
            elems: 2,
            indices: vec![0],
            values: vec![f32::NAN],
        })]);
        assert!(!nan_sparse.all_finite());
        let mut sign = SignTensor::encode(&[1.0, -1.0]);
        sign.magnitude = f32::INFINITY;
        assert!(!ModelUpdate::Delta(vec![TensorUpdate::Sign(sign.clone())]).all_finite());
        assert!(!ModelUpdate::Chain(vec![vec![TensorUpdate::Sign(sign)]]).all_finite());
        let dense = ModelUpdate::Dense(vec![Tensor::new(vec![2], vec![0.0, f32::NAN])]);
        assert!(!dense.all_finite());
    }

    #[test]
    fn wire_bytes_match_documented_formulas() {
        let dense = ModelUpdate::Dense(vec![Tensor::zeros(&[10]), Tensor::zeros(&[5])]);
        assert_eq!(dense.wire_bytes(), 4 * 15);
        assert_eq!(dense.survivors(), 0);
        let pruned = [1.0f32, 0.0, -1.0, 0.0, 0.0];
        let delta = ModelUpdate::Delta(vec![
            TensorUpdate::Sparse(SparseTensor::encode(&pruned)),
            TensorUpdate::Sign(SignTensor::encode(&pruned)),
        ]);
        assert_eq!(
            delta.wire_bytes(),
            sparse_tensor_bytes(2) + sign_tensor_bytes(5, 2)
        );
        assert_eq!(delta.survivors(), 4);
    }
}
