//! Wire formats for federated model exchange.
//!
//! Three encodings, one per [`crate::config::CommMode`]:
//!
//! * **dense** — the legacy format: every f32 of every param tensor,
//!   `4·P` bytes. No header (matches the pre-comm accounting exactly).
//! * **sparse** — pruned-delta survivors as `u32` element offsets +
//!   `f32` values: `8 + 8·nnz` bytes per tensor.
//! * **sign** — the paper's sign-symmetric trick applied to the wire:
//!   a presence bitmap over all elements (1 bit each), one sign bit per
//!   survivor, and a single shared per-tensor magnitude:
//!   `12 + 4·⌈E/32⌉ + 4·⌈nnz/32⌉` bytes per tensor. This is the format
//!   that survives eq. 3's stochastic promotion: promoted survivors all
//!   sit at `±τ`, so a shared magnitude loses almost nothing while the
//!   per-survivor cost drops from 8 bytes to ~1.25 bits + amortized
//!   bitmap.
//!
//! The byte functions below are the *normative* size model
//! (`docs/TRANSFER_MODEL.md` §Network tier); `wire_bytes()` on the
//! structs computes sizes through them, so the ledger the federated
//! leader reports is the documented formula by construction, and the
//! doc-tests pin the arithmetic.
//!
//! Workers are threads in this simulation, so updates travel as these
//! structs rather than a byte stream — but the bitmaps and sign planes
//! are genuinely bit-packed (`Vec<u32>` words), and encode/decode are
//! real, round-trip-tested transforms, so `wire_bytes()` is what a
//! serialized message would actually cost.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Per-tensor header of the sparse format: element count + nnz (u32 each).
pub const SPARSE_TENSOR_HEADER_BYTES: u64 = 8;

/// Per-tensor header of the sign format: element count + nnz (u32 each)
/// + the shared f32 magnitude.
pub const SIGN_TENSOR_HEADER_BYTES: u64 = 12;

/// Per-message header of a chained downlink: base version + link count
/// (u32 each). The links themselves are ordinary per-round delta
/// payloads, so a chain costs exactly the header plus what the receiver
/// would have paid had it caught every round's downlink individually.
pub const CHAIN_HEADER_BYTES: u64 = 8;

/// Per-tensor header of the quantized format: element count + nnz
/// (u32 each), the affine `scale` + `zero` (f32 each), and one flags
/// byte (code width, support encoding).
pub const QUANT_TENSOR_HEADER_BYTES: u64 = 17;

/// Per-tensor header of a merged (v2) chain: element count + union nnz
/// (u32 each) + one flags byte for the shared support encoding.
pub const MERGED_TENSOR_HEADER_BYTES: u64 = 9;

/// Per-link-per-tensor header inside a merged chain: flags byte (code
/// width) + the link's affine `scale` + `zero` (f32 each). The link's
/// support rides as varint ordinal gaps, not a header field.
pub const MERGED_LINK_HEADER_BYTES: u64 = 9;

/// Wire bytes of one dense f32 tensor: `4·E`.
///
/// ```
/// use efficientgrad::comm::wire::dense_tensor_bytes;
/// assert_eq!(dense_tensor_bytes(42_000), 168_000);
/// assert_eq!(dense_tensor_bytes(0), 0);
/// ```
pub fn dense_tensor_bytes(elems: usize) -> u64 {
    4 * elems as u64
}

/// Wire bytes of one sparse tensor: `8 + 8·nnz` (header + u32 index +
/// f32 value per survivor).
///
/// ```
/// use efficientgrad::comm::wire::sparse_tensor_bytes;
/// assert_eq!(sparse_tensor_bytes(0), 8); // header only
/// assert_eq!(sparse_tensor_bytes(1_000), 8 + 8_000);
/// ```
pub fn sparse_tensor_bytes(nnz: usize) -> u64 {
    SPARSE_TENSOR_HEADER_BYTES + 8 * nnz as u64
}

/// Wire bytes of one sign-magnitude tensor: `12 + 4·⌈E/32⌉ + 4·⌈nnz/32⌉`
/// (header, presence bitmap over all `E` elements, one sign bit per
/// survivor, both bit planes padded to u32 words).
///
/// ```
/// use efficientgrad::comm::wire::{dense_tensor_bytes, sign_tensor_bytes};
/// assert_eq!(sign_tensor_bytes(64, 0), 12 + 8);
/// assert_eq!(sign_tensor_bytes(64, 33), 12 + 8 + 8);
/// // ~42k elements at ~46% survivors (eq. 3 at P=0.9 on N(0,σ) deltas):
/// // the presence+sign planes cost ~0.18 bytes/element vs 4 dense
/// let sign = sign_tensor_bytes(42_000, 19_320);
/// assert!(dense_tensor_bytes(42_000) / sign >= 20);
/// ```
pub fn sign_tensor_bytes(elems: usize, nnz: usize) -> u64 {
    SIGN_TENSOR_HEADER_BYTES + 4 * elems.div_ceil(32) as u64 + 4 * nnz.div_ceil(32) as u64
}

/// Wire bytes of one sparse-mode model message given its total survivor
/// count: `8·nnz + n_tensors·8`. The sparse per-tensor cost is linear in
/// `nnz`, so (unlike sign mode) the model total *is* a function of the
/// summed survivors — integration tests and benches assert measured
/// sparse messages against this exactly.
///
/// ```
/// use efficientgrad::comm::wire::{sparse_model_bytes, sparse_tensor_bytes};
/// assert_eq!(sparse_model_bytes(100, 3),
///            sparse_tensor_bytes(50) + sparse_tensor_bytes(30) + sparse_tensor_bytes(20));
/// ```
pub fn sparse_model_bytes(total_nnz: u64, n_tensors: u64) -> u64 {
    8 * total_nnz + n_tensors * SPARSE_TENSOR_HEADER_BYTES
}

/// `[min, max]` wire bytes of one sign-mode model message over tensors
/// of the given element counts: the empty (nnz = 0 everywhere) and full
/// (nnz = E everywhere) envelopes of [`sign_tensor_bytes`]. The per-
/// tensor `⌈nnz/32⌉` padding keeps the exact total from being a function
/// of the *summed* survivors, so integration tests/benches pin measured
/// sign messages inside this envelope (the per-tensor formula itself is
/// pinned exactly by unit tests).
///
/// ```
/// use efficientgrad::comm::wire::{sign_model_bytes_envelope, sign_tensor_bytes};
/// let (lo, hi) = sign_model_bytes_envelope([64usize, 10].iter().copied());
/// assert_eq!(lo, sign_tensor_bytes(64, 0) + sign_tensor_bytes(10, 0));
/// assert_eq!(hi, sign_tensor_bytes(64, 64) + sign_tensor_bytes(10, 10));
/// ```
pub fn sign_model_bytes_envelope(tensor_elems: impl Iterator<Item = usize>) -> (u64, u64) {
    tensor_elems.fold((0, 0), |(lo, hi), e| {
        (lo + sign_tensor_bytes(e, 0), hi + sign_tensor_bytes(e, e))
    })
}

/// Wire bytes of a chained downlink over per-link payload sizes:
/// `8 + Σ link_bytes` — the normative formula for resyncing a worker
/// `k` versions behind from the `k` retained per-round deltas
/// (`docs/TRANSFER_MODEL.md` §Model versions). Against a dense resync's
/// `4·P`, a chain wins whenever the retained deltas are sparse enough —
/// at the paper's P=0.9 in sign mode, ~k·0.18·P̃ bytes vs 4·P̃ dense
/// (P̃ = param elements).
///
/// ```
/// use efficientgrad::comm::wire::{chained_model_bytes, CHAIN_HEADER_BYTES};
/// assert_eq!(chained_model_bytes([100u64, 250].into_iter()), 8 + 350);
/// assert_eq!(chained_model_bytes(std::iter::empty()), CHAIN_HEADER_BYTES);
/// ```
pub fn chained_model_bytes(link_bytes: impl Iterator<Item = u64>) -> u64 {
    CHAIN_HEADER_BYTES + link_bytes.sum::<u64>()
}

/// Edge→root uplink bytes of one two-tier round: each *active* edge
/// aggregator (one that heard from ≥ 1 worker) seals ONE pre-folded
/// sparse delta whose support is the union of its cohort slice's
/// survivors, so the tier costs
/// `Σ_e (sparse_model_bytes(nnz_e, T) + 24)` — O(nnz) per tier plus the
/// flat 24 B frame envelope per edge, never O(P·edges)
/// (`docs/TRANSFER_MODEL.md` §Fleet tier). Silent edges ship nothing
/// and cost nothing.
///
/// ```
/// use efficientgrad::comm::wire::{fleet_tier_bytes, sparse_model_bytes};
/// use efficientgrad::comm::envelope::FRAME_HEADER_BYTES;
/// // two active edges over a 3-tensor model, 50 and 20 union-survivors
/// assert_eq!(fleet_tier_bytes(3, [50u64, 20].into_iter()),
///            sparse_model_bytes(50, 3) + sparse_model_bytes(20, 3)
///                + 2 * FRAME_HEADER_BYTES);
/// // a round where every edge was silent ships no tier traffic at all
/// assert_eq!(fleet_tier_bytes(3, std::iter::empty()), 0);
/// ```
pub fn fleet_tier_bytes(n_tensors: u64, edge_nnz: impl Iterator<Item = u64>) -> u64 {
    edge_nnz
        .map(|nnz| sparse_model_bytes(nnz, n_tensors) + crate::comm::envelope::FRAME_HEADER_BYTES)
        .sum()
}

// ---------------------------------------------------------------------------
// Wire v2 primitives: varints, RLE presence bitmaps, quantized survivors,
// merged chains (docs/TRANSFER_MODEL.md §Wire v2)
// ---------------------------------------------------------------------------

/// Bytes of one LEB128 varint (7 payload bits per byte, high bit = more).
///
/// ```
/// use efficientgrad::comm::wire::varint_len;
/// assert_eq!(varint_len(0), 1);
/// assert_eq!(varint_len(127), 1);
/// assert_eq!(varint_len(128), 2);
/// assert_eq!(varint_len(16_383), 2);
/// assert_eq!(varint_len(16_384), 3);
/// ```
pub fn varint_len(mut v: u64) -> u64 {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

/// Append `v` as a LEB128 varint.
pub fn push_varint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

/// Read one LEB128 varint at `*pos`, advancing it. Rejects truncated
/// streams and over-long (> 10 byte) encodings.
pub fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = bytes.get(*pos) else {
            bail!("varint truncated");
        };
        *pos += 1;
        if shift >= 64 {
            bail!("varint overflows u64");
        }
        v |= u64::from(b & 0x7f) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Bytes of a raw presence bitmap over `elems` elements (u32 words).
///
/// ```
/// use efficientgrad::comm::wire::raw_bitmap_bytes;
/// assert_eq!(raw_bitmap_bytes(0), 0);
/// assert_eq!(raw_bitmap_bytes(32), 4);
/// assert_eq!(raw_bitmap_bytes(33), 8);
/// assert_eq!(raw_bitmap_bytes(42_000), 5252);
/// ```
pub fn raw_bitmap_bytes(elems: usize) -> u64 {
    4 * elems.div_ceil(32) as u64
}

/// Build the presence bitmap (bit `i % 32` of word `i / 32`) over sorted
/// survivor element offsets.
pub fn presence_bitmap(elems: usize, indices: &[u32]) -> Vec<u32> {
    let mut words = vec![0u32; elems.div_ceil(32)];
    for &i in indices {
        words[i as usize / 32] |= 1 << (i % 32);
    }
    words
}

/// Run-length-encode a presence bitmap: alternating run lengths as
/// varints, zeros first (the leading zero-run may be 0; every later run
/// is > 0; a trailing zero-run is included so the runs always sum to
/// `len`). Top-k pruning produces long runs, so for structured sparsity
/// this beats the raw `4·⌈len/32⌉` bytes; the per-tensor flag bit in the
/// quantized/merged formats picks whichever is smaller.
pub fn bitmap_rle_encode(bitmap: &[u32], len: usize) -> Vec<u8> {
    assert_eq!(bitmap.len(), len.div_ceil(32), "bitmap sized for {len} bits");
    let mut out = Vec::new();
    let bit = |i: usize| bitmap[i / 32] >> (i % 32) & 1 == 1;
    let mut pos = 0usize;
    let mut ones = false; // the run being measured
    while pos < len {
        let start = pos;
        while pos < len && bit(pos) == ones {
            pos += 1;
        }
        push_varint(&mut out, (pos - start) as u64);
        ones = !ones;
    }
    out
}

/// Decode [`bitmap_rle_encode`]'s stream back to bitmap words. Rejects
/// streams whose runs do not sum to exactly `len` or that leave trailing
/// bytes.
pub fn bitmap_rle_decode(bytes: &[u8], len: usize) -> Result<Vec<u32>> {
    let mut words = vec![0u32; len.div_ceil(32)];
    let mut pos = 0usize;
    let mut at = 0usize;
    let mut ones = false;
    while at < len {
        let run = read_varint(bytes, &mut pos)? as usize;
        if run > len - at {
            bail!("RLE run of {run} overruns the {len}-bit bitmap");
        }
        if ones {
            for i in at..at + run {
                words[i / 32] |= 1 << (i % 32);
            }
        }
        at += run;
        ones = !ones;
    }
    if pos != bytes.len() {
        bail!("RLE stream has {} trailing bytes", bytes.len() - pos);
    }
    Ok(words)
}

/// Decode an RLE support stream straight to sorted survivor offsets —
/// the envelope's decode path. Unlike [`bitmap_rle_decode`] this never
/// allocates `O(elems)`: a forged header claiming a huge element count
/// can only make the decoder do work (and memory) proportional to the
/// claimed `nnz`, which the envelope bounds against the payload bytes
/// actually present. Rejects runs past `elems`, ones-counts ≠ `nnz`,
/// and trailing bytes.
pub fn rle_decode_indices(bytes: &[u8], elems: usize, nnz: usize) -> Result<Vec<u32>> {
    let mut indices = Vec::with_capacity(nnz);
    let mut pos = 0usize;
    let mut at = 0usize;
    let mut ones = false;
    while at < elems {
        let run = read_varint(bytes, &mut pos)? as usize;
        if run > elems - at {
            bail!("RLE run of {run} overruns the {elems}-bit bitmap");
        }
        if ones {
            if indices.len() + run > nnz {
                bail!("RLE ones exceed the claimed nnz {nnz}");
            }
            for i in at..at + run {
                indices.push(i as u32);
            }
        }
        at += run;
        ones = !ones;
    }
    if pos != bytes.len() {
        bail!("RLE stream has {} trailing bytes", bytes.len() - pos);
    }
    if indices.len() != nnz {
        bail!("RLE ones {} != claimed nnz {nnz}", indices.len());
    }
    Ok(indices)
}

/// RLE byte count straight from sorted survivor offsets — what
/// [`bitmap_rle_encode`] would produce for their bitmap, in O(nnz)
/// without materializing it. The byte-accounting side of the raw-vs-RLE
/// choice.
pub fn rle_bytes_from_indices(elems: usize, indices: &[u32]) -> u64 {
    let mut bytes = 0u64;
    let mut pos = 0u64;
    let mut i = 0usize;
    while i < indices.len() {
        let start = indices[i] as u64;
        let mut end = start + 1;
        i += 1;
        while i < indices.len() && indices[i] as u64 == end {
            end += 1;
            i += 1;
        }
        bytes += varint_len(start - pos); // zero-run (first may be 0)
        bytes += varint_len(end - start); // ones-run
        pos = end;
    }
    if pos < elems as u64 {
        bytes += varint_len(elems as u64 - pos); // trailing zeros
    }
    bytes
}

/// Support bytes of one survivor set on the v2 wire: the smaller of the
/// raw bitmap and its RLE stream (the header flag bit records which).
pub fn support_bytes(elems: usize, indices: &[u32]) -> u64 {
    raw_bitmap_bytes(elems).min(rle_bytes_from_indices(elems, indices))
}

/// Wire bytes of one quantized code plane: `nnz` codes of
/// `bits ∈ {8, 4}` packed into u32 words.
///
/// ```
/// use efficientgrad::comm::wire::{quant_code_bytes, QuantBits};
/// assert_eq!(quant_code_bytes(0, QuantBits::Q8), 0);
/// assert_eq!(quant_code_bytes(4_200, QuantBits::Q8), 4_200);
/// assert_eq!(quant_code_bytes(4_200, QuantBits::Q4), 2_100);
/// assert_eq!(quant_code_bytes(5, QuantBits::Q4), 4); // one padded word
/// ```
pub fn quant_code_bytes(nnz: usize, bits: QuantBits) -> u64 {
    4 * (nnz * bits.bits()).div_ceil(32) as u64
}

/// Wire bytes of one quantized tensor: header + survivor support
/// (raw-or-RLE bitmap, whichever `support_bytes` picked) + packed codes.
/// The v2 replacement for [`sparse_tensor_bytes`]'s `8 + 8·nnz`: the
/// 8-byte survivor (u32 index + f32 value) becomes ~`P/nnz` bitmap bits
/// plus one 8- or 4-bit code.
///
/// ```
/// use efficientgrad::comm::wire::{quantized_tensor_bytes, raw_bitmap_bytes, QuantBits};
/// // ~42k-element tensor, 10% top-k survivors, raw bitmap support:
/// // 17 + 4·⌈42000/32⌉ + 4·⌈4200·8/32⌉
/// assert_eq!(
///     quantized_tensor_bytes(raw_bitmap_bytes(42_000), 4_200, QuantBits::Q8),
///     17 + 5_252 + 4_200
/// );
/// // q4 halves the code plane
/// assert_eq!(
///     quantized_tensor_bytes(raw_bitmap_bytes(42_000), 4_200, QuantBits::Q4),
///     17 + 5_252 + 2_100
/// );
/// ```
pub fn quantized_tensor_bytes(support: u64, nnz: usize, bits: QuantBits) -> u64 {
    QUANT_TENSOR_HEADER_BYTES + support + quant_code_bytes(nnz, bits)
}

/// Checked `usize → u32` for wire headers. Every format addresses
/// elements with u32 offsets and counts, so a buffer past 2³² elements
/// must fail loudly here instead of silently truncating `elems`/indices
/// and corrupting every decode downstream.
pub(crate) fn checked_elems(len: usize) -> u32 {
    u32::try_from(len).unwrap_or_else(|_| {
        panic!("tensor of {len} elements exceeds the u32 wire index space (max {})", u32::MAX)
    })
}

/// Pruned-delta survivors of one tensor: `u32` element offsets (sorted,
/// ascending — encode walks the buffer in order) + exact `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseTensor {
    /// element count of the dense tensor this update applies to
    pub elems: u32,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseTensor {
    /// Encode the nonzero coordinates of a (pruned) dense buffer.
    /// Panics past 2³² elements ([`checked_elems`]) — the u32 index
    /// space is the format's hard ceiling.
    pub fn encode(pruned: &[f32]) -> Self {
        let elems = checked_elems(pruned.len());
        let mut indices = Vec::new();
        let mut values = Vec::new();
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if crate::util::simd::active() {
            crate::util::simd::sparse_encode_into(pruned, &mut indices, &mut values);
            return Self {
                elems,
                indices,
                values,
            };
        }
        for (i, &v) in pruned.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        Self {
            elems,
            indices,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn wire_bytes(&self) -> u64 {
        sparse_tensor_bytes(self.nnz())
    }
}

/// Sign-magnitude survivors of one tensor: presence bitmap over all
/// elements, one sign bit per survivor (1 = negative) in survivor order,
/// and the shared magnitude (mean |value| of the survivors — the L2-best
/// single scale for the sign plane).
#[derive(Clone, Debug, PartialEq)]
pub struct SignTensor {
    /// element count of the dense tensor this update applies to
    pub elems: u32,
    /// survivor count (redundant with the bitmap popcount; shipped so a
    /// decoder can size buffers before touching the planes)
    pub nnz: u32,
    /// presence bitmap, bit `i % 32` of word `i / 32` set iff element
    /// `i` survived
    pub presence: Vec<u32>,
    /// sign bits in survivor order, 1 = negative
    pub signs: Vec<u32>,
    /// shared decoded magnitude
    pub magnitude: f32,
}

impl SignTensor {
    /// Encode the nonzero coordinates of a (pruned) dense buffer as
    /// presence + sign planes with a shared magnitude.
    ///
    /// Under `--features simd` the planes are built a word at a time
    /// (movemask-style: 32 lanes per u32, BMI2 `pext` sign compaction);
    /// [`SignTensor::encode_scalar`] is the bit-for-bit oracle the vector
    /// path is pinned against.
    pub fn encode(pruned: &[f32]) -> Self {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if crate::util::simd::active() {
            let (presence, signs, nnz) = crate::util::simd::sign_encode_planes(pruned);
            return Self::assemble(pruned, presence, signs, nnz);
        }
        Self::encode_scalar(pruned)
    }

    /// The scalar oracle: per-element plane pushes, exactly the loop the
    /// word-at-a-time encoder must reproduce bit for bit.
    pub(crate) fn encode_scalar(pruned: &[f32]) -> Self {
        let mut presence = vec![0u32; pruned.len().div_ceil(32)];
        let mut signs = Vec::new();
        let mut nnz = 0u32;
        for (i, &v) in pruned.iter().enumerate() {
            if v != 0.0 {
                presence[i / 32] |= 1 << (i % 32);
                let j = nnz as usize;
                if j % 32 == 0 {
                    signs.push(0);
                }
                if v < 0.0 {
                    signs[j / 32] |= 1 << (j % 32);
                }
                nnz += 1;
            }
        }
        Self::assemble(pruned, presence, signs, nnz)
    }

    /// Shared magnitude + header assembly. Mean |survivor| is computed as
    /// the striped Σ|x| over *all* elements (non-survivors are exactly
    /// ±0.0 and contribute +0.0), so scalar and simd builds — and both
    /// encode paths — produce identical magnitude bytes.
    fn assemble(pruned: &[f32], presence: Vec<u32>, signs: Vec<u32>, nnz: u32) -> Self {
        let magnitude = if nnz == 0 {
            0.0
        } else {
            (crate::util::simd::abs_sum_striped(pruned) / nnz as f64) as f32
        };
        Self {
            elems: checked_elems(pruned.len()),
            nnz,
            presence,
            signs,
            magnitude,
        }
    }

    pub fn wire_bytes(&self) -> u64 {
        sign_tensor_bytes(self.elems as usize, self.nnz as usize)
    }

    /// `dst[i] += alpha · value` over survivors, non-survivor lanes
    /// untouched — the slice-level sign fold shared by
    /// [`TensorUpdate::axpy_into`] and the codec's residual update
    /// (`alpha = −1`: `x + (−1)·v` is bit-identical to `x − v`).
    /// Dispatches to the word-at-a-time AVX2 fold under `--features
    /// simd`; the [`SignTensor::for_each_survivor`] walk is the oracle.
    pub fn axpy_into_slice(&self, alpha: f32, dst: &mut [f32]) {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if crate::util::simd::active() {
            crate::util::simd::sign_axpy_f32(&self.presence, &self.signs, self.magnitude, alpha, dst);
            return;
        }
        self.for_each_survivor(|i, v| dst[i] += alpha * v);
    }

    /// Visit `(element_index, decoded_value)` for every survivor, in
    /// index order. The decode primitive behind `axpy_into` and the
    /// codec's residual update.
    pub fn for_each_survivor(&self, mut f: impl FnMut(usize, f32)) {
        let mut ordinal = 0usize;
        for (w, &word) in self.presence.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let idx = w * 32 + b;
                let neg = (self.signs[ordinal / 32] >> (ordinal % 32)) & 1 == 1;
                f(idx, if neg { -self.magnitude } else { self.magnitude });
                ordinal += 1;
            }
        }
        debug_assert_eq!(ordinal, self.nnz as usize);
    }
}

/// Quantized code width of the v2 wire: 8- or 4-bit affine codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantBits {
    Q8,
    Q4,
}

impl QuantBits {
    /// Bits per survivor code.
    pub fn bits(self) -> usize {
        match self {
            QuantBits::Q8 => 8,
            QuantBits::Q4 => 4,
        }
    }

    /// Top quantization level (`2^bits − 1`): codes span `0..=levels`.
    pub fn levels(self) -> u32 {
        match self {
            QuantBits::Q8 => 255,
            QuantBits::Q4 => 15,
        }
    }

    /// Codes packed per u32 word.
    pub fn per_word(self) -> usize {
        32 / self.bits()
    }

    /// Code mask (`2^bits − 1` as a bit mask).
    pub fn mask(self) -> u32 {
        self.levels()
    }
}

/// Affine-quantized survivors of one tensor (the v2 `pruned`-mode wire):
/// the exact survivor *support* (sorted u32 offsets, shipped as a
/// raw-or-RLE presence bitmap), and the survivor *values* squeezed to
/// `bits`-wide affine codes `v ≈ zero + scale·q`. The quantization error
/// per survivor is ≤ `scale/2`, and the [`crate::comm::DeltaCodec`]
/// subtracts the *dequantized* values from its error-feedback residual,
/// so the error re-enters the next round's delta instead of biasing
/// training — the same mechanism that already absorbs pruning error.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    /// element count of the dense tensor this update applies to
    pub elems: u32,
    /// sorted survivor element offsets
    pub indices: Vec<u32>,
    /// code width (8- or 4-bit)
    pub bits: QuantBits,
    /// affine step: `(max − min) / levels` over survivor values, 0 when
    /// the survivors are constant or absent
    pub scale: f32,
    /// affine zero point: the minimum survivor value (codes are offsets
    /// above it, so they never go negative)
    pub zero: f32,
    /// packed codes, `per_word()` per u32, little-endian within the word
    pub codes: Vec<u32>,
}

impl QuantTensor {
    /// Encode the nonzero coordinates of a (pruned) dense buffer with
    /// `bits`-wide affine codes. The survivor scan reuses the sparse
    /// encoder (vectorized under `--features simd`); min/max and the
    /// quantize+pack pass dispatch through [`crate::util::simd`] with
    /// the scalar path as the bit-parity oracle.
    pub fn encode(pruned: &[f32], bits: QuantBits) -> Self {
        let sp = SparseTensor::encode(pruned);
        Self::from_survivors(sp.elems, sp.indices, &sp.values, bits)
    }

    /// Quantize an explicit survivor list (the encode core; also the
    /// merged-chain decode path's reconstruction check).
    pub fn from_survivors(elems: u32, indices: Vec<u32>, values: &[f32], bits: QuantBits) -> Self {
        debug_assert_eq!(indices.len(), values.len());
        let (lo, hi) = crate::util::simd::minmax(values);
        let scale = if hi > lo {
            (hi - lo) / bits.levels() as f32
        } else {
            0.0
        };
        let mut codes = Vec::new();
        match bits {
            QuantBits::Q8 => crate::util::simd::quantize_q8_into(values, lo, scale, &mut codes),
            QuantBits::Q4 => crate::util::simd::quantize_q4_into(values, lo, scale, &mut codes),
        }
        Self {
            elems,
            indices,
            bits,
            scale,
            zero: lo,
            codes,
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Code of survivor ordinal `j` (unpacked from the word plane).
    #[inline]
    pub fn code(&self, j: usize) -> u32 {
        let per = self.bits.per_word();
        (self.codes[j / per] >> ((j % per) * self.bits.bits())) & self.bits.mask()
    }

    /// Dequantized value of survivor ordinal `j`: `zero + scale·code`.
    /// Mul-then-add, never fused — the simd dequantize kernel performs
    /// the identical two rounded ops, so both paths agree bit for bit.
    #[inline]
    pub fn value(&self, j: usize) -> f32 {
        self.zero + self.scale * self.code(j) as f32
    }

    /// Visit `(element_index, dequantized_value)` for every survivor in
    /// index order — the decode primitive behind `axpy_into` and the
    /// codec's residual update.
    pub fn for_each_survivor(&self, mut f: impl FnMut(usize, f32)) {
        for (j, &i) in self.indices.iter().enumerate() {
            f(i as usize, self.value(j));
        }
    }

    /// Dequantize the full survivor value list into `out` (cleared
    /// first). Dispatches to the vectorized unpack+affine kernel under
    /// `--features simd`.
    pub fn dequantize_values(&self, out: &mut Vec<f32>) {
        match self.bits {
            QuantBits::Q8 => crate::util::simd::dequantize_q8_into(
                &self.codes,
                self.nnz(),
                self.zero,
                self.scale,
                out,
            ),
            QuantBits::Q4 => crate::util::simd::dequantize_q4_into(
                &self.codes,
                self.nnz(),
                self.zero,
                self.scale,
                out,
            ),
        }
    }

    /// Whether the v2 support plane ships RLE (strictly smaller than the
    /// raw bitmap) — the per-tensor flag bit of the header.
    pub fn uses_rle(&self) -> bool {
        rle_bytes_from_indices(self.elems as usize, &self.indices)
            < raw_bitmap_bytes(self.elems as usize)
    }

    pub fn wire_bytes(&self) -> u64 {
        quantized_tensor_bytes(
            support_bytes(self.elems as usize, &self.indices),
            self.nnz(),
            self.bits,
        )
    }
}

/// One tensor's delta on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorUpdate {
    Sparse(SparseTensor),
    Sign(SignTensor),
    /// v2 `pruned`-mode wire: affine int8/int4 survivor codes
    /// (`--wire-quant {q8,q4}`).
    Quantized(QuantTensor),
}

impl TensorUpdate {
    /// Element count of the dense tensor this update applies to.
    pub fn elems(&self) -> usize {
        match self {
            TensorUpdate::Sparse(t) => t.elems as usize,
            TensorUpdate::Sign(t) => t.elems as usize,
            TensorUpdate::Quantized(t) => t.elems as usize,
        }
    }

    /// Survivor (nonzero) count.
    pub fn survivors(&self) -> usize {
        match self {
            TensorUpdate::Sparse(t) => t.nnz(),
            TensorUpdate::Sign(t) => t.nnz as usize,
            TensorUpdate::Quantized(t) => t.nnz(),
        }
    }

    pub fn wire_bytes(&self) -> u64 {
        match self {
            TensorUpdate::Sparse(t) => t.wire_bytes(),
            TensorUpdate::Sign(t) => t.wire_bytes(),
            TensorUpdate::Quantized(t) => t.wire_bytes(),
        }
    }

    /// `dst += alpha · decode(self)` in O(nnz) — the FedAvg accumulation
    /// primitive. Panics (via [`Tensor::axpy_sparse`] / indexing) if the
    /// update addresses elements outside `dst`.
    pub fn axpy_into(&self, alpha: f32, dst: &mut Tensor) {
        assert_eq!(
            self.elems(),
            dst.len(),
            "update for {} elements applied to tensor of {}",
            self.elems(),
            dst.len()
        );
        match self {
            TensorUpdate::Sparse(t) => dst.axpy_sparse(alpha, &t.indices, &t.values),
            TensorUpdate::Sign(t) => t.axpy_into_slice(alpha, dst.data_mut()),
            TensorUpdate::Quantized(t) => {
                let d = dst.data_mut();
                t.for_each_survivor(|i, v| d[i] += alpha * v);
            }
        }
    }

    /// `dst[i] += alpha · decode(self)[i]` into an **f64** accumulator —
    /// the precision the FedAvg fold now carries so that per-worker
    /// contributions combine without f32 rounding drift
    /// ([`crate::coordinator::weighted_sparse_fedavg`]). Same O(nnz)
    /// walk and survivor order as [`TensorUpdate::axpy_into`].
    pub fn axpy_into_f64(&self, alpha: f64, dst: &mut [f64]) {
        assert_eq!(
            self.elems(),
            dst.len(),
            "update for {} elements applied to accumulator of {}",
            self.elems(),
            dst.len()
        );
        match self {
            TensorUpdate::Sparse(t) => {
                for (&i, &v) in t.indices.iter().zip(&t.values) {
                    dst[i as usize] += alpha * v as f64;
                }
            }
            TensorUpdate::Sign(t) => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if crate::util::simd::active() {
                    crate::util::simd::sign_axpy_f64(&t.presence, &t.signs, t.magnitude, alpha, dst);
                    return;
                }
                t.for_each_survivor(|i, v| dst[i] += alpha * v as f64)
            }
            TensorUpdate::Quantized(t) => t.for_each_survivor(|i, v| dst[i] += alpha * v as f64),
        }
    }

    /// Whether every shipped value is finite. A NaN/Inf survivor in an
    /// otherwise well-formed update would fold straight into the global
    /// model; the leader rejects such reports at the fold boundary
    /// (`RoundReport::rejected_reports`).
    pub fn all_finite(&self) -> bool {
        match self {
            TensorUpdate::Sparse(t) => t.values.iter().all(|v| v.is_finite()),
            TensorUpdate::Sign(t) => t.magnitude.is_finite(),
            // codes are integers; finite scale + zero ⇒ every
            // dequantized survivor is finite
            TensorUpdate::Quantized(t) => t.scale.is_finite() && t.zero.is_finite(),
        }
    }

    /// Decode to a dense buffer (tests / residual bookkeeping). Allocates;
    /// per-round paths should hold a scratch buffer and use
    /// [`TensorUpdate::decode_into`] instead.
    pub fn decode_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.elems()];
        self.decode_into(&mut out);
        out
    }

    /// Decode into a caller-provided dense scratch, overwriting every lane
    /// (`out.len()` must equal `self.elems()`). This is the allocation-free
    /// decode the leader threads one reusable buffer through instead of
    /// allocating a dense-size `Vec` per worker per round.
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(
            self.elems(),
            out.len(),
            "update for {} elements decoded into scratch of {}",
            self.elems(),
            out.len()
        );
        match self {
            TensorUpdate::Sparse(t) => {
                out.fill(0.0);
                for (&i, &v) in t.indices.iter().zip(&t.values) {
                    out[i as usize] = v;
                }
            }
            TensorUpdate::Sign(t) => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if crate::util::simd::active() {
                    crate::util::simd::sign_decode_into(&t.presence, &t.signs, t.magnitude, out);
                    return;
                }
                out.fill(0.0);
                t.for_each_survivor(|i, v| out[i] = v);
            }
            TensorUpdate::Quantized(t) => {
                out.fill(0.0);
                t.for_each_survivor(|i, v| out[i] = v);
            }
        }
    }
}

/// Whether a chain takes the merged (v2) encoding: ≥ 2 all-quantized
/// links. Off-mode chains carry `Sparse`/`Sign` links and keep the v1
/// per-link encoding bit for bit; a *single* quantized link also stays
/// v1 — its support bitmap already encodes every survivor position, so
/// the merged record's ordinal-gap plane (~1 byte per survivor) would
/// be pure overhead with nothing to share it against.
pub fn chain_is_quantized(links: &[Vec<TensorUpdate>]) -> bool {
    links.len() >= 2
        && links
            .iter()
            .all(|us| !us.is_empty() && us.iter().all(|u| matches!(u, TensorUpdate::Quantized(_))))
}

/// Union survivor support of tensor position `t` across a quantized
/// chain's links (sorted, deduped) — the one merged presence bitmap a
/// v2 chain ships instead of k per-link bitmaps.
pub fn chain_union_indices(links: &[Vec<TensorUpdate>], t: usize) -> Vec<u32> {
    let mut all: Vec<u32> = Vec::new();
    for us in links {
        if let TensorUpdate::Quantized(q) = &us[t] {
            all.extend_from_slice(&q.indices);
        }
    }
    all.sort_unstable();
    all.dedup();
    all
}

/// Visit the varint ordinal gaps that encode `indices` against the
/// merged `union` support: `d₀ = ord₀`, `dᵢ = ordᵢ − ordᵢ₋₁` (≥ 1),
/// where `ord` is the index's position in `union`. Top-k chains overlap
/// heavily round to round, so most gaps are 1 → one varint byte per
/// survivor instead of a fresh bitmap per link. Both sorted;
/// `indices ⊆ union` is the caller's invariant.
pub fn for_each_ordinal_gap(union: &[u32], indices: &[u32], mut f: impl FnMut(u64)) {
    let mut prev: Option<u64> = None;
    let mut u = 0usize;
    for &idx in indices {
        while union[u] != idx {
            u += 1;
        }
        let ord = u as u64;
        f(match prev {
            None => ord,
            Some(p) => ord - p,
        });
        prev = Some(ord);
        u += 1;
    }
}

/// Wire bytes of a merged (v2) chain — the normative formula
/// (`docs/TRANSFER_MODEL.md` §Wire v2):
///
/// `8 + Σ_t [9 + support(E_t, union_t) + Σ_ℓ (9 + varint(nnz_ℓₜ)
///  + Σ varint(gaps) + quant_code_bytes(nnz_ℓₜ))]`
///
/// — one shared support plane per tensor where the v1 chain paid one
/// per link per tensor. Requires [`chain_is_quantized`].
///
/// ```
/// use efficientgrad::comm::wire::{merged_chain_bytes, QuantBits, QuantTensor, TensorUpdate};
/// // one 64-element tensor, two links: survivors 0..10 and 5..15
/// let mut a = vec![0.0f32; 64];
/// let mut b = vec![0.0f32; 64];
/// for i in 0..10 { a[i] = 1.0 + i as f32; }
/// for i in 5..15 { b[i] = -(1.0 + i as f32); }
/// let l1 = vec![TensorUpdate::Quantized(QuantTensor::encode(&a, QuantBits::Q8))];
/// let l2 = vec![TensorUpdate::Quantized(QuantTensor::encode(&b, QuantBits::Q8))];
/// // union = 0..15: RLE runs [0, 15, 49] → 3 B beats the 8 B raw bitmap.
/// // each link: 9 B header + varint(10) + ten 1-B gaps + 3 code words
/// assert_eq!(merged_chain_bytes(&[l1, l2]), 8 + (9 + 3) + (9 + 1 + 10 + 12) * 2);
/// ```
pub fn merged_chain_bytes(links: &[Vec<TensorUpdate>]) -> u64 {
    debug_assert!(chain_is_quantized(links));
    let mut bytes = CHAIN_HEADER_BYTES;
    for t in 0..links[0].len() {
        let union = chain_union_indices(links, t);
        let elems = links[0][t].elems();
        bytes += MERGED_TENSOR_HEADER_BYTES + support_bytes(elems, &union);
        for us in links {
            let TensorUpdate::Quantized(q) = &us[t] else {
                unreachable!("chain_is_quantized checked")
            };
            bytes += MERGED_LINK_HEADER_BYTES + varint_len(q.nnz() as u64);
            for_each_ordinal_gap(&union, &q.indices, |d| bytes += varint_len(d));
            bytes += quant_code_bytes(q.nnz(), q.bits);
        }
    }
    bytes
}

/// One full model exchange (uplink or downlink).
#[derive(Clone, Debug, PartialEq)]
pub enum ModelUpdate {
    /// Full parameter snapshot — the legacy format, still used by
    /// `comm = dense`, by the first round of every compressed run, and to
    /// resync a worker that missed a downlink.
    Dense(Vec<Tensor>),
    /// Pruned delta, one [`TensorUpdate`] per param tensor in store order.
    Delta(Vec<TensorUpdate>),
    /// Chained downlink: the retained per-round deltas a worker missed,
    /// oldest first. Applying the chain replays exactly the per-round
    /// downlinks (same float ops, same order), so the receiver's replica
    /// lands bit-identical to a peer that caught every round — at
    /// `8 + Σ link` wire bytes ([`chained_model_bytes`]) instead of a
    /// dense `4·P` resync. Downlink-only; never a valid uplink.
    Chain(Vec<Vec<TensorUpdate>>),
}

impl ModelUpdate {
    /// Bytes this message occupies on the wire (normative formulas above;
    /// the dense variant is headerless `4·P`, matching the pre-comm
    /// network accounting bit for bit).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            ModelUpdate::Dense(ts) => ts.iter().map(|t| dense_tensor_bytes(t.len())).sum(),
            ModelUpdate::Delta(us) => us.iter().map(TensorUpdate::wire_bytes).sum(),
            // all-quantized chains (wire-quant on) take the merged v2
            // encoding; everything else keeps the v1 per-link formula,
            // so `--wire-quant off` ledgers are bit-for-bit legacy
            ModelUpdate::Chain(links) if chain_is_quantized(links) => merged_chain_bytes(links),
            ModelUpdate::Chain(links) => chained_model_bytes(
                links
                    .iter()
                    .map(|us| us.iter().map(TensorUpdate::wire_bytes).sum()),
            ),
        }
    }

    /// Total survivors across tensors (0 for the dense variant — every
    /// element travels, "survivor" is a delta-format notion; a chain
    /// sums its links).
    pub fn survivors(&self) -> u64 {
        match self {
            ModelUpdate::Dense(_) => 0,
            ModelUpdate::Delta(us) => us.iter().map(|u| u.survivors() as u64).sum(),
            ModelUpdate::Chain(links) => links
                .iter()
                .flat_map(|us| us.iter())
                .map(|u| u.survivors() as u64)
                .sum(),
        }
    }

    /// True for the dense-snapshot variant.
    pub fn is_dense(&self) -> bool {
        matches!(self, ModelUpdate::Dense(_))
    }

    /// Whether every value in the message is finite (see
    /// [`TensorUpdate::all_finite`]).
    pub fn all_finite(&self) -> bool {
        match self {
            ModelUpdate::Dense(ts) => ts.iter().all(|t| t.data().iter().all(|v| v.is_finite())),
            ModelUpdate::Delta(us) => us.iter().all(TensorUpdate::all_finite),
            ModelUpdate::Chain(links) => links
                .iter()
                .all(|us| us.iter().all(TensorUpdate::all_finite)),
        }
    }

    /// True for the chained-downlink variant.
    pub fn is_chain(&self) -> bool {
        matches!(self, ModelUpdate::Chain(_))
    }

    /// Materialize this update into `params`: a dense snapshot replaces
    /// them (an empty `params` bootstraps from any snapshot), a delta
    /// accumulates into them (`alpha = 1`). Leader and workers apply
    /// every *delta* downlink through this one function, which is what
    /// keeps their reference replicas bit-identical; dense snapshots may
    /// also move directly into a replica (the worker's dense-mode path
    /// does, to skip the clone) — replacement has no float math, so the
    /// lockstep guarantee is unaffected.
    pub fn apply(&self, params: &mut Vec<Tensor>) -> Result<()> {
        match self {
            ModelUpdate::Dense(ts) => {
                if !params.is_empty() && params.len() != ts.len() {
                    bail!("dense update has {} tensors, store {}", ts.len(), params.len());
                }
                *params = ts.clone();
            }
            ModelUpdate::Delta(us) => {
                validate_delta(us, params)?;
                apply_delta(us, params);
            }
            ModelUpdate::Chain(links) => {
                // validate every link before mutating anything: a chain
                // that fails halfway would leave the replica at an
                // intermediate version its peer has no record of
                for us in links {
                    validate_delta(us, params)?;
                }
                // oldest first — exactly the per-round downlink replay
                for us in links {
                    apply_delta(us, params);
                }
            }
        }
        Ok(())
    }
}

/// Shape-check one per-round delta against the replica it would mutate
/// (a half-applied delta would silently desync the replica from its
/// peer, so callers validate everything before touching anything).
fn validate_delta(us: &[TensorUpdate], params: &[Tensor]) -> Result<()> {
    if params.len() != us.len() {
        bail!("delta update has {} tensors, store {}", us.len(), params.len());
    }
    for (u, p) in us.iter().zip(params.iter()) {
        if u.elems() != p.len() {
            bail!("delta tensor sized {} applied to {}", u.elems(), p.len());
        }
    }
    Ok(())
}

fn apply_delta(us: &[TensorUpdate], params: &mut [Tensor]) {
    for (u, p) in us.iter().zip(params.iter_mut()) {
        u.axpy_into(1.0, p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_encode_decode_roundtrip() {
        let pruned = [0.0f32, 1.5, 0.0, -2.0, 0.0, 0.25];
        let t = SparseTensor::encode(&pruned);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.indices, vec![1, 3, 5]);
        assert_eq!(t.wire_bytes(), sparse_tensor_bytes(3));
        let u = TensorUpdate::Sparse(t);
        assert_eq!(u.decode_dense(), pruned.to_vec());
    }

    #[test]
    fn sign_encode_preserves_support_and_signs() {
        let pruned = [0.0f32, 2.0, 0.0, -2.0, 2.0];
        let t = SignTensor::encode(&pruned);
        assert_eq!(t.nnz, 3);
        assert_eq!(t.magnitude, 2.0);
        let decoded = TensorUpdate::Sign(t).decode_dense();
        assert_eq!(decoded, pruned.to_vec()); // equal magnitudes: exact
    }

    #[test]
    fn sign_shared_magnitude_is_mean_abs() {
        let pruned = [1.0f32, -3.0, 0.0];
        let t = SignTensor::encode(&pruned);
        assert_eq!(t.magnitude, 2.0);
        let decoded = TensorUpdate::Sign(t).decode_dense();
        assert_eq!(decoded, vec![2.0, -2.0, 0.0]);
    }

    #[test]
    fn sign_bit_planes_cross_word_boundaries() {
        // 70 elements, all surviving, alternating signs: exercises both
        // planes past one u32 word
        let pruned: Vec<f32> = (0..70)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let t = SignTensor::encode(&pruned);
        assert_eq!(t.nnz, 70);
        assert_eq!(t.presence.len(), 3);
        assert_eq!(t.signs.len(), 3);
        assert_eq!(t.wire_bytes(), sign_tensor_bytes(70, 70));
        assert_eq!(TensorUpdate::Sign(t).decode_dense(), pruned);
    }

    #[test]
    fn empty_and_full_sparsity_edges() {
        // all-zero buffer: headers only, decode is all zeros
        let z = [0.0f32; 40];
        let s = SparseTensor::encode(&z);
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.wire_bytes(), SPARSE_TENSOR_HEADER_BYTES);
        let g = SignTensor::encode(&z);
        assert_eq!(g.nnz, 0);
        assert_eq!(g.magnitude, 0.0);
        assert_eq!(TensorUpdate::Sign(g).decode_dense(), z.to_vec());
        // zero-length tensor
        let e = SparseTensor::encode(&[]);
        assert_eq!(e.elems, 0);
        assert_eq!(TensorUpdate::Sparse(e).decode_dense(), Vec::<f32>::new());
    }

    #[test]
    fn axpy_into_accumulates_weighted() {
        let mut dst = Tensor::ones(&[4]);
        let u = TensorUpdate::Sparse(SparseTensor::encode(&[0.0, 2.0, 0.0, -4.0]));
        u.axpy_into(0.5, &mut dst);
        assert_eq!(dst.data(), &[1.0, 2.0, 1.0, -1.0]);
    }

    #[test]
    fn model_update_apply_dense_and_delta() {
        let mut params = vec![Tensor::zeros(&[3])];
        let dense = ModelUpdate::Dense(vec![Tensor::full(&[3], 2.0)]);
        dense.apply(&mut params).unwrap();
        assert_eq!(params[0].data(), &[2.0, 2.0, 2.0]);
        let delta =
            ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[0.0, 1.0, 0.0]))]);
        delta.apply(&mut params).unwrap();
        assert_eq!(params[0].data(), &[2.0, 3.0, 2.0]);
        // shape mismatch is an error, not corruption
        let bad = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[0.0]))]);
        assert!(bad.apply(&mut params).is_err());
        let bad_count = ModelUpdate::Delta(vec![]);
        assert!(bad_count.apply(&mut params).is_err());
    }

    #[test]
    fn chain_applies_links_in_order_and_prices_the_header() {
        let mut params = vec![Tensor::zeros(&[3])];
        let l1 = vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0, 0.0, 0.0]))];
        let l2 = vec![TensorUpdate::Sparse(SparseTensor::encode(&[0.0, 2.0, -1.0]))];
        let chain = ModelUpdate::Chain(vec![l1.clone(), l2.clone()]);
        assert!(chain.is_chain() && !chain.is_dense());
        // bytes: the documented formula — header + each link priced as
        // the per-round delta it replays
        assert_eq!(
            chain.wire_bytes(),
            chained_model_bytes(
                [sparse_tensor_bytes(1), sparse_tensor_bytes(2)].into_iter()
            )
        );
        assert_eq!(chain.survivors(), 3);
        chain.apply(&mut params).unwrap();
        // == applying l1 then l2 individually
        let mut replay = vec![Tensor::zeros(&[3])];
        ModelUpdate::Delta(l1).apply(&mut replay).unwrap();
        ModelUpdate::Delta(l2).apply(&mut replay).unwrap();
        assert_eq!(params, replay);
        // a bad link anywhere rejects the whole chain without mutating
        let before = params.clone();
        let bad = ModelUpdate::Chain(vec![
            vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0, 0.0, 0.0]))],
            vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0]))], // wrong size
        ]);
        assert!(bad.apply(&mut params).is_err());
        assert_eq!(params, before, "failed chain must not half-apply");
    }

    #[test]
    fn all_finite_flags_nan_and_inf_payloads() {
        let ok = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0, 0.0]))]);
        assert!(ok.all_finite());
        let nan_sparse = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor {
            elems: 2,
            indices: vec![0],
            values: vec![f32::NAN],
        })]);
        assert!(!nan_sparse.all_finite());
        let mut sign = SignTensor::encode(&[1.0, -1.0]);
        sign.magnitude = f32::INFINITY;
        assert!(!ModelUpdate::Delta(vec![TensorUpdate::Sign(sign.clone())]).all_finite());
        assert!(!ModelUpdate::Chain(vec![vec![TensorUpdate::Sign(sign)]]).all_finite());
        let dense = ModelUpdate::Dense(vec![Tensor::new(vec![2], vec![0.0, f32::NAN])]);
        assert!(!dense.all_finite());
    }

    #[test]
    fn checked_elems_accepts_the_full_u32_range() {
        assert_eq!(checked_elems(0), 0);
        assert_eq!(checked_elems(42_000), 42_000);
        assert_eq!(checked_elems(u32::MAX as usize), u32::MAX);
    }

    #[test]
    #[should_panic(expected = "exceeds the u32 wire index space")]
    fn checked_elems_panics_past_u32() {
        checked_elems(u32::MAX as usize + 1);
    }

    #[test]
    fn quant_encode_decode_roundtrip_within_half_scale() {
        let pruned = [0.0f32, 1.5, 0.0, -2.0, 0.0, 0.25, 3.75, 0.0];
        for bits in [QuantBits::Q8, QuantBits::Q4] {
            let t = QuantTensor::encode(&pruned, bits);
            assert_eq!(t.elems, 8);
            assert_eq!(t.indices, vec![1, 3, 5, 6]);
            assert_eq!(t.zero, -2.0);
            assert_eq!(t.scale, (3.75 - -2.0) / bits.levels() as f32);
            let decoded = TensorUpdate::Quantized(t.clone()).decode_dense();
            for (i, (&d, &p)) in decoded.iter().zip(&pruned).enumerate() {
                if p == 0.0 {
                    assert_eq!(d, 0.0, "non-survivor lane {i} touched");
                } else {
                    assert!(
                        (d - p).abs() <= t.scale / 2.0 + 1e-6,
                        "survivor {i}: {p} decoded {d}, scale {}",
                        t.scale
                    );
                }
            }
            // min and max survivors land exactly on codes 0 / levels
            assert_eq!(decoded[3], -2.0);
            assert!((decoded[6] - 3.75).abs() < 1e-5);
        }
    }

    #[test]
    fn quant_constant_and_empty_survivors_are_exact() {
        // all survivors equal: scale 0, every code 0, decode exact
        let t = QuantTensor::encode(&[0.0f32, 0.5, 0.5, 0.0], QuantBits::Q4);
        assert_eq!(t.scale, 0.0);
        assert_eq!(t.zero, 0.5);
        assert_eq!(
            TensorUpdate::Quantized(t).decode_dense(),
            vec![0.0, 0.5, 0.5, 0.0]
        );
        // no survivors at all
        let e = QuantTensor::encode(&[0.0f32; 5], QuantBits::Q8);
        assert_eq!(e.nnz(), 0);
        assert_eq!(e.scale, 0.0);
        assert_eq!(e.zero, 0.0);
        assert_eq!(TensorUpdate::Quantized(e).decode_dense(), vec![0.0f32; 5]);
    }

    #[test]
    fn quant_wire_bytes_match_documented_formula() {
        // 70 elements, scattered survivors: raw bitmap support (RLE
        // loses on scattered bits), 8-bit codes
        let mut buf = vec![0.0f32; 70];
        for i in (0..70).step_by(3) {
            buf[i] = i as f32 + 1.0;
        }
        let t = QuantTensor::encode(&buf, QuantBits::Q8);
        let nnz = t.nnz();
        assert_eq!(nnz, 24);
        assert!(!t.uses_rle(), "alternating support should keep raw bitmap");
        assert_eq!(
            t.wire_bytes(),
            QUANT_TENSOR_HEADER_BYTES + raw_bitmap_bytes(70) + quant_code_bytes(nnz, QuantBits::Q8)
        );
        // one dense run: RLE wins and the flag flips
        let mut run = vec![0.0f32; 1000];
        for v in run.iter_mut().skip(100).take(200) {
            *v = 1.0;
        }
        let r = QuantTensor::encode(&run, QuantBits::Q4);
        assert!(r.uses_rle());
        assert_eq!(
            r.wire_bytes(),
            QUANT_TENSOR_HEADER_BYTES
                + rle_bytes_from_indices(1000, &r.indices)
                + quant_code_bytes(200, QuantBits::Q4)
        );
        assert!(r.wire_bytes() < QUANT_TENSOR_HEADER_BYTES + raw_bitmap_bytes(1000));
    }

    #[test]
    fn rle_roundtrips_and_matches_index_accounting() {
        for len in [0usize, 1, 31, 32, 33, 63, 64, 65, 200] {
            for pat in 0..4u32 {
                let bitmap: Vec<u32> = (0..len.div_ceil(32))
                    .map(|w| match pat {
                        0 => 0,
                        1 => u32::MAX,
                        2 => 0x0F0F_0F0F,
                        _ => (w as u32).wrapping_mul(0x9E37_79B9),
                    })
                    .collect();
                // mask tail bits clear like every real presence plane
                let mut bitmap = bitmap;
                if len % 32 != 0 {
                    if let Some(last) = bitmap.last_mut() {
                        *last &= (1u32 << (len % 32)) - 1;
                    }
                }
                let rle = bitmap_rle_encode(&bitmap, len);
                assert_eq!(bitmap_rle_decode(&rle, len).unwrap(), bitmap, "len {len} pat {pat}");
                let indices: Vec<u32> = (0..len as u32)
                    .filter(|&i| bitmap[i as usize / 32] >> (i % 32) & 1 == 1)
                    .collect();
                assert_eq!(
                    rle.len() as u64,
                    rle_bytes_from_indices(len, &indices),
                    "len {len} pat {pat}"
                );
                assert_eq!(presence_bitmap(len, &indices), bitmap);
            }
        }
        // corrupt streams are rejected, not mis-decoded
        assert!(bitmap_rle_decode(&[200, 1], 10).is_err()); // overruns
        let good = bitmap_rle_encode(&[0b11], 2);
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(bitmap_rle_decode(&trailing, 2).is_err());
    }

    #[test]
    fn merged_chain_wire_bytes_and_quantized_detection() {
        let mut a = vec![0.0f32; 64];
        let mut b = vec![0.0f32; 64];
        for i in 0..10 {
            a[i] = 1.0 + i as f32;
        }
        for i in 5..15 {
            b[i] = -(1.0 + i as f32);
        }
        let l1 = vec![TensorUpdate::Quantized(QuantTensor::encode(&a, QuantBits::Q8))];
        let l2 = vec![TensorUpdate::Quantized(QuantTensor::encode(&b, QuantBits::Q8))];
        assert!(chain_is_quantized(&[l1.clone(), l2.clone()]));
        assert_eq!(chain_union_indices(&[l1.clone(), l2.clone()], 0), (0u32..15).collect::<Vec<_>>());
        let chain = ModelUpdate::Chain(vec![l1.clone(), l2.clone()]);
        assert_eq!(chain.wire_bytes(), merged_chain_bytes(&[l1.clone(), l2]));
        // a merged chain always beats the legacy f32 per-link encoding
        let s1 = vec![TensorUpdate::Sparse(SparseTensor::encode(&a))];
        let s2 = vec![TensorUpdate::Sparse(SparseTensor::encode(&b))];
        let legacy = ModelUpdate::Chain(vec![s1, s2]);
        assert!(!chain_is_quantized(match &legacy {
            ModelUpdate::Chain(ls) => ls,
            _ => unreachable!(),
        }));
        assert!(chain.wire_bytes() < legacy.wire_bytes());
        // mixed chains fall back to the v1 per-link formula
        let mixed = vec![
            vec![TensorUpdate::Quantized(QuantTensor::encode(&a, QuantBits::Q8))],
            vec![TensorUpdate::Sparse(SparseTensor::encode(&b))],
        ];
        assert!(!chain_is_quantized(&mixed));
        // a single quantized link stays v1 too: its bitmap already codes
        // the support, so the ordinal plane would only add bytes
        assert!(!chain_is_quantized(&[l1.clone()]));
        assert_eq!(
            ModelUpdate::Chain(vec![l1.clone()]).wire_bytes(),
            chained_model_bytes([l1.iter().map(TensorUpdate::wire_bytes).sum()].into_iter())
        );
        let mu = ModelUpdate::Chain(mixed.clone());
        assert_eq!(
            mu.wire_bytes(),
            chained_model_bytes(mixed.iter().map(|us| us.iter().map(TensorUpdate::wire_bytes).sum()))
        );
    }

    #[test]
    fn ordinal_gaps_rebuild_link_support() {
        let union = vec![2u32, 5, 9, 10, 11, 40];
        let link = vec![5u32, 10, 11, 40];
        let mut gaps = Vec::new();
        for_each_ordinal_gap(&union, &link, |d| gaps.push(d));
        assert_eq!(gaps, vec![1, 2, 1, 1]);
        // replaying the gaps through the union recovers the link exactly
        let mut ord = 0u64;
        let mut rebuilt = Vec::new();
        for (k, &d) in gaps.iter().enumerate() {
            ord = if k == 0 { d } else { ord + d };
            rebuilt.push(union[ord as usize]);
        }
        assert_eq!(rebuilt, link);
    }

    #[test]
    fn wire_bytes_match_documented_formulas() {
        let dense = ModelUpdate::Dense(vec![Tensor::zeros(&[10]), Tensor::zeros(&[5])]);
        assert_eq!(dense.wire_bytes(), 4 * 15);
        assert_eq!(dense.survivors(), 0);
        let pruned = [1.0f32, 0.0, -1.0, 0.0, 0.0];
        let delta = ModelUpdate::Delta(vec![
            TensorUpdate::Sparse(SparseTensor::encode(&pruned)),
            TensorUpdate::Sign(SignTensor::encode(&pruned)),
        ]);
        assert_eq!(
            delta.wire_bytes(),
            sparse_tensor_bytes(2) + sign_tensor_bytes(5, 2)
        );
        assert_eq!(delta.survivors(), 4);
    }
}
