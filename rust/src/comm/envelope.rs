//! Integrity-checked framing for the federated channel.
//!
//! Workers are threads in this simulation, so PR 3's wire formats travel
//! as structs; this module is the missing byte layer under them — the
//! piece a real transport (ROADMAP item 1, "coordinator as a service")
//! would put on the socket, and the piece the fault-injection harness
//! ([`crate::faults`]) needs so a flipped bit is *detected and rejected*
//! instead of silently folded into the global model.
//!
//! Every `ModelUpdate` / `WorkerReport` payload is sealed into a
//! [`Frame`]: a fixed 24-byte header (magic, schema version, payload
//! kind, payload length, FNV-1a-64 checksum) followed by the serialized
//! payload. [`Frame::open`] verifies all five fields before a caller
//! ever sees payload bytes; corrupt, truncated, duplicated-length or
//! wrong-schema frames come back as errors, never as updates. A
//! single-byte flip anywhere in a frame is always caught: FNV-1a's
//! per-byte step `h ← (h ⊕ b)·prime` is injective in `h`, so two
//! payloads differing in one byte can never collide, and header flips
//! fail the magic/version/length checks directly.
//!
//! Envelope overhead is a flat [`FRAME_HEADER_BYTES`] = 24 bytes per
//! frame, independent of payload size (`docs/TRANSFER_MODEL.md`
//! §Integrity & recovery):
//!
//! ```
//! use efficientgrad::comm::envelope::{Frame, FrameKind, FRAME_HEADER_BYTES};
//! assert_eq!(FRAME_HEADER_BYTES, 24);
//! let empty = Frame::seal(FrameKind::Nack, &[]);
//! assert_eq!(empty.wire_bytes(), FRAME_HEADER_BYTES);
//! let framed = Frame::seal(FrameKind::Report, &[7u8; 1000]);
//! assert_eq!(framed.wire_bytes(), 1000 + FRAME_HEADER_BYTES);
//! ```

use anyhow::{bail, Context, Result};

use crate::comm::wire::{ModelUpdate, SignTensor, SparseTensor, TensorUpdate};
use crate::tensor::Tensor;

/// Wire schema version sealed into every frame. Bump on any layout
/// change to `encode_update` / the report encoding; old decoders then
/// reject new frames outright instead of misparsing them.
pub const SCHEMA_VERSION: u16 = 1;

/// Fixed per-frame envelope overhead in bytes: 4 magic + 2 version +
/// 2 kind + 8 payload length + 8 checksum.
pub const FRAME_HEADER_BYTES: u64 = 24;

const MAGIC: &[u8; 4] = b"EGFR";

/// FNV-1a 64-bit over a byte slice — the per-payload digest. Chosen for
/// the same reason the params checkpoint hand-rolls its footer: zero
/// dependencies, one multiply per byte, and guaranteed detection of any
/// single-byte corruption (each step is injective in the running hash).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// What a frame's payload claims to be. Sealed into the header so a
/// report can never be misparsed as an update (or vice versa).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Downlink: a serialized [`ModelUpdate`].
    Update = 1,
    /// Uplink: a serialized `WorkerReport`.
    Report = 2,
    /// Uplink: worker could not open/apply its downlink; empty payload.
    Nack = 3,
    /// Transport downlink: a full `WorkerTask` (round header + the inner
    /// sealed [`FrameKind::Update`] frame, byte-for-byte as dispatched).
    Task = 4,
    /// Transport uplink: worker finished one task; empty payload. Plays
    /// the role the in-process reply channel's hangup plays.
    RoundDone = 5,
    /// Transport handshake, worker → coordinator: worker id + config hash.
    Hello = 6,
    /// Transport handshake, coordinator → worker: admission granted.
    Welcome = 7,
    /// Transport liveness probe; empty payload, either direction.
    Heartbeat = 8,
    /// Transport farewell: the peer is closing this connection cleanly.
    Goodbye = 9,
    /// Transport control, coordinator → worker: send back a snapshot.
    Capture = 10,
    /// Transport control, worker → coordinator: a serialized snapshot.
    Snapshot = 11,
    /// Transport control, coordinator → worker: restore from snapshot.
    Restore = 12,
    /// Transport control, worker → coordinator: restore applied; empty.
    RestoreAck = 13,
}

impl FrameKind {
    /// Decode a header kind field. Public so the transport layer can
    /// *route* a frame by its claimed kind without opening it — payload
    /// bytes still only leave through [`Frame::open`].
    pub fn from_u16(v: u16) -> Result<Self> {
        Ok(match v {
            1 => FrameKind::Update,
            2 => FrameKind::Report,
            3 => FrameKind::Nack,
            4 => FrameKind::Task,
            5 => FrameKind::RoundDone,
            6 => FrameKind::Hello,
            7 => FrameKind::Welcome,
            8 => FrameKind::Heartbeat,
            9 => FrameKind::Goodbye,
            10 => FrameKind::Capture,
            11 => FrameKind::Snapshot,
            12 => FrameKind::Restore,
            13 => FrameKind::RestoreAck,
            other => bail!("unknown frame kind {other}"),
        })
    }
}

/// One sealed message: header + payload, as the bytes a socket would
/// carry. Mutable access to the raw bytes exists so the fault harness
/// can corrupt frames exactly where a radio would.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame(Vec<u8>);

impl Frame {
    /// Seal a payload: compute length + checksum, prepend the header.
    pub fn seal(kind: FrameKind, payload: &[u8]) -> Self {
        let mut buf = Vec::with_capacity(FRAME_HEADER_BYTES as usize + payload.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&SCHEMA_VERSION.to_le_bytes());
        buf.extend_from_slice(&(kind as u16).to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        buf.extend_from_slice(&fnv1a64(payload).to_le_bytes());
        buf.extend_from_slice(payload);
        Frame(buf)
    }

    /// Verify magic, schema version, kind, length and checksum; return
    /// the payload only if all five hold. This is the *only* way payload
    /// bytes leave a frame — there is no unchecked accessor.
    pub fn open(&self) -> Result<(FrameKind, &[u8])> {
        let b = &self.0;
        if b.len() < FRAME_HEADER_BYTES as usize {
            bail!("frame truncated: {} bytes < {}-byte header", b.len(), FRAME_HEADER_BYTES);
        }
        if &b[0..4] != MAGIC {
            bail!("bad frame magic {:02x?}", &b[0..4]);
        }
        let version = u16::from_le_bytes([b[4], b[5]]);
        if version != SCHEMA_VERSION {
            bail!("frame schema v{version}, this build speaks v{SCHEMA_VERSION}");
        }
        let kind = FrameKind::from_u16(u16::from_le_bytes([b[6], b[7]]))?;
        let len = u64::from_le_bytes(b[8..16].try_into().unwrap());
        let payload = &b[FRAME_HEADER_BYTES as usize..];
        if len != payload.len() as u64 {
            bail!("frame length field {len} != payload {} bytes", payload.len());
        }
        let want = u64::from_le_bytes(b[16..24].try_into().unwrap());
        let got = fnv1a64(payload);
        if want != got {
            bail!("frame checksum mismatch: header {want:#018x}, payload {got:#018x}");
        }
        Ok((kind, payload))
    }

    /// Total bytes on the wire (header + payload).
    pub fn wire_bytes(&self) -> u64 {
        self.0.len() as u64
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.0
    }

    /// Raw byte access for the fault harness — corruption happens on
    /// the sealed bytes, exactly where a flaky link would flip them.
    pub fn bytes_mut(&mut self) -> &mut Vec<u8> {
        &mut self.0
    }

    /// Rehydrate a frame from bytes read off a socket. Deliberately
    /// unchecked: a `Frame` is just a byte container, and [`Frame::open`]
    /// remains the only gate through which payload bytes escape — wire
    /// garbage arrives as a frame that then fails to open, exactly like
    /// a fault-harness corruption.
    pub fn from_wire(bytes: Vec<u8>) -> Frame {
        Frame(bytes)
    }
}

/// Little-endian payload serializer (the counterpart of [`ByteReader`]).
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f32 by raw bits — bit-preserving through the roundtrip (NaN
    /// payloads included, which the fold-boundary finiteness check then
    /// rejects *after* an honest decode).
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes, verbatim — for nested already-sealed frames (the
    /// transport's task messages carry the downlink frame unmodified, so
    /// fault-injected damage travels bit-for-bit).
    pub fn put_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Checked little-endian payload reader: every read is bounds-checked
/// and every collection length is validated against the bytes actually
/// remaining *before* allocation, so a forged length field can neither
/// panic the decoder nor make it balloon memory.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("payload truncated: wanted {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read `n` u32s after checking `4·n` bytes remain.
    pub fn get_u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Read `n` f32s after checking `4·n` bytes remain.
    pub fn get_f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Read `n` raw bytes after checking they remain (the counterpart of
    /// [`ByteWriter::put_raw`] — the caller owns any further validation,
    /// e.g. a nested frame's own [`Frame::open`]).
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Fail if payload bytes remain — trailing garbage is a schema
    /// violation, not padding.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("{} trailing bytes after payload", self.remaining());
        }
        Ok(())
    }
}

const UPDATE_DENSE: u8 = 0;
const UPDATE_DELTA: u8 = 1;
const UPDATE_CHAIN: u8 = 2;
const TU_SPARSE: u8 = 0;
const TU_SIGN: u8 = 1;

/// Serialize a [`ModelUpdate`] payload (the downlink body; uplink delta
/// reports embed the same delta encoding inside the report payload).
pub fn encode_update(u: &ModelUpdate) -> Vec<u8> {
    let mut w = ByteWriter::new();
    write_update(&mut w, u);
    w.into_bytes()
}

pub(crate) fn write_update(w: &mut ByteWriter, u: &ModelUpdate) {
    match u {
        ModelUpdate::Dense(ts) => {
            w.put_u8(UPDATE_DENSE);
            w.put_u32(ts.len() as u32);
            for t in ts {
                w.put_u32(t.shape().len() as u32);
                for &d in t.shape() {
                    w.put_u32(d as u32);
                }
                for &v in t.data() {
                    w.put_f32(v);
                }
            }
        }
        ModelUpdate::Delta(us) => {
            w.put_u8(UPDATE_DELTA);
            write_delta(w, us);
        }
        ModelUpdate::Chain(links) => {
            w.put_u8(UPDATE_CHAIN);
            w.put_u32(links.len() as u32);
            for us in links {
                write_delta(w, us);
            }
        }
    }
}

fn write_delta(w: &mut ByteWriter, us: &[TensorUpdate]) {
    w.put_u32(us.len() as u32);
    for u in us {
        match u {
            TensorUpdate::Sparse(t) => {
                w.put_u8(TU_SPARSE);
                w.put_u32(t.elems);
                w.put_u32(t.indices.len() as u32);
                for &i in &t.indices {
                    w.put_u32(i);
                }
                for &v in &t.values {
                    w.put_f32(v);
                }
            }
            TensorUpdate::Sign(t) => {
                w.put_u8(TU_SIGN);
                w.put_u32(t.elems);
                w.put_u32(t.nnz);
                w.put_f32(t.magnitude);
                for &p in &t.presence {
                    w.put_u32(p);
                }
                for &s in &t.signs {
                    w.put_u32(s);
                }
            }
        }
    }
}

/// Decode a [`ModelUpdate`] payload, validating every structural
/// invariant the apply path relies on (index bounds, bitmap popcounts,
/// tensor shapes) so a decoded update can never panic downstream.
pub fn decode_update(payload: &[u8]) -> Result<ModelUpdate> {
    let mut r = ByteReader::new(payload);
    let u = read_update(&mut r)?;
    r.finish()?;
    Ok(u)
}

pub(crate) fn read_update(r: &mut ByteReader) -> Result<ModelUpdate> {
    Ok(match r.get_u8().context("update tag")? {
        UPDATE_DENSE => {
            let n = r.get_u32()? as usize;
            let mut ts = Vec::with_capacity(n.min(r.remaining()));
            for _ in 0..n {
                let rank = r.get_u32()? as usize;
                if rank > 8 {
                    bail!("dense tensor rank {rank} exceeds limit 8");
                }
                let mut shape = Vec::with_capacity(rank);
                let mut elems: usize = 1;
                for _ in 0..rank {
                    let d = r.get_u32()? as usize;
                    elems = elems
                        .checked_mul(d)
                        .filter(|&e| e <= r.remaining())
                        .context("dense tensor shape overflows payload")?;
                    shape.push(d);
                }
                let data = r.get_f32s(elems)?;
                ts.push(Tensor::new(shape, data));
            }
            ModelUpdate::Dense(ts)
        }
        UPDATE_DELTA => ModelUpdate::Delta(read_delta(r)?),
        UPDATE_CHAIN => {
            let links = r.get_u32()? as usize;
            if links > r.remaining() {
                bail!("chain claims {links} links in {} bytes", r.remaining());
            }
            let mut out = Vec::with_capacity(links);
            for _ in 0..links {
                out.push(read_delta(r)?);
            }
            ModelUpdate::Chain(out)
        }
        other => bail!("unknown update tag {other}"),
    })
}

fn read_delta(r: &mut ByteReader) -> Result<Vec<TensorUpdate>> {
    let n = r.get_u32()? as usize;
    if n > r.remaining() {
        bail!("delta claims {n} tensors in {} bytes", r.remaining());
    }
    let mut us = Vec::with_capacity(n);
    for _ in 0..n {
        us.push(match r.get_u8().context("tensor update tag")? {
            TU_SPARSE => {
                let elems = r.get_u32()?;
                let nnz = r.get_u32()? as usize;
                if nnz > elems as usize {
                    bail!("sparse tensor nnz {nnz} > elems {elems}");
                }
                let indices = r.get_u32s(nnz)?;
                let values = r.get_f32s(nnz)?;
                if let Some(&bad) = indices.iter().find(|&&i| i >= elems) {
                    bail!("sparse index {bad} out of bounds for {elems} elements");
                }
                TensorUpdate::Sparse(SparseTensor { elems, indices, values })
            }
            TU_SIGN => {
                let elems = r.get_u32()?;
                let nnz = r.get_u32()?;
                let magnitude = r.get_f32()?;
                if nnz > elems {
                    bail!("sign tensor nnz {nnz} > elems {elems}");
                }
                let presence = r.get_u32s((elems as usize).div_ceil(32))?;
                let signs = r.get_u32s((nnz as usize).div_ceil(32))?;
                let pop: u32 = presence.iter().map(|w| w.count_ones()).sum();
                if pop != nnz {
                    bail!("sign bitmap popcount {pop} != nnz {nnz}");
                }
                if let Some(last) = presence.last() {
                    let tail = elems as usize % 32;
                    if tail != 0 && (last >> tail) != 0 {
                        bail!("sign bitmap sets bits past element {elems}");
                    }
                }
                TensorUpdate::Sign(SignTensor { elems, nnz, presence, signs, magnitude })
            }
            other => bail!("unknown tensor update tag {other}"),
        });
    }
    Ok(us)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_updates() -> Vec<ModelUpdate> {
        let pruned = [1.0f32, 0.0, -2.0, 0.0, 0.5, 0.0, 0.0];
        let delta = vec![
            TensorUpdate::Sparse(SparseTensor::encode(&pruned)),
            TensorUpdate::Sign(SignTensor::encode(&pruned)),
        ];
        vec![
            ModelUpdate::Dense(vec![
                Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 4.25, -0.5]),
                Tensor::new(vec![4], vec![9.0, 8.0, 7.0, 6.0]),
            ]),
            ModelUpdate::Delta(delta.clone()),
            ModelUpdate::Chain(vec![delta.clone(), delta]),
        ]
    }

    #[test]
    fn update_roundtrips_all_variants() {
        for u in sample_updates() {
            let bytes = encode_update(&u);
            let back = decode_update(&bytes).unwrap();
            assert_eq!(back, u);
        }
    }

    #[test]
    fn seal_open_roundtrip_and_kinds() {
        for (kind, payload) in [
            (FrameKind::Update, vec![1u8, 2, 3]),
            (FrameKind::Report, vec![]),
            (FrameKind::Nack, vec![0xFF; 100]),
        ] {
            let f = Frame::seal(kind, &payload);
            let (k, p) = f.open().unwrap();
            assert_eq!(k, kind);
            assert_eq!(p, &payload[..]);
            assert_eq!(f.wire_bytes(), payload.len() as u64 + FRAME_HEADER_BYTES);
        }
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let payload = encode_update(&sample_updates()[1]);
        let clean = Frame::seal(FrameKind::Update, &payload);
        assert!(clean.open().is_ok());
        for pos in 0..clean.as_bytes().len() {
            let mut f = clean.clone();
            f.bytes_mut()[pos] ^= 0xA5;
            assert!(f.open().is_err(), "flip at byte {pos} went undetected");
        }
    }

    #[test]
    fn every_truncation_is_detected() {
        let f = Frame::seal(FrameKind::Report, &[9u8; 37]);
        for keep in 0..f.as_bytes().len() {
            let mut t = f.clone();
            t.bytes_mut().truncate(keep);
            assert!(t.open().is_err(), "truncation to {keep} bytes went undetected");
        }
    }

    #[test]
    fn wrong_schema_version_rejected() {
        let mut f = Frame::seal(FrameKind::Update, &[1, 2, 3]);
        let v = (SCHEMA_VERSION + 1).to_le_bytes();
        f.bytes_mut()[4] = v[0];
        f.bytes_mut()[5] = v[1];
        let err = f.open().unwrap_err().to_string();
        assert!(err.contains("schema"), "unexpected error: {err}");
    }

    #[test]
    fn forged_lengths_never_panic_or_balloon() {
        // nnz far beyond the bytes present: decode must error cleanly
        let mut w = ByteWriter::new();
        w.put_u8(1); // delta
        w.put_u32(1); // one tensor
        w.put_u8(0); // sparse
        w.put_u32(1000);
        w.put_u32(u32::MAX); // forged nnz
        assert!(decode_update(&w.into_bytes()).is_err());
        // sparse index out of bounds
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u32(1);
        w.put_u8(0);
        w.put_u32(4); // elems
        w.put_u32(1); // nnz
        w.put_u32(4); // index == elems: out of bounds
        w.put_f32(1.0);
        assert!(decode_update(&w.into_bytes()).is_err());
        // sign popcount disagreeing with nnz
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u32(1);
        w.put_u8(1);
        w.put_u32(32); // elems
        w.put_u32(2); // nnz
        w.put_f32(1.0);
        w.put_u32(0b111); // popcount 3 != 2
        w.put_u32(0);
        assert!(decode_update(&w.into_bytes()).is_err());
        // trailing garbage
        let mut bytes = encode_update(&sample_updates()[0]);
        bytes.push(0);
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn prop_multi_byte_damage_never_panics_or_silently_accepts() {
        // the single-flip test above is exhaustive; this is the seeded
        // random extension to MULTI-byte damage: any number of random
        // xor-flips anywhere in a sealed frame must either be rejected
        // or — when the flips happen to cancel exactly — open to the
        // identical (kind, payload). "Accepted but different" is the one
        // forbidden outcome.
        use crate::testing::{default_cases, for_all, UsizeIn};
        let kinds = [FrameKind::Update, FrameKind::Report, FrameKind::Nack];
        for_all(0xE57A11, &UsizeIn(0, u32::MAX as usize), default_cases(), |&s| {
            let mut rng = crate::util::rng::Rng::new(s as u64 ^ 0xDA4A6E);
            let len = rng.below(300) as usize;
            let mut payload = vec![0u8; len];
            for b in payload.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            let kind = kinds[rng.below(3) as usize];
            let clean = Frame::seal(kind, &payload);
            let mut f = clean.clone();
            let flips = 1 + rng.below(8);
            for _ in 0..flips {
                let pos = rng.below(f.as_bytes().len() as u64) as usize;
                let mask = (rng.next_u64() as u8) | 1; // never a no-op flip
                f.bytes_mut()[pos] ^= mask;
            }
            let net_change = f.as_bytes() != clean.as_bytes();
            match f.open() {
                Err(_) => Ok(()),
                Ok((k, p)) if k == kind && p == &payload[..] && !net_change => Ok(()),
                Ok((k, p)) => Err(format!(
                    "damaged frame accepted: kind {k:?}, {} payload bytes (was {kind:?}, {len})",
                    p.len()
                )),
            }
        });
    }

    #[test]
    fn prop_random_truncation_is_always_rejected() {
        // any strict prefix of a sealed frame must fail open() — the
        // length field (or the header-size floor) catches every cut
        use crate::testing::{default_cases, for_all, UsizeIn};
        for_all(0x7C47, &UsizeIn(0, u32::MAX as usize), default_cases(), |&s| {
            let mut rng = crate::util::rng::Rng::new(s as u64 ^ 0x7C47);
            let len = rng.below(200) as usize;
            let mut payload = vec![0u8; len];
            for b in payload.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            let f = Frame::seal(FrameKind::Report, &payload);
            let keep = rng.below(f.as_bytes().len() as u64) as usize;
            let mut t = f.clone();
            t.bytes_mut().truncate(keep);
            match t.open() {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("truncation to {keep} bytes accepted")),
            }
        });
    }

    #[test]
    fn prop_decode_update_never_panics() {
        // decode_update sits *inside* the seal, so it sees only
        // checksum-clean bytes in production — but the decoder itself
        // must still be total: random garbage and randomly mutated valid
        // encodings return Err (or a valid value), never panic and never
        // balloon allocation on forged lengths
        use crate::testing::{default_cases, for_all, UsizeIn};
        for_all(0xDEC0DE, &UsizeIn(0, u32::MAX as usize), default_cases(), |&s| {
            let mut rng = crate::util::rng::Rng::new(s as u64 ^ 0xDEC0DE);
            // pure garbage
            let len = rng.below(400) as usize;
            let mut bytes = vec![0u8; len];
            for b in bytes.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            let _ = decode_update(&bytes);
            // a valid encoding with random byte damage
            let updates = sample_updates();
            let mut enc = encode_update(&updates[rng.below(updates.len() as u64) as usize]);
            if !enc.is_empty() {
                for _ in 0..=rng.below(6) {
                    let pos = rng.below(enc.len() as u64) as usize;
                    enc[pos] ^= (rng.next_u64() as u8) | 1;
                }
            }
            let _ = decode_update(&enc);
            Ok(())
        });
    }

    #[test]
    fn f32_bits_survive_the_roundtrip() {
        let u = ModelUpdate::Dense(vec![Tensor::new(
            vec![3],
            vec![f32::NAN, f32::INFINITY, -0.0],
        )]);
        let back = decode_update(&encode_update(&u)).unwrap();
        let ModelUpdate::Dense(ts) = back else { panic!() };
        let bits: Vec<u32> = ts[0].data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(
            bits,
            vec![f32::NAN.to_bits(), f32::INFINITY.to_bits(), (-0.0f32).to_bits()]
        );
    }
}
