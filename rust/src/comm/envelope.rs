//! Integrity-checked framing for the federated channel.
//!
//! Workers are threads in this simulation, so PR 3's wire formats travel
//! as structs; this module is the missing byte layer under them — the
//! piece a real transport (ROADMAP item 1, "coordinator as a service")
//! would put on the socket, and the piece the fault-injection harness
//! ([`crate::faults`]) needs so a flipped bit is *detected and rejected*
//! instead of silently folded into the global model.
//!
//! Every `ModelUpdate` / `WorkerReport` payload is sealed into a
//! [`Frame`]: a fixed 24-byte header (magic, schema version, payload
//! kind, payload length, FNV-1a-64 checksum) followed by the serialized
//! payload. [`Frame::open`] verifies all five fields before a caller
//! ever sees payload bytes; corrupt, truncated, duplicated-length or
//! wrong-schema frames come back as errors, never as updates. A
//! single-byte flip anywhere in a frame is always caught: FNV-1a's
//! per-byte step `h ← (h ⊕ b)·prime` is injective in `h`, so two
//! payloads differing in one byte can never collide, and header flips
//! fail the magic/version/length checks directly.
//!
//! Envelope overhead is a flat [`FRAME_HEADER_BYTES`] = 24 bytes per
//! frame, independent of payload size (`docs/TRANSFER_MODEL.md`
//! §Integrity & recovery):
//!
//! ```
//! use efficientgrad::comm::envelope::{Frame, FrameKind, FRAME_HEADER_BYTES};
//! assert_eq!(FRAME_HEADER_BYTES, 24);
//! let empty = Frame::seal(FrameKind::Nack, &[]);
//! assert_eq!(empty.wire_bytes(), FRAME_HEADER_BYTES);
//! let framed = Frame::seal(FrameKind::Report, &[7u8; 1000]);
//! assert_eq!(framed.wire_bytes(), 1000 + FRAME_HEADER_BYTES);
//! ```

use anyhow::{bail, Context, Result};

use crate::comm::wire::{
    chain_is_quantized, chain_union_indices, for_each_ordinal_gap, presence_bitmap,
    bitmap_rle_encode, rle_decode_indices, ModelUpdate, QuantBits, QuantTensor, SignTensor,
    SparseTensor, TensorUpdate,
};
use crate::tensor::Tensor;

/// Wire schema version sealed into every frame. Bump on any layout
/// change to `encode_update` / the report encoding; old decoders then
/// reject new frames outright instead of misparsing them. v2 added the
/// quantized tensor record ([`TensorUpdate::Quantized`]) and the merged
/// chain encoding (`docs/TRANSFER_MODEL.md` §Wire v2).
pub const SCHEMA_VERSION: u16 = 2;

/// Fixed per-frame envelope overhead in bytes: 4 magic + 2 version +
/// 2 kind + 8 payload length + 8 checksum.
pub const FRAME_HEADER_BYTES: u64 = 24;

const MAGIC: &[u8; 4] = b"EGFR";

/// FNV-1a 64-bit over a byte slice — the per-payload digest. Chosen for
/// the same reason the params checkpoint hand-rolls its footer: zero
/// dependencies, one multiply per byte, and guaranteed detection of any
/// single-byte corruption (each step is injective in the running hash).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// What a frame's payload claims to be. Sealed into the header so a
/// report can never be misparsed as an update (or vice versa).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Downlink: a serialized [`ModelUpdate`].
    Update = 1,
    /// Uplink: a serialized `WorkerReport`.
    Report = 2,
    /// Uplink: worker could not open/apply its downlink; empty payload.
    Nack = 3,
    /// Transport downlink: a full `WorkerTask` (round header + the inner
    /// sealed [`FrameKind::Update`] frame, byte-for-byte as dispatched).
    Task = 4,
    /// Transport uplink: worker finished one task; empty payload. Plays
    /// the role the in-process reply channel's hangup plays.
    RoundDone = 5,
    /// Transport handshake, worker → coordinator: worker id + config hash.
    Hello = 6,
    /// Transport handshake, coordinator → worker: admission granted.
    Welcome = 7,
    /// Transport liveness probe; empty payload, either direction.
    Heartbeat = 8,
    /// Transport farewell: the peer is closing this connection cleanly.
    Goodbye = 9,
    /// Transport control, coordinator → worker: send back a snapshot.
    Capture = 10,
    /// Transport control, worker → coordinator: a serialized snapshot.
    Snapshot = 11,
    /// Transport control, coordinator → worker: restore from snapshot.
    Restore = 12,
    /// Transport control, worker → coordinator: restore applied; empty.
    RestoreAck = 13,
}

impl FrameKind {
    /// Decode a header kind field. Public so the transport layer can
    /// *route* a frame by its claimed kind without opening it — payload
    /// bytes still only leave through [`Frame::open`].
    pub fn from_u16(v: u16) -> Result<Self> {
        Ok(match v {
            1 => FrameKind::Update,
            2 => FrameKind::Report,
            3 => FrameKind::Nack,
            4 => FrameKind::Task,
            5 => FrameKind::RoundDone,
            6 => FrameKind::Hello,
            7 => FrameKind::Welcome,
            8 => FrameKind::Heartbeat,
            9 => FrameKind::Goodbye,
            10 => FrameKind::Capture,
            11 => FrameKind::Snapshot,
            12 => FrameKind::Restore,
            13 => FrameKind::RestoreAck,
            other => bail!("unknown frame kind {other}"),
        })
    }
}

/// One sealed message: header + payload, as the bytes a socket would
/// carry. Mutable access to the raw bytes exists so the fault harness
/// can corrupt frames exactly where a radio would.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame(Vec<u8>);

impl Frame {
    /// Seal a payload: compute length + checksum, prepend the header.
    pub fn seal(kind: FrameKind, payload: &[u8]) -> Self {
        let mut buf = Vec::with_capacity(FRAME_HEADER_BYTES as usize + payload.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&SCHEMA_VERSION.to_le_bytes());
        buf.extend_from_slice(&(kind as u16).to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        buf.extend_from_slice(&fnv1a64(payload).to_le_bytes());
        buf.extend_from_slice(payload);
        Frame(buf)
    }

    /// Verify magic, schema version, kind, length and checksum; return
    /// the payload only if all five hold. This is the *only* way payload
    /// bytes leave a frame — there is no unchecked accessor.
    pub fn open(&self) -> Result<(FrameKind, &[u8])> {
        let b = &self.0;
        if b.len() < FRAME_HEADER_BYTES as usize {
            bail!("frame truncated: {} bytes < {}-byte header", b.len(), FRAME_HEADER_BYTES);
        }
        if &b[0..4] != MAGIC {
            bail!("bad frame magic {:02x?}", &b[0..4]);
        }
        let version = u16::from_le_bytes([b[4], b[5]]);
        if version != SCHEMA_VERSION {
            bail!("frame schema v{version}, this build speaks v{SCHEMA_VERSION}");
        }
        let kind = FrameKind::from_u16(u16::from_le_bytes([b[6], b[7]]))?;
        let len = u64::from_le_bytes(b[8..16].try_into().unwrap());
        let payload = &b[FRAME_HEADER_BYTES as usize..];
        if len != payload.len() as u64 {
            bail!("frame length field {len} != payload {} bytes", payload.len());
        }
        let want = u64::from_le_bytes(b[16..24].try_into().unwrap());
        let got = fnv1a64(payload);
        if want != got {
            bail!("frame checksum mismatch: header {want:#018x}, payload {got:#018x}");
        }
        Ok((kind, payload))
    }

    /// Total bytes on the wire (header + payload).
    pub fn wire_bytes(&self) -> u64 {
        self.0.len() as u64
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.0
    }

    /// Raw byte access for the fault harness — corruption happens on
    /// the sealed bytes, exactly where a flaky link would flip them.
    pub fn bytes_mut(&mut self) -> &mut Vec<u8> {
        &mut self.0
    }

    /// Rehydrate a frame from bytes read off a socket. Deliberately
    /// unchecked: a `Frame` is just a byte container, and [`Frame::open`]
    /// remains the only gate through which payload bytes escape — wire
    /// garbage arrives as a frame that then fails to open, exactly like
    /// a fault-harness corruption.
    pub fn from_wire(bytes: Vec<u8>) -> Frame {
        Frame(bytes)
    }
}

/// Little-endian payload serializer (the counterpart of [`ByteReader`]).
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f32 by raw bits — bit-preserving through the roundtrip (NaN
    /// payloads included, which the fold-boundary finiteness check then
    /// rejects *after* an honest decode).
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 varint (the v2 gap/count encoding —
    /// [`crate::comm::wire::varint_len`] is its byte accounting).
    pub fn put_varint(&mut self, v: u64) {
        crate::comm::wire::push_varint(&mut self.buf, v);
    }

    /// Raw bytes, verbatim — for nested already-sealed frames (the
    /// transport's task messages carry the downlink frame unmodified, so
    /// fault-injected damage travels bit-for-bit).
    pub fn put_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Checked little-endian payload reader: every read is bounds-checked
/// and every collection length is validated against the bytes actually
/// remaining *before* allocation, so a forged length field can neither
/// panic the decoder nor make it balloon memory.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("payload truncated: wanted {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read one LEB128 varint; every byte is bounds-checked and over-long
    /// (> 64-bit) encodings are rejected.
    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.get_u8().context("varint truncated")?;
            if shift >= 64 {
                bail!("varint overflows u64");
            }
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Read `n` u32s after checking `4·n` bytes remain.
    pub fn get_u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Read `n` f32s after checking `4·n` bytes remain.
    pub fn get_f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Read `n` raw bytes after checking they remain (the counterpart of
    /// [`ByteWriter::put_raw`] — the caller owns any further validation,
    /// e.g. a nested frame's own [`Frame::open`]).
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Fail if payload bytes remain — trailing garbage is a schema
    /// violation, not padding.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("{} trailing bytes after payload", self.remaining());
        }
        Ok(())
    }
}

const UPDATE_DENSE: u8 = 0;
const UPDATE_DELTA: u8 = 1;
const UPDATE_CHAIN: u8 = 2;
/// v2: a chain whose links are all quantized ships one merged support
/// plane per tensor plus per-link varint ordinal gaps.
const UPDATE_CHAIN_MERGED: u8 = 3;
const TU_SPARSE: u8 = 0;
const TU_SIGN: u8 = 1;
/// v2: affine int8/int4 survivor codes over a raw-or-RLE support bitmap.
const TU_QUANT: u8 = 2;

/// Flag bits shared by the quantized tensor record and the merged-chain
/// per-tensor / per-link headers.
const QF_Q4: u8 = 1; // 4-bit codes (8-bit when clear)
const QF_RLE: u8 = 2; // support plane is RLE (raw bitmap when clear)

/// Serialize a [`ModelUpdate`] payload (the downlink body; uplink delta
/// reports embed the same delta encoding inside the report payload).
pub fn encode_update(u: &ModelUpdate) -> Vec<u8> {
    let mut w = ByteWriter::new();
    write_update(&mut w, u);
    w.into_bytes()
}

pub(crate) fn write_update(w: &mut ByteWriter, u: &ModelUpdate) {
    match u {
        ModelUpdate::Dense(ts) => {
            w.put_u8(UPDATE_DENSE);
            w.put_u32(ts.len() as u32);
            for t in ts {
                w.put_u32(t.shape().len() as u32);
                for &d in t.shape() {
                    w.put_u32(d as u32);
                }
                for &v in t.data() {
                    w.put_f32(v);
                }
            }
        }
        ModelUpdate::Delta(us) => {
            w.put_u8(UPDATE_DELTA);
            write_delta(w, us);
        }
        ModelUpdate::Chain(links) if chain_is_quantized(links) => {
            write_merged_chain(w, links);
        }
        ModelUpdate::Chain(links) => {
            w.put_u8(UPDATE_CHAIN);
            w.put_u32(links.len() as u32);
            for us in links {
                write_delta(w, us);
            }
        }
    }
}

/// v2 merged-chain body: per tensor position, ONE union support plane
/// shared by every link, then each link's survivors as varint ordinal
/// gaps into that union plus its affine header and packed codes. Byte
/// accounting: [`crate::comm::wire::merged_chain_bytes`].
fn write_merged_chain(w: &mut ByteWriter, links: &[Vec<TensorUpdate>]) {
    w.put_u8(UPDATE_CHAIN_MERGED);
    w.put_u32(links.len() as u32);
    w.put_u32(links[0].len() as u32);
    for t in 0..links[0].len() {
        let union = chain_union_indices(links, t);
        let elems = links[0][t].elems();
        debug_assert!(links.iter().all(|us| us[t].elems() == elems));
        let rle = crate::comm::wire::rle_bytes_from_indices(elems, &union)
            < crate::comm::wire::raw_bitmap_bytes(elems);
        w.put_u32(elems as u32);
        w.put_u32(union.len() as u32);
        w.put_u8(if rle { QF_RLE } else { 0 });
        let bitmap = presence_bitmap(elems, &union);
        if rle {
            let stream = bitmap_rle_encode(&bitmap, elems);
            w.put_u32(stream.len() as u32);
            w.put_raw(&stream);
        } else {
            for &p in &bitmap {
                w.put_u32(p);
            }
        }
        for us in links {
            let TensorUpdate::Quantized(q) = &us[t] else {
                unreachable!("chain_is_quantized checked by the caller")
            };
            w.put_u8(if q.bits == QuantBits::Q4 { QF_Q4 } else { 0 });
            w.put_f32(q.scale);
            w.put_f32(q.zero);
            w.put_varint(q.nnz() as u64);
            for_each_ordinal_gap(&union, &q.indices, |d| w.put_varint(d));
            for &c in &q.codes {
                w.put_u32(c);
            }
        }
    }
}

fn write_delta(w: &mut ByteWriter, us: &[TensorUpdate]) {
    w.put_u32(us.len() as u32);
    for u in us {
        match u {
            TensorUpdate::Sparse(t) => {
                w.put_u8(TU_SPARSE);
                w.put_u32(t.elems);
                w.put_u32(t.indices.len() as u32);
                for &i in &t.indices {
                    w.put_u32(i);
                }
                for &v in &t.values {
                    w.put_f32(v);
                }
            }
            TensorUpdate::Sign(t) => {
                w.put_u8(TU_SIGN);
                w.put_u32(t.elems);
                w.put_u32(t.nnz);
                w.put_f32(t.magnitude);
                for &p in &t.presence {
                    w.put_u32(p);
                }
                for &s in &t.signs {
                    w.put_u32(s);
                }
            }
            TensorUpdate::Quantized(t) => {
                w.put_u8(TU_QUANT);
                let rle = t.uses_rle();
                let mut flags = 0u8;
                if t.bits == QuantBits::Q4 {
                    flags |= QF_Q4;
                }
                if rle {
                    flags |= QF_RLE;
                }
                w.put_u8(flags);
                w.put_u32(t.elems);
                w.put_u32(t.indices.len() as u32);
                w.put_f32(t.scale);
                w.put_f32(t.zero);
                let bitmap = presence_bitmap(t.elems as usize, &t.indices);
                if rle {
                    let stream = bitmap_rle_encode(&bitmap, t.elems as usize);
                    w.put_u32(stream.len() as u32);
                    w.put_raw(&stream);
                } else {
                    for &p in &bitmap {
                        w.put_u32(p);
                    }
                }
                for &c in &t.codes {
                    w.put_u32(c);
                }
            }
        }
    }
}

/// Decode a [`ModelUpdate`] payload, validating every structural
/// invariant the apply path relies on (index bounds, bitmap popcounts,
/// tensor shapes) so a decoded update can never panic downstream.
pub fn decode_update(payload: &[u8]) -> Result<ModelUpdate> {
    let mut r = ByteReader::new(payload);
    let u = read_update(&mut r)?;
    r.finish()?;
    Ok(u)
}

pub(crate) fn read_update(r: &mut ByteReader) -> Result<ModelUpdate> {
    Ok(match r.get_u8().context("update tag")? {
        UPDATE_DENSE => {
            let n = r.get_u32()? as usize;
            let mut ts = Vec::with_capacity(n.min(r.remaining()));
            for _ in 0..n {
                let rank = r.get_u32()? as usize;
                if rank > 8 {
                    bail!("dense tensor rank {rank} exceeds limit 8");
                }
                let mut shape = Vec::with_capacity(rank);
                let mut elems: usize = 1;
                for _ in 0..rank {
                    let d = r.get_u32()? as usize;
                    elems = elems
                        .checked_mul(d)
                        .filter(|&e| e <= r.remaining())
                        .context("dense tensor shape overflows payload")?;
                    shape.push(d);
                }
                let data = r.get_f32s(elems)?;
                ts.push(Tensor::new(shape, data));
            }
            ModelUpdate::Dense(ts)
        }
        UPDATE_DELTA => ModelUpdate::Delta(read_delta(r)?),
        UPDATE_CHAIN => {
            let links = r.get_u32()? as usize;
            if links > r.remaining() {
                bail!("chain claims {links} links in {} bytes", r.remaining());
            }
            let mut out = Vec::with_capacity(links);
            for _ in 0..links {
                out.push(read_delta(r)?);
            }
            ModelUpdate::Chain(out)
        }
        UPDATE_CHAIN_MERGED => ModelUpdate::Chain(read_merged_chain(r)?),
        other => bail!("unknown update tag {other}"),
    })
}

/// Decode a v2 merged chain back into the in-memory per-link form (the
/// apply path replays links one by one, so the replica math is
/// unchanged — merging is purely a wire encoding). Validates the union
/// support plane, that every link's ordinals are strictly increasing
/// and in-bounds, that every union survivor is referenced by ≥ 1 link
/// (the writer's union is minimal, so anything else is a forgery), and
/// every code-plane tail bit.
fn read_merged_chain(r: &mut ByteReader) -> Result<Vec<Vec<TensorUpdate>>> {
    let links = r.get_u32()? as usize;
    let tensors = r.get_u32()? as usize;
    if links == 0 || tensors == 0 {
        bail!("merged chain with {links} links × {tensors} tensors");
    }
    if links > r.remaining() || tensors > r.remaining() {
        bail!("merged chain claims {links} links × {tensors} tensors in {} bytes", r.remaining());
    }
    let mut out: Vec<Vec<TensorUpdate>> = vec![Vec::with_capacity(tensors); links];
    for _ in 0..tensors {
        let elems = r.get_u32()?;
        let union_nnz = r.get_u32()? as usize;
        let tflags = r.get_u8()?;
        if tflags & !QF_RLE != 0 {
            bail!("unknown merged-tensor flags {tflags:#x}");
        }
        if union_nnz > elems as usize {
            bail!("merged union nnz {union_nnz} > elems {elems}");
        }
        // every union survivor costs ≥ 1 gap byte in some link, so a
        // legitimate union can never outgrow links · remaining — reject
        // forged counts before allocating anything proportional to them
        if union_nnz as u64 > links as u64 * r.remaining() as u64 {
            bail!("merged union claims {union_nnz} survivors in {} bytes", r.remaining());
        }
        let union = if tflags & QF_RLE != 0 {
            let slen = r.get_u32()? as usize;
            let stream = r.get_raw(slen)?;
            rle_decode_indices(stream, elems as usize, union_nnz)?
        } else {
            let bitmap = r.get_u32s((elems as usize).div_ceil(32))?;
            bitmap_indices_checked(&bitmap, elems, union_nnz)?
        };
        let mut referenced = vec![false; union_nnz];
        for link in out.iter_mut() {
            let lflags = r.get_u8()?;
            if lflags & !QF_Q4 != 0 {
                bail!("unknown merged-link flags {lflags:#x}");
            }
            let bits = if lflags & QF_Q4 != 0 { QuantBits::Q4 } else { QuantBits::Q8 };
            let scale = r.get_f32()?;
            let zero = r.get_f32()?;
            let nnz = r.get_varint()? as usize;
            if nnz > union_nnz {
                bail!("merged link nnz {nnz} > union {union_nnz}");
            }
            let mut indices = Vec::with_capacity(nnz);
            let mut ord = 0u64;
            for k in 0..nnz {
                let d = r.get_varint()?;
                if k == 0 {
                    ord = d;
                } else {
                    if d == 0 {
                        bail!("merged link ordinals not strictly increasing");
                    }
                    ord = ord.checked_add(d).context("merged link ordinal overflows")?;
                }
                if ord >= union_nnz as u64 {
                    bail!("merged link ordinal {ord} out of union bounds {union_nnz}");
                }
                referenced[ord as usize] = true;
                indices.push(union[ord as usize]);
            }
            let words = (nnz * bits.bits()).div_ceil(32);
            let codes = r.get_u32s(words)?;
            check_code_tail(&codes, nnz, bits)?;
            link.push(TensorUpdate::Quantized(QuantTensor {
                elems,
                indices,
                bits,
                scale,
                zero,
                codes,
            }));
        }
        if let Some(unused) = referenced.iter().position(|&s| !s) {
            bail!("merged union survivor {unused} referenced by no link (union not minimal)");
        }
    }
    Ok(out)
}

/// Raw-bitmap support decode shared by the quantized tensor record and
/// the merged chain: popcount must equal the claimed nnz, tail bits
/// past `elems` must be clear, and the survivor offsets come back
/// sorted.
fn bitmap_indices_checked(bitmap: &[u32], elems: u32, nnz: usize) -> Result<Vec<u32>> {
    let pop: u64 = bitmap.iter().map(|w| u64::from(w.count_ones())).sum();
    if pop != nnz as u64 {
        bail!("support bitmap popcount {pop} != nnz {nnz}");
    }
    if let Some(last) = bitmap.last() {
        let tail = elems as usize % 32;
        if tail != 0 && (last >> tail) != 0 {
            bail!("support bitmap sets bits past element {elems}");
        }
    }
    let mut indices = Vec::with_capacity(nnz);
    for (wi, &word) in bitmap.iter().enumerate() {
        let mut bits = word;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            indices.push((wi * 32 + b) as u32);
        }
    }
    Ok(indices)
}

/// Reject set bits past the last survivor's code in the packed plane —
/// the writer zero-pads, so anything else is damage or a forgery.
fn check_code_tail(codes: &[u32], nnz: usize, bits: QuantBits) -> Result<()> {
    if let Some(&last) = codes.last() {
        let used = (nnz * bits.bits()) % 32;
        if used != 0 && (last >> used) != 0 {
            bail!("quant code plane sets bits past survivor {nnz}");
        }
    }
    Ok(())
}

fn read_delta(r: &mut ByteReader) -> Result<Vec<TensorUpdate>> {
    let n = r.get_u32()? as usize;
    if n > r.remaining() {
        bail!("delta claims {n} tensors in {} bytes", r.remaining());
    }
    let mut us = Vec::with_capacity(n);
    for _ in 0..n {
        us.push(match r.get_u8().context("tensor update tag")? {
            TU_SPARSE => {
                let elems = r.get_u32()?;
                let nnz = r.get_u32()? as usize;
                if nnz > elems as usize {
                    bail!("sparse tensor nnz {nnz} > elems {elems}");
                }
                let indices = r.get_u32s(nnz)?;
                let values = r.get_f32s(nnz)?;
                if let Some(&bad) = indices.iter().find(|&&i| i >= elems) {
                    bail!("sparse index {bad} out of bounds for {elems} elements");
                }
                TensorUpdate::Sparse(SparseTensor { elems, indices, values })
            }
            TU_SIGN => {
                let elems = r.get_u32()?;
                let nnz = r.get_u32()?;
                let magnitude = r.get_f32()?;
                if nnz > elems {
                    bail!("sign tensor nnz {nnz} > elems {elems}");
                }
                let presence = r.get_u32s((elems as usize).div_ceil(32))?;
                let signs = r.get_u32s((nnz as usize).div_ceil(32))?;
                let pop: u32 = presence.iter().map(|w| w.count_ones()).sum();
                if pop != nnz {
                    bail!("sign bitmap popcount {pop} != nnz {nnz}");
                }
                if let Some(last) = presence.last() {
                    let tail = elems as usize % 32;
                    if tail != 0 && (last >> tail) != 0 {
                        bail!("sign bitmap sets bits past element {elems}");
                    }
                }
                TensorUpdate::Sign(SignTensor { elems, nnz, presence, signs, magnitude })
            }
            TU_QUANT => {
                let qflags = r.get_u8()?;
                if qflags & !(QF_Q4 | QF_RLE) != 0 {
                    bail!("unknown quant tensor flags {qflags:#x}");
                }
                let bits = if qflags & QF_Q4 != 0 { QuantBits::Q4 } else { QuantBits::Q8 };
                let elems = r.get_u32()?;
                let nnz = r.get_u32()? as usize;
                if nnz > elems as usize {
                    bail!("quant tensor nnz {nnz} > elems {elems}");
                }
                let scale = r.get_f32()?;
                let zero = r.get_f32()?;
                // the codes plane alone needs nnz·bits packed bits, so a
                // legitimate nnz can never exceed 8× the remaining payload —
                // reject forged counts before allocating proportional to them
                if nnz as u64 * bits.bits() as u64 > 8 * r.remaining() as u64 {
                    bail!("quant tensor claims {nnz} survivors in {} bytes", r.remaining());
                }
                let indices = if qflags & QF_RLE != 0 {
                    let slen = r.get_u32()? as usize;
                    let stream = r.get_raw(slen)?;
                    rle_decode_indices(stream, elems as usize, nnz)?
                } else {
                    let bitmap = r.get_u32s((elems as usize).div_ceil(32))?;
                    bitmap_indices_checked(&bitmap, elems, nnz)?
                };
                let words = (nnz * bits.bits()).div_ceil(32);
                let codes = r.get_u32s(words)?;
                check_code_tail(&codes, nnz, bits)?;
                TensorUpdate::Quantized(QuantTensor { elems, indices, bits, scale, zero, codes })
            }
            other => bail!("unknown tensor update tag {other}"),
        });
    }
    Ok(us)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_updates() -> Vec<ModelUpdate> {
        let pruned = [1.0f32, 0.0, -2.0, 0.0, 0.5, 0.0, 0.0];
        let delta = vec![
            TensorUpdate::Sparse(SparseTensor::encode(&pruned)),
            TensorUpdate::Sign(SignTensor::encode(&pruned)),
        ];
        // a long run so the RLE support path is exercised, and a short
        // scattered one so the raw-bitmap path is
        let mut run = vec![0.0f32; 400];
        for (i, v) in run.iter_mut().enumerate().take(180).skip(100) {
            *v = (i as f32 - 140.0) * 0.125;
        }
        let qdelta = vec![
            TensorUpdate::Quantized(QuantTensor::encode(&pruned, QuantBits::Q8)),
            TensorUpdate::Quantized(QuantTensor::encode(&run, QuantBits::Q4)),
        ];
        // same per-tensor elems as qdelta (links of one chain update the
        // same model) but a shifted support, so the merged union is a
        // strict superset of each link
        let mut run2 = vec![0.0f32; 400];
        for (i, v) in run2.iter_mut().enumerate().take(220).skip(150) {
            *v = (i as f32 - 170.0) * 0.0625;
        }
        let qdelta2 = vec![
            TensorUpdate::Quantized(QuantTensor::encode(&[0.0, 3.0, 0.0, -1.0, 0.0, 0.5, 0.75], QuantBits::Q8)),
            TensorUpdate::Quantized(QuantTensor::encode(&run2, QuantBits::Q4)),
        ];
        vec![
            ModelUpdate::Dense(vec![
                Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 4.25, -0.5]),
                Tensor::new(vec![4], vec![9.0, 8.0, 7.0, 6.0]),
            ]),
            ModelUpdate::Delta(delta.clone()),
            ModelUpdate::Chain(vec![delta.clone(), delta.clone()]),
            ModelUpdate::Delta(qdelta.clone()),
            // all-quantized chain: travels as the merged v2 record
            ModelUpdate::Chain(vec![qdelta.clone(), qdelta2, qdelta]),
            // mixed chain: falls back to the per-link v1 record
            ModelUpdate::Chain(vec![delta, vec![
                TensorUpdate::Quantized(QuantTensor::encode(&pruned, QuantBits::Q8)),
                TensorUpdate::Quantized(QuantTensor::encode(&run, QuantBits::Q8)),
            ]]),
        ]
    }

    #[test]
    fn update_roundtrips_all_variants() {
        for u in sample_updates() {
            let bytes = encode_update(&u);
            let back = decode_update(&bytes).unwrap();
            assert_eq!(back, u);
        }
    }

    #[test]
    fn seal_open_roundtrip_and_kinds() {
        for (kind, payload) in [
            (FrameKind::Update, vec![1u8, 2, 3]),
            (FrameKind::Report, vec![]),
            (FrameKind::Nack, vec![0xFF; 100]),
        ] {
            let f = Frame::seal(kind, &payload);
            let (k, p) = f.open().unwrap();
            assert_eq!(k, kind);
            assert_eq!(p, &payload[..]);
            assert_eq!(f.wire_bytes(), payload.len() as u64 + FRAME_HEADER_BYTES);
        }
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let payload = encode_update(&sample_updates()[1]);
        let clean = Frame::seal(FrameKind::Update, &payload);
        assert!(clean.open().is_ok());
        for pos in 0..clean.as_bytes().len() {
            let mut f = clean.clone();
            f.bytes_mut()[pos] ^= 0xA5;
            assert!(f.open().is_err(), "flip at byte {pos} went undetected");
        }
    }

    #[test]
    fn every_truncation_is_detected() {
        let f = Frame::seal(FrameKind::Report, &[9u8; 37]);
        for keep in 0..f.as_bytes().len() {
            let mut t = f.clone();
            t.bytes_mut().truncate(keep);
            assert!(t.open().is_err(), "truncation to {keep} bytes went undetected");
        }
    }

    #[test]
    fn wrong_schema_version_rejected() {
        let mut f = Frame::seal(FrameKind::Update, &[1, 2, 3]);
        let v = (SCHEMA_VERSION + 1).to_le_bytes();
        f.bytes_mut()[4] = v[0];
        f.bytes_mut()[5] = v[1];
        let err = f.open().unwrap_err().to_string();
        assert!(err.contains("schema"), "unexpected error: {err}");
    }

    #[test]
    fn forged_lengths_never_panic_or_balloon() {
        // nnz far beyond the bytes present: decode must error cleanly
        let mut w = ByteWriter::new();
        w.put_u8(1); // delta
        w.put_u32(1); // one tensor
        w.put_u8(0); // sparse
        w.put_u32(1000);
        w.put_u32(u32::MAX); // forged nnz
        assert!(decode_update(&w.into_bytes()).is_err());
        // sparse index out of bounds
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u32(1);
        w.put_u8(0);
        w.put_u32(4); // elems
        w.put_u32(1); // nnz
        w.put_u32(4); // index == elems: out of bounds
        w.put_f32(1.0);
        assert!(decode_update(&w.into_bytes()).is_err());
        // sign popcount disagreeing with nnz
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u32(1);
        w.put_u8(1);
        w.put_u32(32); // elems
        w.put_u32(2); // nnz
        w.put_f32(1.0);
        w.put_u32(0b111); // popcount 3 != 2
        w.put_u32(0);
        assert!(decode_update(&w.into_bytes()).is_err());
        // trailing garbage
        let mut bytes = encode_update(&sample_updates()[0]);
        bytes.push(0);
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn all_quantized_chain_travels_as_the_merged_record() {
        let updates = sample_updates();
        let merged = &updates[4]; // the all-quantized chain
        let mixed = &updates[5]; // the sparse/sign + quantized chain
        assert_eq!(encode_update(merged)[0], UPDATE_CHAIN_MERGED);
        assert_eq!(encode_update(mixed)[0], UPDATE_CHAIN);
        // the win the merged record is sized against is the legacy
        // f32-sparse chain (8 B/survivor + one support per link) — the
        // same supports and values shipped the way PR 9 shipped them
        let ModelUpdate::Chain(links) = merged else { panic!() };
        let legacy: Vec<Vec<TensorUpdate>> = links
            .iter()
            .map(|l| {
                l.iter()
                    .map(|u| {
                        let TensorUpdate::Quantized(q) = u else { panic!() };
                        let mut vals = Vec::new();
                        q.dequantize_values(&mut vals);
                        TensorUpdate::Sparse(SparseTensor {
                            elems: q.elems,
                            indices: q.indices.clone(),
                            values: vals,
                        })
                    })
                    .collect()
            })
            .collect();
        let legacy_bytes = encode_update(&ModelUpdate::Chain(legacy)).len();
        assert!(
            encode_update(merged).len() < legacy_bytes,
            "merged record must beat the legacy f32 chain ({} vs {legacy_bytes})",
            encode_update(merged).len()
        );
    }

    #[test]
    fn forged_merged_chains_are_rejected() {
        // start from a valid merged encoding and check the reader's
        // structural guards one by one
        let merged = &sample_updates()[4];
        let clean = encode_update(merged);
        assert!(decode_update(&clean).is_ok());

        // zero links / zero tensors
        let mut w = ByteWriter::new();
        w.put_u8(UPDATE_CHAIN_MERGED);
        w.put_u32(0);
        w.put_u32(1);
        assert!(decode_update(&w.into_bytes()).is_err());

        // union nnz beyond elems
        let mut w = ByteWriter::new();
        w.put_u8(UPDATE_CHAIN_MERGED);
        w.put_u32(1); // links
        w.put_u32(1); // tensors
        w.put_u32(8); // elems
        w.put_u32(9); // union nnz > elems
        w.put_u8(0);
        assert!(decode_update(&w.into_bytes()).is_err());

        // a union survivor no link references (non-minimal union)
        let mut w = ByteWriter::new();
        w.put_u8(UPDATE_CHAIN_MERGED);
        w.put_u32(1); // links
        w.put_u32(1); // tensors
        w.put_u32(64); // elems
        w.put_u32(2); // union nnz
        w.put_u8(0); // raw bitmap
        w.put_u32(0b101); // union = {0, 2}
        w.put_u32(0);
        w.put_u8(0); // link flags: q8
        w.put_f32(1.0); // scale
        w.put_f32(0.0); // zero
        w.put_varint(1); // link nnz: only ordinal 0
        w.put_varint(0); // gap → ordinal 0
        w.put_u32(7); // one code word
        assert!(decode_update(&w.into_bytes())
            .unwrap_err()
            .to_string()
            .contains("not minimal"));

        // non-increasing ordinals within a link
        let mut w = ByteWriter::new();
        w.put_u8(UPDATE_CHAIN_MERGED);
        w.put_u32(1);
        w.put_u32(1);
        w.put_u32(64);
        w.put_u32(2);
        w.put_u8(0);
        w.put_u32(0b101);
        w.put_u32(0);
        w.put_u8(0);
        w.put_f32(1.0);
        w.put_f32(0.0);
        w.put_varint(2);
        w.put_varint(1); // ordinal 1
        w.put_varint(0); // gap 0 after the first: forged
        w.put_u32(0x0707);
        assert!(decode_update(&w.into_bytes()).is_err());

        // ordinal past the union
        let mut w = ByteWriter::new();
        w.put_u8(UPDATE_CHAIN_MERGED);
        w.put_u32(1);
        w.put_u32(1);
        w.put_u32(64);
        w.put_u32(2);
        w.put_u8(0);
        w.put_u32(0b101);
        w.put_u32(0);
        w.put_u8(0);
        w.put_f32(1.0);
        w.put_f32(0.0);
        w.put_varint(1);
        w.put_varint(2); // union has ordinals {0, 1} only
        w.put_u32(7);
        assert!(decode_update(&w.into_bytes()).is_err());

        // every single-byte corruption of the merged record must be
        // rejected or decode to something != the original (the seal
        // catches damage in production; the decoder must stay total)
        for pos in 1..clean.len() {
            let mut dmg = clean.clone();
            dmg[pos] ^= 0x5A;
            if let Ok(back) = decode_update(&dmg) {
                assert_ne!(&back, merged, "byte {pos} damage decoded to the original");
            }
        }
    }

    #[test]
    fn forged_quant_tensor_records_are_rejected() {
        fn quant_prefix(flags: u8, elems: u32, nnz: u32) -> ByteWriter {
            let mut w = ByteWriter::new();
            w.put_u8(UPDATE_DELTA);
            w.put_u32(1); // one tensor
            w.put_u8(TU_QUANT);
            w.put_u8(flags);
            w.put_u32(elems);
            w.put_u32(nnz);
            w.put_f32(0.5); // scale
            w.put_f32(-1.0); // zero
            w
        }
        // unknown flag bits
        assert!(decode_update(&quant_prefix(0x80, 8, 1).into_bytes()).is_err());
        // nnz > elems
        assert!(decode_update(&quant_prefix(0, 8, 9).into_bytes()).is_err());
        // forged huge nnz with no payload behind it: must error before
        // allocating
        assert!(decode_update(&quant_prefix(0, u32::MAX, u32::MAX).into_bytes()).is_err());
        // popcount != nnz
        let mut w = quant_prefix(0, 32, 2);
        w.put_u32(0b111); // 3 bits set
        w.put_u32(0x0102_0300); // codes
        assert!(decode_update(&w.into_bytes()).is_err());
        // bitmap bits past elems
        let mut w = quant_prefix(0, 30, 2);
        w.put_u32(1 | (1 << 31)); // bit 31 ≥ elems 30
        w.put_u32(0x0000_0201);
        assert!(decode_update(&w.into_bytes()).is_err());
        // code plane with set bits past the last survivor
        let mut w = quant_prefix(0, 32, 2);
        w.put_u32(0b11);
        w.put_u32(0xFFFF_FFFF); // survivors use 16 bits; tail must be clear
        assert!(decode_update(&w.into_bytes()).is_err());
        // RLE stream whose runs disagree with nnz
        let mut w = quant_prefix(QF_RLE, 16, 3);
        let mut stream = Vec::new();
        crate::comm::wire::push_varint(&mut stream, 2); // zeros
        crate::comm::wire::push_varint(&mut stream, 2); // ones: 2 != nnz 3
        crate::comm::wire::push_varint(&mut stream, 12); // zeros to len
        w.put_u32(stream.len() as u32);
        w.put_raw(&stream);
        w.put_u32(0x0003_0201);
        assert!(decode_update(&w.into_bytes()).is_err());
    }

    #[test]
    fn prop_multi_byte_damage_never_panics_or_silently_accepts() {
        // the single-flip test above is exhaustive; this is the seeded
        // random extension to MULTI-byte damage: any number of random
        // xor-flips anywhere in a sealed frame must either be rejected
        // or — when the flips happen to cancel exactly — open to the
        // identical (kind, payload). "Accepted but different" is the one
        // forbidden outcome.
        use crate::testing::{default_cases, for_all, UsizeIn};
        let kinds = [FrameKind::Update, FrameKind::Report, FrameKind::Nack];
        for_all(0xE57A11, &UsizeIn(0, u32::MAX as usize), default_cases(), |&s| {
            let mut rng = crate::util::rng::Rng::new(s as u64 ^ 0xDA4A6E);
            let len = rng.below(300) as usize;
            let mut payload = vec![0u8; len];
            for b in payload.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            let kind = kinds[rng.below(3) as usize];
            let clean = Frame::seal(kind, &payload);
            let mut f = clean.clone();
            let flips = 1 + rng.below(8);
            for _ in 0..flips {
                let pos = rng.below(f.as_bytes().len() as u64) as usize;
                let mask = (rng.next_u64() as u8) | 1; // never a no-op flip
                f.bytes_mut()[pos] ^= mask;
            }
            let net_change = f.as_bytes() != clean.as_bytes();
            match f.open() {
                Err(_) => Ok(()),
                Ok((k, p)) if k == kind && p == &payload[..] && !net_change => Ok(()),
                Ok((k, p)) => Err(format!(
                    "damaged frame accepted: kind {k:?}, {} payload bytes (was {kind:?}, {len})",
                    p.len()
                )),
            }
        });
    }

    #[test]
    fn prop_random_truncation_is_always_rejected() {
        // any strict prefix of a sealed frame must fail open() — the
        // length field (or the header-size floor) catches every cut
        use crate::testing::{default_cases, for_all, UsizeIn};
        for_all(0x7C47, &UsizeIn(0, u32::MAX as usize), default_cases(), |&s| {
            let mut rng = crate::util::rng::Rng::new(s as u64 ^ 0x7C47);
            let len = rng.below(200) as usize;
            let mut payload = vec![0u8; len];
            for b in payload.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            let f = Frame::seal(FrameKind::Report, &payload);
            let keep = rng.below(f.as_bytes().len() as u64) as usize;
            let mut t = f.clone();
            t.bytes_mut().truncate(keep);
            match t.open() {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("truncation to {keep} bytes accepted")),
            }
        });
    }

    #[test]
    fn prop_decode_update_never_panics() {
        // decode_update sits *inside* the seal, so it sees only
        // checksum-clean bytes in production — but the decoder itself
        // must still be total: random garbage and randomly mutated valid
        // encodings return Err (or a valid value), never panic and never
        // balloon allocation on forged lengths
        use crate::testing::{default_cases, for_all, UsizeIn};
        for_all(0xDEC0DE, &UsizeIn(0, u32::MAX as usize), default_cases(), |&s| {
            let mut rng = crate::util::rng::Rng::new(s as u64 ^ 0xDEC0DE);
            // pure garbage
            let len = rng.below(400) as usize;
            let mut bytes = vec![0u8; len];
            for b in bytes.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            let _ = decode_update(&bytes);
            // a valid encoding with random byte damage
            let updates = sample_updates();
            let mut enc = encode_update(&updates[rng.below(updates.len() as u64) as usize]);
            if !enc.is_empty() {
                for _ in 0..=rng.below(6) {
                    let pos = rng.below(enc.len() as u64) as usize;
                    enc[pos] ^= (rng.next_u64() as u8) | 1;
                }
            }
            let _ = decode_update(&enc);
            Ok(())
        });
    }

    #[test]
    fn f32_bits_survive_the_roundtrip() {
        let u = ModelUpdate::Dense(vec![Tensor::new(
            vec![3],
            vec![f32::NAN, f32::INFINITY, -0.0],
        )]);
        let back = decode_update(&encode_update(&u)).unwrap();
        let ModelUpdate::Dense(ts) = back else { panic!() };
        let bits: Vec<u32> = ts[0].data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(
            bits,
            vec![f32::NAN.to_bits(), f32::INFINITY.to_bits(), (-0.0f32).to_bits()]
        );
    }
}
