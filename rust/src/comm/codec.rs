//! Pruned-delta codec with error feedback.
//!
//! `encode` turns `local − reference` into a [`ModelUpdate`] by running
//! the paper's eq. 3 (`sparsity::stochastic_prune_into_partitioned`, τ
//! from eq. 5's `tau_from_rate` at each tensor's measured σ) over the
//! delta, then
//! packing the survivors in the wire format selected by the
//! [`CommMode`]. What pruning (and, in sign mode, magnitude sharing)
//! throws away is *not lost*: the codec keeps a per-tensor **residual**
//! accumulator — the difference between the true delta and what the
//! decoder will reconstruct — and folds it into the next round's delta
//! before pruning. This is the standard error-feedback construction
//! (memory-compensated compression); combined with eq. 3's unbiasedness
//! it is what keeps compressed federated runs tracking the dense run's
//! accuracy (`tests/federated.rs`).
//!
//! Determinism: the caller provides the [`Rng`] for the stochastic
//! promotion draws, seeded per (run seed, endpoint, round), so a
//! federated run is reproducible bit for bit. Internally `encode`
//! consumes exactly **one** draw from that stream per call and derives
//! per-tensor / per-chunk child streams from it
//! (`sparsity::stochastic_prune_into_partitioned`), which is what lets
//! the O(P) hot loops — the delta+residual fold, the σ pass, the prune
//! itself — chunk across the scoped-thread pool (`util::par`) while the
//! output stays bit-identical for a given caller stream, independent of
//! thread count.

use anyhow::{bail, Result};

use super::wire::{ModelUpdate, QuantTensor, SignTensor, SparseTensor, TensorUpdate};
use crate::config::{CommMode, CommPruner, WireQuant};
use crate::sparsity::{
    stochastic_prune_into_partitioned, tau_from_rate, topk_keep_count, topk_prune_into,
};
use crate::tensor::Tensor;
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::stats::std_dev;

/// One endpoint's encoder state: mode + rate + pruner + the
/// error-feedback residuals. Each worker owns one (uplink); the leader
/// owns one (downlink).
pub struct DeltaCodec {
    mode: CommMode,
    rate: f64,
    /// survivor selection: eq. 3 stochastic promotion (default) or
    /// exact top-k by |δ| (`federated.comm_pruner = topk`)
    pruner: CommPruner,
    /// v2 survivor-value quantization (`federated.wire_quant`): `Off`
    /// ships legacy f32 values bit-for-bit; `Q8`/`Q4` ship affine codes
    /// and the dequantization error joins the residual below. Only
    /// `pruned` mode consults it (sign mode already shares one
    /// magnitude; dense loses nothing to quantize against).
    quant: WireQuant,
    /// per-tensor carried-over pruning error; empty until the first
    /// compressed encode
    residual: Vec<Vec<f32>>,
    /// reusable prune-output scratch, grown once to the largest tensor
    /// and reused every round — per-round encode allocates nothing
    /// dense-sized (pinned by the allocs/round row in `runtime_hotpath`)
    scratch: Vec<f32>,
}

impl DeltaCodec {
    pub fn new(mode: CommMode, rate: f64) -> Self {
        Self::with_pruner(mode, rate, CommPruner::Stochastic)
    }

    pub fn with_pruner(mode: CommMode, rate: f64, pruner: CommPruner) -> Self {
        Self {
            mode,
            rate,
            pruner,
            quant: WireQuant::Off,
            residual: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Builder: select the v2 survivor-value quantization. Defaults to
    /// [`WireQuant::Off`] — every existing construction site stays the
    /// legacy f32 wire bit-for-bit unless it opts in.
    pub fn with_quant(mut self, quant: WireQuant) -> Self {
        self.quant = quant;
        self
    }

    pub fn mode(&self) -> CommMode {
        self.mode
    }

    pub fn pruner(&self) -> CommPruner {
        self.pruner
    }

    pub fn quant(&self) -> WireQuant {
        self.quant
    }

    /// Encode `local − reference` (+ carried residual) into a wire
    /// update. Dense mode ships the full `local` snapshot and keeps no
    /// residual (nothing is lost). Compressed modes prune with eq. 3 at
    /// this codec's rate and update the residual to `delta − decoded`.
    pub fn encode(
        &mut self,
        local: &[Tensor],
        reference: &[Tensor],
        rng: &mut Rng,
    ) -> Result<ModelUpdate> {
        if local.len() != reference.len() {
            bail!(
                "encode: {} local tensors vs {} reference",
                local.len(),
                reference.len()
            );
        }
        if self.mode == CommMode::Dense {
            return Ok(ModelUpdate::Dense(local.to_vec()));
        }
        if self.residual.is_empty() {
            self.residual = local.iter().map(|t| vec![0.0f32; t.len()]).collect();
        } else if self.residual.len() != local.len() {
            bail!(
                "encode: residual holds {} tensors, model has {}",
                self.residual.len(),
                local.len()
            );
        }
        // one draw advances the caller's stream; every prune draw below
        // derives from it through (tensor index, chunk index) fold-ins,
        // so the partitioned parallel prune cannot depend on scheduling
        let base = Rng::new(rng.next_u64());
        let mut updates = Vec::with_capacity(local.len());
        for (ti, ((l, r), res)) in local
            .iter()
            .zip(reference)
            .zip(self.residual.iter_mut())
            .enumerate()
        {
            if l.shape() != r.shape() || l.len() != res.len() {
                bail!(
                    "encode: shape mismatch {:?} vs {:?} (residual {})",
                    l.shape(),
                    r.shape(),
                    res.len()
                );
            }
            // delta + carried error, in place in the residual buffer —
            // element-wise, chunked across the thread pool, vectorized
            // per chunk under `simd`
            par::for_each_chunk_triple(res, l.data(), r.data(), |_, e, a, b| {
                crate::util::simd::fold_delta(e, a, b)
            });
            // the prune output lands in the codec's reusable scratch:
            // both pruners overwrite every element, so stale content from
            // a previous (even larger) tensor never leaks through
            self.scratch.resize(res.len(), 0.0);
            match self.pruner {
                CommPruner::Stochastic => {
                    let sigma = std_dev(res);
                    let tau = tau_from_rate(sigma, self.rate);
                    stochastic_prune_into_partitioned(
                        res,
                        tau,
                        &base.fold_in(ti as u64),
                        &mut self.scratch,
                    );
                }
                // exact top-k by |δ|: deterministic (the caller's draw is
                // still consumed above, so switching pruners never shifts
                // any other consumer of the rng stream), and the survivor
                // fraction is exactly 1−P instead of eq. 3's ≈46% floor
                CommPruner::TopK => {
                    topk_prune_into(res, topk_keep_count(res.len(), self.rate), &mut self.scratch);
                }
            }
            let update = match (self.mode, self.quant.to_bits()) {
                (CommMode::Pruned, None) => {
                    TensorUpdate::Sparse(SparseTensor::encode(&self.scratch))
                }
                (CommMode::Pruned, Some(bits)) => {
                    TensorUpdate::Quantized(QuantTensor::encode(&self.scratch, bits))
                }
                (CommMode::Sign, _) => TensorUpdate::Sign(SignTensor::encode(&self.scratch)),
                (CommMode::Dense, _) => unreachable!("handled above"),
            };
            // residual = (delta + old residual) − decode(update); for the
            // sparse format decode == pruned, for sign the shared
            // magnitude's quantization error lands in the residual too
            match &update {
                TensorUpdate::Sparse(t) => {
                    for (&i, &v) in t.indices.iter().zip(&t.values) {
                        res[i as usize] -= v;
                    }
                }
                // the *dequantized* survivor values are what the decoder
                // reconstructs, so subtracting them (not the pre-quant
                // survivors) leaves exactly the quantization error in the
                // residual — the EF identity extends to the quantized wire
                TensorUpdate::Quantized(t) => t.for_each_survivor(|i, v| res[i] -= v),
                // x + (−1)·v ≡ x − v bit for bit; the fold dispatches to
                // the vectorized sign kernel under `simd`
                TensorUpdate::Sign(t) => t.axpy_into_slice(-1.0, res),
            }
            updates.push(update);
        }
        Ok(ModelUpdate::Delta(updates))
    }

    /// L2 norm of the carried residual (test/telemetry hook: bounded
    /// across rounds iff error feedback is stable).
    pub fn residual_norm(&self) -> f64 {
        self.residual
            .iter()
            .flat_map(|r| r.iter())
            .map(|&x| (x as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    /// Drop the carried residual (a worker resyncing from a dense
    /// snapshot starts error feedback afresh — the old residual described
    /// a divergence that the snapshot just erased).
    pub fn reset_residual(&mut self) {
        self.residual.clear();
    }

    /// The carried per-tensor residual, for run-store persistence
    /// (empty until the first compressed encode).
    pub fn residual(&self) -> &[Vec<f32>] {
        &self.residual
    }

    /// Restore a persisted residual — the crash/resume counterpart of
    /// [`DeltaCodec::residual`]. An empty vec is the fresh-codec state.
    pub fn set_residual(&mut self, residual: Vec<Vec<f32>>) {
        self.residual = residual;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor::new(vec![v.len()], v.to_vec())
    }

    #[test]
    fn dense_mode_snapshots_without_residual() {
        let mut c = DeltaCodec::new(CommMode::Dense, 0.9);
        let local = vec![t(&[1.0, 2.0])];
        let reference = vec![t(&[0.0, 0.0])];
        let u = c.encode(&local, &reference, &mut Rng::new(0)).unwrap();
        assert_eq!(u, ModelUpdate::Dense(local.clone()));
        assert_eq!(c.residual_norm(), 0.0);
    }

    #[test]
    fn rate_zero_is_dense_equivalent() {
        // τ = 0 keeps every nonzero delta coordinate exactly: decode of
        // the sparse update reproduces the delta bit for bit and the
        // residual stays zero
        let mut c = DeltaCodec::new(CommMode::Pruned, 0.0);
        let local = vec![t(&[1.0, -0.5, 0.0, 3.25])];
        let reference = vec![t(&[0.5, -0.5, 0.0, 3.0])];
        let u = c.encode(&local, &reference, &mut Rng::new(1)).unwrap();
        let ModelUpdate::Delta(us) = &u else {
            panic!("expected delta")
        };
        assert_eq!(us[0].decode_dense(), vec![0.5, 0.0, 0.0, 0.25]);
        assert_eq!(c.residual_norm(), 0.0);
        // applying onto the reference reconstructs local exactly
        let mut p = reference.clone();
        u.apply(&mut p).unwrap();
        assert_eq!(p, local);
    }

    #[test]
    fn residual_carries_pruned_mass_into_next_round() {
        let mut c = DeltaCodec::new(CommMode::Pruned, 0.9);
        let local = vec![t(&[0.01, -0.02, 5.0, 0.015])];
        let reference = vec![t(&[0.0, 0.0, 0.0, 0.0])];
        let u = c.encode(&local, &reference, &mut Rng::new(2)).unwrap();
        let decoded = match &u {
            ModelUpdate::Delta(us) => us[0].decode_dense(),
            _ => panic!(),
        };
        // residual + decoded == delta, always (the EF identity)
        let norm2: f64 = local[0]
            .data()
            .iter()
            .zip(&decoded)
            .map(|(&d, &q)| ((d - q) as f64).powi(2))
            .sum();
        assert!((c.residual_norm() - norm2.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn topk_pruner_ships_exact_survivor_budget_with_ef_identity() {
        let n = 40;
        let mut c = DeltaCodec::with_pruner(CommMode::Pruned, 0.9, CommPruner::TopK);
        assert_eq!(c.pruner(), CommPruner::TopK);
        let mut vals = vec![0f32; n];
        let mut rng = Rng::new(33);
        rng.fill_normal(&mut vals, 1.0);
        let local = vec![t(&vals)];
        let reference = vec![Tensor::zeros(&[n])];
        let u = c.encode(&local, &reference, &mut Rng::new(0)).unwrap();
        // exactly ⌈(1−P)·E⌉ survivors — the sharpened budget, not eq. 3's
        // stochastic ≈46%
        assert_eq!(u.survivors(), 4);
        let decoded = match &u {
            ModelUpdate::Delta(us) => us[0].decode_dense(),
            _ => panic!("expected delta"),
        };
        // survivors are the exact largest-|δ| coordinates, exact values
        let mut mags: Vec<f32> = vals.iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let cutoff = mags[3];
        for (&d, &q) in vals.iter().zip(&decoded) {
            if q != 0.0 {
                assert_eq!(q, d);
                assert!(d.abs() >= cutoff);
            }
        }
        // EF identity holds for this pruner too
        let norm2: f64 = vals
            .iter()
            .zip(&decoded)
            .map(|(&d, &q)| ((d - q) as f64).powi(2))
            .sum();
        assert!((c.residual_norm() - norm2.sqrt()).abs() < 1e-6);
        // deterministic regardless of the rng handed in
        let mut c2 = DeltaCodec::with_pruner(CommMode::Pruned, 0.9, CommPruner::TopK);
        let u2 = c2.encode(&local, &reference, &mut Rng::new(999)).unwrap();
        assert_eq!(u, u2, "top-k must not depend on the caller's rng");
    }

    #[test]
    fn quantized_wire_keeps_the_ef_identity() {
        use crate::config::WireQuant;
        let n = 64;
        let mut vals = vec![0f32; n];
        Rng::new(77).fill_normal(&mut vals, 1.0);
        let local = vec![t(&vals)];
        let reference = vec![Tensor::zeros(&[n])];
        for quant in [WireQuant::Q8, WireQuant::Q4] {
            let mut c = DeltaCodec::with_pruner(CommMode::Pruned, 0.9, CommPruner::TopK)
                .with_quant(quant);
            assert_eq!(c.quant(), quant);
            let u = c.encode(&local, &reference, &mut Rng::new(0)).unwrap();
            let ModelUpdate::Delta(us) = &u else { panic!("expected delta") };
            let TensorUpdate::Quantized(q) = &us[0] else {
                panic!("pruned + wire_quant must ship Quantized tensors")
            };
            // same survivor support as the unquantized top-k encode
            assert_eq!(q.nnz(), 7); // ⌈0.1·64⌉
            // residual + decoded == delta, always — the quantization
            // error (≤ scale/2 per survivor) is *in* the residual, not
            // lost, so it re-enters the next round's delta
            let decoded = us[0].decode_dense();
            let norm2: f64 = vals
                .iter()
                .zip(&decoded)
                .map(|(&d, &dq)| ((d - dq) as f64).powi(2))
                .sum();
            assert!(
                (c.residual_norm() - norm2.sqrt()).abs() < 1e-6,
                "EF identity broken under {quant:?}"
            );
            // per-survivor dequantization error within half a step
            for (j, &i) in q.indices.iter().enumerate() {
                let err = (q.value(j) - vals[i as usize]).abs();
                assert!(err <= q.scale / 2.0 + 1e-6, "survivor {i} err {err}");
            }
        }
        // Off stays bit-for-bit the legacy sparse wire
        let mut off = DeltaCodec::with_pruner(CommMode::Pruned, 0.9, CommPruner::TopK);
        let u = off.encode(&local, &reference, &mut Rng::new(0)).unwrap();
        let ModelUpdate::Delta(us) = &u else { panic!() };
        assert!(matches!(us[0], TensorUpdate::Sparse(_)));
    }

    #[test]
    fn mismatched_inputs_rejected() {
        let mut c = DeltaCodec::new(CommMode::Pruned, 0.9);
        assert!(c
            .encode(&[t(&[1.0])], &[t(&[1.0]), t(&[2.0])], &mut Rng::new(0))
            .is_err());
        assert!(c
            .encode(&[t(&[1.0, 2.0])], &[t(&[1.0])], &mut Rng::new(0))
            .is_err());
    }
}
