//! Configuration system: a TOML-subset parser (no `toml` crate offline)
//! plus typed configs for training, federated runs and the accelerator
//! simulator. CLI flags override file values (see `cli.rs`).
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! integer, float, bool and flat-array values, `#` comments.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    entries: BTreeMap<String, Value>,
}

impl Table {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            entries.insert(
                key,
                parse_value(v.trim())
                    .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?,
            );
        }
        Ok(Self { entries })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Merge another table over this one (overrides win).
    pub fn merge(&mut self, over: Table) {
        self.entries.extend(over.entries);
    }

    pub fn set(&mut self, key: &str, v: Value) {
        self.entries.insert(key.to_string(), v);
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(Value::as_i64)
            .map(|v| v as usize)
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(Value::as_i64)
            .map(|v| v as u64)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: we don't allow '#' inside strings in configs
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let items: Result<Vec<Value>> = inner
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(parse_value)
            .collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse {s:?}")
}

// ---------------------------------------------------------------------------
// typed configs
// ---------------------------------------------------------------------------

/// Where the training state lives between steps (see `runtime::resident`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ResidencyMode {
    /// Device-resident `PjRtBuffer` state: upload once, per-step host
    /// traffic is scalars-only; host store synced at round boundaries.
    #[default]
    Resident,
    /// Legacy literal-in/literal-out path: full state round-trips the
    /// host every step. Fallback + parity oracle.
    Literal,
}

impl ResidencyMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "resident" | "device" => Ok(Self::Resident),
            "literal" | "host" => Ok(Self::Literal),
            other => bail!("unknown residency mode {other:?} (want resident|literal)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Resident => "resident",
            Self::Literal => "literal",
        }
    }
}

/// Federated network-tier encoding (see `comm` and
/// `docs/TRANSFER_MODEL.md` §Network tier).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CommMode {
    /// Legacy dense fp32 snapshots both directions — bit-for-bit the
    /// pre-comm exchange, and the accuracy/byte baseline.
    #[default]
    Dense,
    /// Pruned deltas (eq. 3 + error feedback) as u32 indices + f32
    /// values.
    Pruned,
    /// Pruned deltas as presence bitmap + sign bits + shared per-tensor
    /// magnitude — the paper's sign-symmetric trick on the wire.
    Sign,
}

impl CommMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dense" => Ok(Self::Dense),
            "pruned" | "sparse" => Ok(Self::Pruned),
            "sign" => Ok(Self::Sign),
            other => bail!("unknown comm mode {other:?} (want dense|pruned|sign)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::Pruned => "pruned",
            Self::Sign => "sign",
        }
    }
}

/// How the comm codec selects delta survivors
/// (`federated.comm_pruner` / `--comm-pruner`); ignored by
/// `comm = dense`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CommPruner {
    /// eq. 3 stochastic promotion at τ from eq. 5 — unbiased, but its
    /// in-band promotions leave ≈46% survivors at P=0.9.
    #[default]
    Stochastic,
    /// exact top-k by |δ| per tensor: keeps exactly `⌈(1−P)·E⌉`
    /// coordinates with their exact values. Biased (error feedback
    /// carries the tail), but the survivor fraction is exactly `1−P` —
    /// sharper than eq. 3's promotion floor.
    TopK,
}

impl CommPruner {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "stochastic" => Ok(Self::Stochastic),
            "topk" | "top-k" => Ok(Self::TopK),
            other => bail!("unknown comm pruner {other:?} (want stochastic|topk)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Stochastic => "stochastic",
            Self::TopK => "topk",
        }
    }
}

/// v2 wire quantization of `pruned`-mode survivor values
/// (`federated.wire_quant` / `--wire-quant`): affine int8/int4 codes with
/// the dequantization error folded into the codec's error-feedback
/// residual. `off` keeps the legacy f32 values bit-for-bit; ignored by
/// `comm = dense` and `comm = sign` (sign already ships ~1 bit/survivor).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireQuant {
    /// legacy f32 survivor values — bit-for-bit the v1 wire
    #[default]
    Off,
    /// 8-bit affine codes: ≈4× smaller values plane, error ≤ range/510
    Q8,
    /// 4-bit affine codes: ≈8× smaller values plane, error ≤ range/30
    Q4,
}

impl WireQuant {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Self::Off),
            "q8" | "int8" => Ok(Self::Q8),
            "q4" | "int4" => Ok(Self::Q4),
            other => bail!("unknown wire quant {other:?} (want off|q8|q4)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Q8 => "q8",
            Self::Q4 => "q4",
        }
    }

    /// The wire code width, `None` when quantization is off.
    pub fn to_bits(self) -> Option<crate::comm::wire::QuantBits> {
        match self {
            Self::Off => None,
            Self::Q8 => Some(crate::comm::wire::QuantBits::Q8),
            Self::Q4 => Some(crate::comm::wire::QuantBits::Q4),
        }
    }
}

/// Training hyperparameters (defaults match the paper's CIFAR recipe,
/// scaled to the synthetic workload).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub mode: String,
    pub steps: usize,
    pub lr: f64,
    pub momentum: f64,
    /// cosine | step | const
    pub lr_schedule: String,
    pub seed: u64,
    pub train_examples: usize,
    pub test_examples: usize,
    pub difficulty: f64,
    pub eval_every: usize,
    pub log_every: usize,
    pub checkpoint: Option<String>,
    /// periodic mid-run checkpointing (`train.checkpoint_every_steps` /
    /// `--checkpoint-every-steps`): every N steps the trainer brings the
    /// host store current (`sync_to_host`, dirty-flag gated — a clean
    /// device state skips the O(model) download) and rewrites the
    /// checkpoint file, so a killed run loses at most N steps. 0 (the
    /// default) keeps the end-of-run-only behavior. Requires
    /// `checkpoint` to be set; ignored otherwise.
    pub checkpoint_every_steps: usize,
    /// step-backend selection: device-resident buffers vs literal path
    pub residency: ResidencyMode,
    /// eval-backend selection (`train.eval_residency` /
    /// `--eval-residency`). Defaults to mirroring `residency` when unset
    /// in a config file. Resident eval with a resident step backend runs
    /// the fwd artifact straight off the training param buffers (zero
    /// state transfer); resident eval with a *literal* step backend
    /// falls back to the fingerprint-cached param-buffer upload (one
    /// `4·P` upload per param change instead of per eval batch).
    pub eval_residency: ResidencyMode,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "convnet_s".into(),
            mode: "efficientgrad".into(),
            steps: 300,
            lr: 0.05,
            momentum: 0.9,
            lr_schedule: "cosine".into(),
            seed: 42,
            train_examples: 2048,
            test_examples: 512,
            difficulty: 0.6,
            eval_every: 100,
            log_every: 20,
            checkpoint: None,
            checkpoint_every_steps: 0,
            residency: ResidencyMode::default(),
            eval_residency: ResidencyMode::default(),
        }
    }
}

impl TrainConfig {
    pub fn from_table(t: &Table) -> Result<Self> {
        let d = Self::default();
        let residency = t
            .get("train.residency")
            .and_then(Value::as_str)
            .map(ResidencyMode::parse)
            .transpose()
            .context("train.residency")?
            .unwrap_or(d.residency);
        Ok(Self {
            model: t.str_or("train.model", &d.model),
            mode: t.str_or("train.mode", &d.mode),
            steps: t.usize_or("train.steps", d.steps),
            lr: t.f64_or("train.lr", d.lr),
            momentum: t.f64_or("train.momentum", d.momentum),
            lr_schedule: t.str_or("train.lr_schedule", &d.lr_schedule),
            seed: t.u64_or("train.seed", d.seed),
            train_examples: t.usize_or("data.train_examples", d.train_examples),
            test_examples: t.usize_or("data.test_examples", d.test_examples),
            difficulty: t.f64_or("data.difficulty", d.difficulty),
            eval_every: t.usize_or("train.eval_every", d.eval_every),
            log_every: t.usize_or("train.log_every", d.log_every),
            checkpoint: t.get("train.checkpoint").and_then(Value::as_str).map(String::from),
            checkpoint_every_steps: t
                .usize_or("train.checkpoint_every_steps", d.checkpoint_every_steps),
            // invalid values error (like lr_schedule / mode do): silently
            // falling back would hand resident-mode numbers to someone
            // who asked for the literal oracle
            residency,
            // unset eval residency follows the step residency, so a bare
            // `--residency literal` run is literal end-to-end (oracle)
            eval_residency: t
                .get("train.eval_residency")
                .and_then(Value::as_str)
                .map(ResidencyMode::parse)
                .transpose()
                .context("train.eval_residency")?
                .unwrap_or(residency),
        })
    }
}

/// Federated coordinator config (paper §1's motivating deployment).
#[derive(Clone, Debug)]
pub struct FedConfig {
    pub workers: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub iid: bool,
    /// probability a worker is a straggler in a round
    pub straggler_prob: f64,
    /// simulated straggler slowdown factor
    pub straggler_slowdown: f64,
    /// wall-clock straggler injection: stragglers actually sleep
    /// `(slowdown − 1)×` their work time instead of only reporting the
    /// inflated simulated time. Off by default (tests stay fast); the
    /// schedule benchmarks turn it on so pipelined-vs-sequential round
    /// wall times see a real straggler.
    pub straggler_sleep: bool,
    /// leader round schedule (`federated.pipeline` / `--pipeline`):
    /// `false` = the sequential oracle (barrier → decode+FedAvg → eval
    /// sweep → downlink encode, all on the leader thread); `true` = the
    /// pipelined schedule (per-report decode at arrival, eval on a
    /// dedicated thread overlapping the next round, downlink encoded
    /// while eval runs). The two are bit-identical in every result —
    /// params, eval_acc, byte ledgers (`tests/federated.rs`) — and
    /// differ only in wall time.
    pub pipeline: bool,
    /// probability a worker is unreachable for a whole round (misses the
    /// downlink and ships nothing; the leader re-weights FedAvg over the
    /// rest and resyncs it with a dense snapshot next round)
    pub dropout_prob: f64,
    /// network-tier encoding (`federated.comm` / `--comm`)
    pub comm: CommMode,
    /// pruning rate for the compressed comm modes (`federated.comm_rate`
    /// / `--comm-rate`); ignored by `comm = dense`
    pub comm_rate: f64,
    /// survivor selection for the compressed comm modes
    /// (`federated.comm_pruner` / `--comm-pruner`)
    pub comm_pruner: CommPruner,
    /// v2 wire quantization of `pruned`-mode survivor values
    /// (`federated.wire_quant` / `--wire-quant`): `off` keeps the legacy
    /// f32 values bit-for-bit, `q8`/`q4` ship affine codes with the
    /// quantization error absorbed by the error-feedback residual
    pub wire_quant: WireQuant,
    /// aggregation quorum (`federated.quorum` / `--quorum`, in (0, 1]):
    /// the leader folds round r as soon as `⌈quorum·dispatched⌉` reports
    /// have arrived and dispatches round r+1 against the new version
    /// while the stragglers are still in flight. 1.0 (the default) is
    /// the full barrier — bit-for-bit today's schedules.
    pub quorum: f64,
    /// staleness decay λ (`federated.staleness_decay`, in [0, 1]): a
    /// straggler report based on a model k versions old folds into the
    /// round it arrives in with weight `examples · λ^k`. λ = 1 weights
    /// late reports like fresh ones; λ = 0 discards them. Unused at
    /// `quorum = 1.0` (no report is ever late).
    pub staleness_decay: f64,
    /// maximum rounds in flight (`federated.pipeline_depth` /
    /// `--pipeline-depth`, ≥ 1): a quorum round's stragglers may stay
    /// outstanding for up to `pipeline_depth` rounds before the leader
    /// blocks on them, bounding late-report staleness at
    /// `k ≤ pipeline_depth`. Irrelevant at `quorum = 1.0` (every round
    /// resolves at its own barrier).
    pub pipeline_depth: usize,
    /// chained-downlink window (`federated.max_chain` / `--max-chain`):
    /// a worker whose replica is `k ≤ max_chain` versions behind is
    /// resynced with the *chain* of the k retained per-round deltas
    /// (bit-identical to having received each round's downlink, and the
    /// worker's error-feedback residual survives) instead of a dense
    /// `4·P` snapshot. 0 (the default) keeps dense resyncs — today's
    /// behavior. Only meaningful for the compressed comm modes.
    pub max_chain: usize,
    /// per-round cohort size (`federated.sample_m` / `--sample-m`): each
    /// round the leader draws `sample_m` of the `workers` registered
    /// workers from a dedicated seeded RNG stream and dispatches only to
    /// them; the rest sit the round out and resync later (chained when
    /// `k ≤ max_chain`, dense otherwise). 0 (the default) — and
    /// `sample_m = workers` — disables sampling: every worker is
    /// dispatched every round, bit-for-bit today's behavior.
    pub sample_m: usize,
    /// edge aggregator count (`federated.aggregators` / `--aggregators`):
    /// `> 1` folds each round in two tiers — workers are statically
    /// partitioned across `aggregators` edge aggregators, each edge
    /// pre-folds its slice into one sparse delta uplinked to the root
    /// (O(nnz) per tier), and the root folds `aggregators`-wide. The
    /// fold result is bit-identical to the flat path (the root merges
    /// the edges' slots and runs the one global (version, worker)-ordered
    /// fold); only the wire/ledger shape changes. 0 or 1 (the default)
    /// keeps the flat single-aggregator path.
    pub aggregators: usize,
    /// deterministic fault injection (`federated.faults` / `--faults`,
    /// a [`crate::faults::FaultPlan`] spec string such as
    /// `"corrupt=0.05,crash=0.02,seed=7"`). `None` — and a plan whose
    /// every knob is zero — leaves the channel untouched, bit-for-bit.
    pub faults: Option<crate::faults::FaultPlan>,
    /// durable run store directory (`federated.run_store` /
    /// `--run-store`): after each round the leader persists a
    /// content-addressed snapshot (manifest + param/momenta/residual
    /// blobs) it can resume from after a crash.
    pub run_store: Option<String>,
    /// resume from `run_store` instead of starting fresh
    /// (`federated.resume` / `--resume`); requires `run_store`
    pub resume: bool,
    /// TCP listen address (`federated.listen` / `--listen`, e.g.
    /// `127.0.0.1:4800`; port 0 picks a free one): the leader binds here
    /// and waits for `efficientgrad worker --connect` processes instead
    /// of spawning in-process worker threads. `None` (the default) keeps
    /// the in-process fleet. Timing-only: never part of the config hash.
    pub listen: Option<String>,
    /// transport heartbeat period in ms (`federated.heartbeat_ms` /
    /// `--heartbeat-ms`): both sides of a TCP connection pulse at this
    /// rate, and a peer silent for 4 periods is declared dead — which
    /// feeds the ordinary dropout/resync machinery, never a hang.
    /// Timing-only: excluded from the config hash.
    pub heartbeat_ms: u64,
    /// per-frame send/recv deadline in ms (`federated.round_deadline_ms`
    /// / `--round-deadline-ms`): the longest the leader waits for a
    /// handshake, control round-trip, or blocked send before writing the
    /// peer off. Timing-only: excluded from the config hash.
    pub round_deadline_ms: u64,
    pub train: TrainConfig,
}

impl Default for FedConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            rounds: 10,
            local_steps: 20,
            iid: true,
            straggler_prob: 0.0,
            straggler_slowdown: 3.0,
            straggler_sleep: false,
            pipeline: false,
            dropout_prob: 0.0,
            comm: CommMode::default(),
            // the paper's P: comm pruning defaults to the same operating
            // point as the gradient pruning
            comm_rate: 0.9,
            comm_pruner: CommPruner::default(),
            wire_quant: WireQuant::default(),
            quorum: 1.0,
            // a late report one version old still carries half a fresh
            // report's weight; only consulted when quorum < 1.0
            staleness_decay: 0.5,
            // allow one round of stragglers in flight once a quorum is
            // configured; inert at the default quorum = 1.0
            pipeline_depth: 2,
            max_chain: 0,
            sample_m: 0,
            aggregators: 0,
            faults: None,
            run_store: None,
            resume: false,
            listen: None,
            heartbeat_ms: 50,
            round_deadline_ms: 30_000,
            train: TrainConfig::default(),
        }
    }
}

impl FedConfig {
    pub fn from_table(t: &Table) -> Result<Self> {
        let d = Self::default();
        let cfg = Self {
            workers: t.usize_or("federated.workers", d.workers),
            rounds: t.usize_or("federated.rounds", d.rounds),
            local_steps: t.usize_or("federated.local_steps", d.local_steps),
            iid: t.bool_or("federated.iid", d.iid),
            straggler_prob: t.f64_or("federated.straggler_prob", d.straggler_prob),
            straggler_slowdown: t.f64_or("federated.straggler_slowdown", d.straggler_slowdown),
            straggler_sleep: t.bool_or("federated.straggler_sleep", d.straggler_sleep),
            pipeline: t.bool_or("federated.pipeline", d.pipeline),
            dropout_prob: t.f64_or("federated.dropout_prob", d.dropout_prob),
            comm: t
                .get("federated.comm")
                .and_then(Value::as_str)
                .map(CommMode::parse)
                .transpose()
                .context("federated.comm")?
                .unwrap_or(d.comm),
            comm_rate: t.f64_or("federated.comm_rate", d.comm_rate),
            comm_pruner: t
                .get("federated.comm_pruner")
                .and_then(Value::as_str)
                .map(CommPruner::parse)
                .transpose()
                .context("federated.comm_pruner")?
                .unwrap_or(d.comm_pruner),
            wire_quant: t
                .get("federated.wire_quant")
                .and_then(Value::as_str)
                .map(WireQuant::parse)
                .transpose()
                .context("federated.wire_quant")?
                .unwrap_or(d.wire_quant),
            quorum: t.f64_or("federated.quorum", d.quorum),
            staleness_decay: t.f64_or("federated.staleness_decay", d.staleness_decay),
            pipeline_depth: t.usize_or("federated.pipeline_depth", d.pipeline_depth),
            max_chain: t.usize_or("federated.max_chain", d.max_chain),
            sample_m: t.usize_or("federated.sample_m", d.sample_m),
            aggregators: t.usize_or("federated.aggregators", d.aggregators),
            faults: t
                .get("federated.faults")
                .and_then(Value::as_str)
                .map(str::parse)
                .transpose()
                .context("federated.faults")?,
            run_store: t.get("federated.run_store").and_then(Value::as_str).map(String::from),
            resume: t.bool_or("federated.resume", d.resume),
            listen: t.get("federated.listen").and_then(Value::as_str).map(String::from),
            heartbeat_ms: t.usize_or("federated.heartbeat_ms", d.heartbeat_ms as usize) as u64,
            round_deadline_ms: t.usize_or(
                "federated.round_deadline_ms",
                d.round_deadline_ms as usize,
            ) as u64,
            train: TrainConfig::from_table(t)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range checks shared by every entry point (config file, CLI
    /// overrides, examples, `Leader::new`) — one normative copy.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..1.0).contains(&self.comm_rate) {
            bail!("comm_rate {} outside [0, 1)", self.comm_rate);
        }
        if !(0.0..=1.0).contains(&self.dropout_prob) {
            bail!("dropout_prob {} outside [0, 1]", self.dropout_prob);
        }
        if !(self.quorum > 0.0 && self.quorum <= 1.0) {
            bail!("quorum {} outside (0, 1]", self.quorum);
        }
        if !(0.0..=1.0).contains(&self.staleness_decay) {
            bail!("staleness_decay {} outside [0, 1]", self.staleness_decay);
        }
        if self.pipeline_depth == 0 {
            bail!("pipeline_depth must be at least 1");
        }
        if self.sample_m > self.workers {
            bail!("sample_m {} exceeds workers {}", self.sample_m, self.workers);
        }
        if self.aggregators > self.workers {
            bail!("aggregators {} exceeds workers {}", self.aggregators, self.workers);
        }
        if self.resume && self.run_store.is_none() {
            bail!("federated.resume needs federated.run_store (nowhere to resume from)");
        }
        if self.heartbeat_ms == 0 {
            bail!("heartbeat_ms must be at least 1");
        }
        if self.round_deadline_ms < self.heartbeat_ms {
            bail!(
                "round_deadline_ms {} below heartbeat_ms {} — every exchange would time out",
                self.round_deadline_ms,
                self.heartbeat_ms
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = Table::parse(
            r#"
            # comment
            top = 1
            [train]
            model = "resnet8"   # trailing comment
            lr = 0.1
            steps = 500
            verbose = true
            dims = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(t.get("top"), Some(&Value::Int(1)));
        assert_eq!(t.str_or("train.model", "x"), "resnet8");
        assert_eq!(t.f64_or("train.lr", 0.0), 0.1);
        assert_eq!(t.usize_or("train.steps", 0), 500);
        assert!(t.bool_or("train.verbose", false));
        assert_eq!(
            t.get("train.dims"),
            Some(&Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)]))
        );
    }

    #[test]
    fn merge_overrides() {
        let mut a = Table::parse("x = 1\ny = 2").unwrap();
        let b = Table::parse("y = 3").unwrap();
        a.merge(b);
        assert_eq!(a.get("y"), Some(&Value::Int(3)));
        assert_eq!(a.get("x"), Some(&Value::Int(1)));
    }

    #[test]
    fn typed_train_config() {
        let t = Table::parse("[train]\nmode = \"bp\"\nlr = 0.2").unwrap();
        let c = TrainConfig::from_table(&t).unwrap();
        assert_eq!(c.mode, "bp");
        assert_eq!(c.lr, 0.2);
        assert_eq!(c.momentum, 0.9); // default
        assert_eq!(c.residency, ResidencyMode::Resident); // default
    }

    #[test]
    fn residency_mode_parsing() {
        assert_eq!(ResidencyMode::parse("resident").unwrap(), ResidencyMode::Resident);
        assert_eq!(ResidencyMode::parse("device").unwrap(), ResidencyMode::Resident);
        assert_eq!(ResidencyMode::parse("literal").unwrap(), ResidencyMode::Literal);
        assert_eq!(ResidencyMode::parse("host").unwrap(), ResidencyMode::Literal);
        assert!(ResidencyMode::parse("ram").is_err());
        let t = Table::parse("[train]\nresidency = \"literal\"").unwrap();
        assert_eq!(
            TrainConfig::from_table(&t).unwrap().residency,
            ResidencyMode::Literal
        );
        // unknown value is an error, not a silent fallback — picking the
        // wrong backend would quietly invalidate parity/bench runs
        let t = Table::parse("[train]\nresidency = \"ram\"").unwrap();
        assert!(TrainConfig::from_table(&t).is_err());
        // unset stays default
        let t = Table::parse("[train]\nlr = 0.1").unwrap();
        assert_eq!(
            TrainConfig::from_table(&t).unwrap().residency,
            ResidencyMode::Resident
        );
    }

    #[test]
    fn eval_residency_mirrors_then_overrides() {
        // unset eval residency follows the step residency…
        let t = Table::parse("[train]\nresidency = \"literal\"").unwrap();
        let c = TrainConfig::from_table(&t).unwrap();
        assert_eq!(c.residency, ResidencyMode::Literal);
        assert_eq!(c.eval_residency, ResidencyMode::Literal);
        // …and an explicit value wins over the mirror
        let t = Table::parse(
            "[train]\nresidency = \"literal\"\neval_residency = \"resident\"",
        )
        .unwrap();
        let c = TrainConfig::from_table(&t).unwrap();
        assert_eq!(c.residency, ResidencyMode::Literal);
        assert_eq!(c.eval_residency, ResidencyMode::Resident);
        // invalid values error, like train.residency
        let t = Table::parse("[train]\neval_residency = \"ram\"").unwrap();
        assert!(TrainConfig::from_table(&t).is_err());
        // fully unset: both default resident
        let c = TrainConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.eval_residency, ResidencyMode::Resident);
    }

    #[test]
    fn comm_mode_parsing_and_defaults() {
        assert_eq!(CommMode::parse("dense").unwrap(), CommMode::Dense);
        assert_eq!(CommMode::parse("pruned").unwrap(), CommMode::Pruned);
        assert_eq!(CommMode::parse("sparse").unwrap(), CommMode::Pruned);
        assert_eq!(CommMode::parse("sign").unwrap(), CommMode::Sign);
        assert!(CommMode::parse("morse").is_err());
        // unset: legacy dense exchange at the paper's P
        let c = FedConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.comm, CommMode::Dense);
        assert_eq!(c.comm_rate, 0.9);
        assert_eq!(c.dropout_prob, 0.0);
        let t = Table::parse("[federated]\ncomm = \"sign\"\ncomm_rate = 0.5").unwrap();
        let c = FedConfig::from_table(&t).unwrap();
        assert_eq!(c.comm, CommMode::Sign);
        assert_eq!(c.comm_rate, 0.5);
        // schedule defaults to the sequential oracle; `pipeline = true`
        // (and the wall-clock straggler knob) parse from [federated]
        assert!(!c.pipeline);
        assert!(!c.straggler_sleep);
        let t =
            Table::parse("[federated]\npipeline = true\nstraggler_sleep = true").unwrap();
        let c = FedConfig::from_table(&t).unwrap();
        assert!(c.pipeline);
        assert!(c.straggler_sleep);
        // invalid values error like residency does — a silently wrong
        // comm mode would invalidate every byte row downstream
        let t = Table::parse("[federated]\ncomm = \"morse\"").unwrap();
        assert!(FedConfig::from_table(&t).is_err());
        let t = Table::parse("[federated]\ncomm_rate = 1.5").unwrap();
        assert!(FedConfig::from_table(&t).is_err());
        let t = Table::parse("[federated]\ndropout_prob = -0.1").unwrap();
        assert!(FedConfig::from_table(&t).is_err());
    }

    #[test]
    fn quorum_staleness_and_chain_parsing() {
        // unset: the full-barrier oracle schedule
        let c = FedConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.quorum, 1.0);
        assert_eq!(c.staleness_decay, 0.5);
        assert_eq!(c.pipeline_depth, 2);
        assert_eq!(c.max_chain, 0);
        assert_eq!(c.comm_pruner, CommPruner::Stochastic);
        let t = Table::parse(
            "[federated]\nquorum = 0.5\nstaleness_decay = 0.9\n\
             pipeline_depth = 3\nmax_chain = 4\ncomm_pruner = \"topk\"",
        )
        .unwrap();
        let c = FedConfig::from_table(&t).unwrap();
        assert_eq!(c.quorum, 0.5);
        assert_eq!(c.staleness_decay, 0.9);
        assert_eq!(c.pipeline_depth, 3);
        assert_eq!(c.max_chain, 4);
        assert_eq!(c.comm_pruner, CommPruner::TopK);
        // out-of-range / unknown values error, not silently clamp — a
        // wrong quorum would quietly change the round semantics
        for bad in [
            "[federated]\nquorum = 0.0",
            "[federated]\nquorum = 1.5",
            "[federated]\nstaleness_decay = -0.1",
            "[federated]\nstaleness_decay = 1.5",
            "[federated]\npipeline_depth = 0",
            "[federated]\ncomm_pruner = \"magnitude\"",
        ] {
            assert!(
                FedConfig::from_table(&Table::parse(bad).unwrap()).is_err(),
                "accepted {bad:?}"
            );
        }
        assert_eq!(CommPruner::parse("top-k").unwrap(), CommPruner::TopK);
        assert_eq!(CommPruner::TopK.as_str(), "topk");
    }

    #[test]
    fn wire_quant_parsing() {
        // unset: the legacy f32 wire, bit-for-bit
        let c = FedConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.wire_quant, WireQuant::Off);
        assert!(c.wire_quant.to_bits().is_none());
        let t = Table::parse("[federated]\ncomm = \"pruned\"\nwire_quant = \"q8\"").unwrap();
        let c = FedConfig::from_table(&t).unwrap();
        assert_eq!(c.wire_quant, WireQuant::Q8);
        assert_eq!(c.wire_quant.to_bits(), Some(crate::comm::wire::QuantBits::Q8));
        let t = Table::parse("[federated]\nwire_quant = \"int4\"").unwrap();
        assert_eq!(FedConfig::from_table(&t).unwrap().wire_quant, WireQuant::Q4);
        // unknown width errors, not silently off — a wrong wire_quant
        // would invalidate every byte row downstream
        let t = Table::parse("[federated]\nwire_quant = \"q2\"").unwrap();
        assert!(FedConfig::from_table(&t).is_err());
        assert_eq!(WireQuant::parse("int8").unwrap(), WireQuant::Q8);
        assert_eq!(WireQuant::Q4.as_str(), "q4");
    }

    #[test]
    fn sampling_and_hierarchy_parsing() {
        // unset: no cohort sampling, flat single-tier aggregation
        let c = FedConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.sample_m, 0);
        assert_eq!(c.aggregators, 0);
        let t = Table::parse("[federated]\nworkers = 16\nsample_m = 4\naggregators = 2").unwrap();
        let c = FedConfig::from_table(&t).unwrap();
        assert_eq!(c.sample_m, 4);
        assert_eq!(c.aggregators, 2);
        // a cohort (or edge tier) wider than the fleet is a config
        // error, not a silent clamp
        for bad in [
            "[federated]\nworkers = 4\nsample_m = 5",
            "[federated]\nworkers = 4\naggregators = 5",
        ] {
            assert!(
                FedConfig::from_table(&Table::parse(bad).unwrap()).is_err(),
                "accepted {bad:?}"
            );
        }
    }

    #[test]
    fn faults_and_run_store_parsing() {
        // unset: no chaos, no store, fresh start
        let c = FedConfig::from_table(&Table::default()).unwrap();
        assert!(c.faults.is_none());
        assert!(c.run_store.is_none());
        assert!(!c.resume);
        let t = Table::parse(
            "[federated]\nfaults = \"corrupt=0.05,kill=2,seed=7\"\n\
             run_store = \"/tmp/run\"\nresume = true",
        )
        .unwrap();
        let c = FedConfig::from_table(&t).unwrap();
        let plan = c.faults.unwrap();
        assert_eq!(plan.corrupt, 0.05);
        assert_eq!(plan.kill_round, Some(2));
        assert_eq!(plan.seed, 7);
        assert_eq!(c.run_store.as_deref(), Some("/tmp/run"));
        assert!(c.resume);
        // bad specs error at parse, not at round 40
        let t = Table::parse("[federated]\nfaults = \"corrupt=1.5\"").unwrap();
        assert!(FedConfig::from_table(&t).is_err());
        // resume without a store is a config error
        let t = Table::parse("[federated]\nresume = true").unwrap();
        assert!(FedConfig::from_table(&t).is_err());
    }

    #[test]
    fn transport_knobs_parse_with_in_process_default() {
        // unset: in-process fleet, stock heartbeat/deadline
        let c = FedConfig::from_table(&Table::default()).unwrap();
        assert!(c.listen.is_none());
        assert_eq!(c.heartbeat_ms, 50);
        assert_eq!(c.round_deadline_ms, 30_000);
        let t = Table::parse(
            "[federated]\nlisten = \"127.0.0.1:0\"\nheartbeat_ms = 20\n\
             round_deadline_ms = 5000",
        )
        .unwrap();
        let c = FedConfig::from_table(&t).unwrap();
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(c.heartbeat_ms, 20);
        assert_eq!(c.round_deadline_ms, 5000);
        // a zero heartbeat or a deadline shorter than one heartbeat
        // would make every exchange time out — config error, not a hang
        for bad in [
            "[federated]\nheartbeat_ms = 0",
            "[federated]\nheartbeat_ms = 100\nround_deadline_ms = 50",
        ] {
            assert!(
                FedConfig::from_table(&Table::parse(bad).unwrap()).is_err(),
                "accepted {bad:?}"
            );
        }
    }

    #[test]
    fn checkpoint_every_steps_parses_with_default_off() {
        let c = TrainConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.checkpoint_every_steps, 0);
        let t = Table::parse(
            "[train]\ncheckpoint = \"/tmp/ck.bin\"\ncheckpoint_every_steps = 25",
        )
        .unwrap();
        let c = TrainConfig::from_table(&t).unwrap();
        assert_eq!(c.checkpoint_every_steps, 25);
        assert_eq!(c.checkpoint.as_deref(), Some("/tmp/ck.bin"));
    }

    #[test]
    fn bad_syntax_rejected() {
        assert!(Table::parse("no_equals_here").is_err());
        assert!(Table::parse("x = @@").is_err());
    }
}
