//! Training metrics: per-step records, summaries, CSV export.

use std::path::Path;

/// One training step's observables.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub batch_acc: f64,
    pub lr: f64,
    /// mean realized gradient sparsity across pruned transports
    pub sparsity: f64,
    pub eval_acc: Option<f64>,
}

/// Append-only training log.
#[derive(Clone, Debug, Default)]
pub struct MetricsLog {
    pub records: Vec<StepRecord>,
}

impl MetricsLog {
    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the trailing `n` steps (smoother convergence signal).
    pub fn trailing_loss(&self, n: usize) -> Option<f64> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64)
    }

    pub fn mean_sparsity(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.sparsity).sum::<f64>() / self.records.len() as f64
    }

    /// Best eval accuracy seen.
    pub fn best_eval(&self) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.eval_acc)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// Loss curve downsampled to ~`points` entries (figure export).
    pub fn loss_curve(&self, points: usize) -> Vec<(usize, f64)> {
        if self.records.is_empty() {
            return vec![];
        }
        let stride = (self.records.len() / points.max(1)).max(1);
        self.records
            .iter()
            .step_by(stride)
            .map(|r| (r.step, r.loss))
            .collect()
    }

    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        let mut out = String::from("step,loss,batch_acc,lr,sparsity,eval_acc\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.4},{:.6},{:.4},{}\n",
                r.step,
                r.loss,
                r.batch_acc,
                r.lr,
                r.sparsity,
                r.eval_acc.map(|v| format!("{v:.4}")).unwrap_or_default()
            ));
        }
        crate::util::fs::atomic_write(path, out.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f64) -> StepRecord {
        StepRecord {
            step,
            loss,
            batch_acc: 0.5,
            lr: 0.1,
            sparsity: 0.4,
            eval_acc: if step == 5 { Some(0.7) } else { None },
        }
    }

    #[test]
    fn trailing_and_best() {
        let mut log = MetricsLog::default();
        for i in 0..10 {
            log.push(rec(i, 10.0 - i as f64));
        }
        assert_eq!(log.final_loss(), Some(1.0));
        assert!((log.trailing_loss(2).unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(log.best_eval(), Some(0.7));
        assert!((log.mean_sparsity() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip() {
        let mut log = MetricsLog::default();
        log.push(rec(0, 2.3));
        let p = std::env::temp_dir().join("effgrad_metrics_test.csv");
        log.save_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("step,loss"));
        assert!(text.contains("2.3"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn loss_curve_downsamples() {
        let mut log = MetricsLog::default();
        for i in 0..100 {
            log.push(rec(i, i as f64));
        }
        let c = log.loss_curve(10);
        assert!(c.len() >= 10 && c.len() <= 11);
        assert_eq!(c[0].0, 0);
    }
}
