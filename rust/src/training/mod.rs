//! Single-device trainer: the e2e driver binding dataset + ParamStore +
//! AOT train step, with LR scheduling, periodic eval, CSV metrics and
//! divergence watchdogs. The federated coordinator composes several of
//! these; `examples/train_cnn_e2e.rs` drives one directly.

pub mod metrics;

use anyhow::{bail, Context, Result};

use crate::config::{ResidencyMode, TrainConfig};
use crate::data::batcher::{eval_batches, prefetch_scoped};
use crate::data::Dataset;
use crate::manifest::{Manifest, ModelSpec};
use crate::params::ParamStore;
use crate::runtime::exec::EvalState;
use crate::runtime::{Runtime, StepDriver, TransferStats};

pub use metrics::{MetricsLog, StepRecord};

/// Learning-rate schedule.
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Const(f64),
    /// cosine decay from lr to lr*floor over total steps
    Cosine { lr: f64, total: usize, floor: f64 },
    /// step decay: lr * gamma^(step/every)
    Step { lr: f64, every: usize, gamma: f64 },
}

impl LrSchedule {
    pub fn from_config(cfg: &TrainConfig) -> Result<Self> {
        Ok(match cfg.lr_schedule.as_str() {
            "const" => LrSchedule::Const(cfg.lr),
            "cosine" => LrSchedule::Cosine {
                lr: cfg.lr,
                total: cfg.steps,
                floor: 0.05,
            },
            "step" => LrSchedule::Step {
                lr: cfg.lr,
                every: (cfg.steps / 3).max(1),
                gamma: 0.1,
            },
            other => bail!("unknown lr schedule {other:?}"),
        })
    }

    pub fn at(&self, step: usize) -> f64 {
        match *self {
            LrSchedule::Const(lr) => lr,
            LrSchedule::Cosine { lr, total, floor } => {
                let t = (step as f64 / total.max(1) as f64).min(1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                lr * (floor + (1.0 - floor) * cos)
            }
            LrSchedule::Step { lr, every, gamma } => {
                lr * gamma.powi((step / every.max(1)) as i32)
            }
        }
    }
}

/// A bound single-device trainer.
///
/// With `cfg.residency == Resident` (the default) the training state
/// lives on the device between steps and `store` is a lazily-synced
/// view: it is refreshed (via [`Trainer::sync_store`]) before literal
/// evals, checkpoints, and at the end of [`Trainer::run`]. With
/// `cfg.eval_residency == Resident` too (the default), evaluation feeds
/// the fwd artifact from the resident param buffers and never syncs —
/// a training run's only O(model) download is the final checkpoint
/// sync. External readers of `store` mid-run must call `sync_store`
/// first.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub model: ModelSpec,
    pub store: ParamStore,
    driver: StepDriver,
    eval_state: EvalState,
    pub log: MetricsLog,
}

impl Trainer {
    /// Build from manifest + runtime: loads (compiles) the train artifact
    /// for `cfg.mode` and the fwd artifact for eval, then binds the step
    /// backend selected by `cfg.residency`.
    pub fn new(rt: &Runtime, manifest: &Manifest, cfg: TrainConfig) -> Result<Self> {
        let model = manifest.model(&cfg.model)?.clone();
        let tag = format!("train_{}", cfg.mode);
        let art = model.artifact(&tag).with_context(|| {
            format!(
                "mode {:?} not exported for {}; available: {:?}",
                cfg.mode,
                model.name,
                model.train_modes()
            )
        })?;
        let store = ParamStore::init(&model, cfg.seed);
        let driver = StepDriver::new(cfg.residency, rt, rt.load(art)?, &model, &store)?;
        let eval_state =
            EvalState::new(rt, rt.load(model.artifact("fwd")?)?, &model, cfg.eval_residency)?;
        Ok(Self {
            cfg,
            model,
            store,
            driver,
            eval_state,
            log: MetricsLog::default(),
        })
    }

    /// Bring `store` up to date with the device state (no-op on the
    /// literal path). Call before reading `store` mid-run.
    pub fn sync_store(&mut self) -> Result<()> {
        self.driver.sync_to_host(&mut self.store)
    }

    /// Steps executed so far, authoritative regardless of residency.
    pub fn steps_done(&self) -> u64 {
        self.driver.steps_done(&self.store)
    }

    /// Combined host↔device traffic so far: the step backend's ledger
    /// plus the eval driver's (device-resident evals land in the step
    /// backend's ledger — they ride its buffers).
    pub fn transfer_stats(&self) -> TransferStats {
        self.driver.transfer_stats() + self.eval_state.transfer_stats()
    }

    /// Run `steps` steps over `train` (prefetched batcher: the next batch
    /// is gathered on a background thread while the current step
    /// executes), evaluating on `test` every `eval_every`. Returns final
    /// eval accuracy.
    pub fn run(&mut self, train: &Dataset, test: &Dataset) -> Result<f64> {
        let sched = LrSchedule::from_config(&self.cfg)?;
        let mut last_eval = 0.0;
        // scoped prefetch: borrows `train` (no clone); the receiver drops
        // at the end of the closure, which unblocks + joins the producer
        std::thread::scope(|scope| -> Result<()> {
            let batches =
                prefetch_scoped(scope, train, self.model.batch, self.cfg.seed ^ 0xBA7C, 2);
            for step in 0..self.cfg.steps {
                let batch = batches.recv().expect("prefetch thread died");
                let lr = sched.at(step) as f32;
                let out = self
                    .driver
                    .step(&mut self.store, &batch, lr, self.cfg.momentum as f32)?;
                if !out.loss.is_finite() {
                    bail!("loss diverged to {} at step {step}", out.loss);
                }
                self.log.push(StepRecord {
                    step,
                    loss: out.loss as f64,
                    batch_acc: out.acc as f64,
                    lr: lr as f64,
                    sparsity: crate::util::stats::mean(&out.sparsity),
                    eval_acc: None,
                });
                if step % self.cfg.log_every == 0 {
                    log::info!(
                        "[{}/{}] step {step:5} loss {:.4} acc {:.3} lr {:.4} sparsity {:.3}",
                        self.model.name,
                        self.cfg.mode,
                        out.loss,
                        out.acc,
                        lr,
                        crate::util::stats::mean(&out.sparsity),
                    );
                }
                self.periodic_checkpoint(step)?;
                if self.cfg.eval_every > 0
                    && (step + 1) % self.cfg.eval_every == 0
                {
                    // evaluate() syncs the store itself only when the
                    // eval path actually reads host params
                    last_eval = self.evaluate(test)?;
                    if let Some(r) = self.log.records.last_mut() {
                        r.eval_acc = Some(last_eval);
                    }
                    log::info!(
                        "[{}/{}] step {step:5} EVAL acc {:.4}",
                        self.model.name,
                        self.cfg.mode,
                        last_eval
                    );
                }
            }
            Ok(())
        })?;
        self.sync_store()?;
        if self.cfg.eval_every == 0 || self.cfg.steps % self.cfg.eval_every != 0 {
            last_eval = self.evaluate(test)?;
        }
        if let Some(path) = &self.cfg.checkpoint {
            self.store.save(std::path::Path::new(path))?;
        }
        Ok(last_eval)
    }

    /// Periodic mid-run checkpointing (`train.checkpoint_every_steps`):
    /// after step `step` (0-based), if the cadence lands and a
    /// checkpoint path is configured, bring the host store current and
    /// rewrite the checkpoint, so a killed run loses at most N steps.
    /// The sync rides the dirty flag — on the literal path (store never
    /// stale) it is free, on the resident path it is the O(model)
    /// download the cadence explicitly opts into. Returns whether a
    /// checkpoint was written.
    pub fn periodic_checkpoint(&mut self, step: usize) -> Result<bool> {
        // cadence check first: this runs every step of the hot loop
        let every = self.cfg.checkpoint_every_steps;
        if every == 0 || (step + 1) % every != 0 {
            return Ok(false);
        }
        let Some(path) = self.cfg.checkpoint.clone() else {
            return Ok(false);
        };
        self.sync_store()?;
        self.store.save(std::path::Path::new(&path))?;
        log::debug!(
            "checkpoint @ step {} -> {path} (periodic, every {every})",
            step + 1
        );
        Ok(true)
    }

    /// One externally-driven step (used by the Fig. 3 probe loop and the
    /// bench harness; `run` is the batteries-included path). Does NOT
    /// sync the host store in resident mode — call
    /// [`Trainer::sync_store`] before reading `store`.
    pub fn manual_step(&mut self, batch: &crate::data::Batch, lr: f32) -> Result<()> {
        let out = self
            .driver
            .step(&mut self.store, batch, lr, self.cfg.momentum as f32)?;
        if !out.loss.is_finite() {
            bail!("loss diverged to {}", out.loss);
        }
        self.log.push(StepRecord {
            step: self.steps_done() as usize - 1,
            loss: out.loss as f64,
            batch_acc: out.acc as f64,
            lr: lr as f64,
            sparsity: crate::util::stats::mean(&out.sparsity),
            eval_acc: None,
        });
        Ok(())
    }

    /// Full-sweep top-1 accuracy on a dataset.
    ///
    /// With resident step *and* eval backends the sweep runs off the
    /// device param buffers — zero state transfer, no store sync.
    /// Otherwise the host store is brought current first (a no-op on
    /// the literal step path) and the [`EvalState`] backend selected by
    /// `cfg.eval_residency` evaluates from host params.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<f64> {
        let device_eval = self.cfg.eval_residency == ResidencyMode::Resident
            && self.driver.mode() == ResidencyMode::Resident;
        if !device_eval {
            self.sync_store()?;
        }
        let mut correct_weighted = 0.0;
        let mut total = 0usize;
        for idx in eval_batches(ds, self.model.batch) {
            let batch = ds.gather(&idx);
            let acc = if device_eval {
                self.driver
                    .eval_accuracy(&self.store, &self.eval_state, &batch)?
            } else {
                self.eval_state.accuracy(&self.store, &batch)?
            };
            correct_weighted += acc * idx.len() as f64;
            total += idx.len();
        }
        if total == 0 {
            bail!("dataset smaller than one batch ({})", self.model.batch);
        }
        Ok(correct_weighted / total as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_decays_to_floor() {
        let s = LrSchedule::Cosine {
            lr: 1.0,
            total: 100,
            floor: 0.05,
        };
        assert!((s.at(0) - 1.0).abs() < 1e-9);
        assert!(s.at(50) < s.at(10));
        assert!((s.at(100) - 0.05).abs() < 1e-9);
        assert!((s.at(500) - 0.05).abs() < 1e-9); // clamped past total
    }

    #[test]
    fn step_schedule() {
        let s = LrSchedule::Step {
            lr: 1.0,
            every: 10,
            gamma: 0.1,
        };
        assert!((s.at(9) - 1.0).abs() < 1e-12);
        assert!((s.at(10) - 0.1).abs() < 1e-12);
        assert!((s.at(25) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn schedule_from_config_rejects_unknown() {
        let cfg = TrainConfig {
            lr_schedule: "warp".into(),
            ..Default::default()
        };
        assert!(LrSchedule::from_config(&cfg).is_err());
    }
}
