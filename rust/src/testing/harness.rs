//! Twin-run harness: run a federated config end to end, then pin one
//! run against another **bit for bit**.
//!
//! Half the coordinator's acceptance criteria share one shape: "knob X
//! must not change the result" — pipelining, full-barrier quorum, a
//! zero fault plan, kill/resume, `sample_m = N`, two-tier aggregation.
//! Each such pin is twin runs plus a field-by-field comparison, and the
//! comparison is where regressions hide: a hand-rolled pin that forgets
//! to compare a ledger silently stops guarding it. This module owns the
//! boilerplate once: [`run`] wraps the leader lifecycle, and
//! [`assert_twin_parity`] compares *every* field of a family so a pin
//! opts ledger families in or out ([`Parity`]) instead of enumerating
//! fields.
//!
//! Float comparisons use `to_bits()` — parity here means the identical
//! f64, not "close enough"; byte ledgers and schedules compare with
//! `==`. The `wire` family deliberately EXCLUDES the fleet-tier fields
//! (`aggregators`, `tier_upload_bytes`): the two-tier acceptance pin
//! runs flat vs tiered twins whose tier ledgers *must* differ while
//! every PR-6-era ledger stays identical — tier fields are asserted
//! against the `docs/TRANSFER_MODEL.md` §Fleet tier formula separately.
//! `transport_bytes` is likewise excluded: heartbeat counts depend on
//! wall-clock timing, so the TCP-vs-in-process pin demands identical
//! payload/envelope ledgers while the transport-plane tax differs by
//! construction (in-process is always 0; see §Transport tier).

use anyhow::Result;

use crate::config::FedConfig;
use crate::coordinator::{FedSummary, Leader, RoundReport};
use crate::manifest::Manifest;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// One finished federated run: the summary plus the leader's final
/// global params (captured before shutdown).
pub struct TwinRun {
    pub summary: FedSummary,
    pub params: Vec<Tensor>,
}

/// The leader lifecycle boilerplate every integration pin repeats:
/// build, run, capture the global params, shut the fleet down.
pub fn run(rt: &Runtime, m: &Manifest, cfg: FedConfig) -> Result<TwinRun> {
    let mut leader = Leader::new(rt, m, cfg)?;
    let summary = leader.run()?;
    let params = leader.global_params().to_vec();
    leader.shutdown();
    Ok(TwinRun { summary, params })
}

/// Which ledger families a twin pin compares. Families exist because
/// some twins legitimately differ in one dimension — e.g. the
/// poisoned-vs-crashed pin demands identical trajectories but *different*
/// wire ledgers (one run paid for a retry) — and the pin should opt that
/// family out, not hand-enumerate the rest.
#[derive(Clone, Copy)]
pub struct Parity {
    /// final global params, element-exact
    pub params: bool,
    /// per-round `mean_loss`/`mean_sparsity`/`eval_acc` + `final_acc`,
    /// compared by `f64::to_bits`
    pub metrics: bool,
    /// payload + envelope byte ledgers, survivor counts, run totals —
    /// the PR-6-era wire surface (fleet-tier fields excluded, see the
    /// module docs)
    pub wire: bool,
    /// dispatch bookkeeping: versions, cohorts, dropouts, resyncs
    /// (dense + chained), retries, late folds, fault counters
    pub schedule: bool,
    /// host↔device transfer ledgers (per worker, per round, totals)
    pub device: bool,
}

impl Parity {
    /// Every family — the default for "knob X is a pure no-op" pins.
    pub fn full() -> Self {
        Self {
            params: true,
            metrics: true,
            wire: true,
            schedule: true,
            device: true,
        }
    }

    /// Model trajectory only (params + metrics) — for twins that take
    /// deliberately different wire/schedule paths to the same state.
    pub fn trajectory() -> Self {
        Self {
            params: true,
            metrics: true,
            wire: false,
            schedule: false,
            device: false,
        }
    }
}

/// Pin run `b` against run `a` under the given families. `label` names
/// the pin in failure messages.
pub fn assert_twin_parity(label: &str, a: &TwinRun, b: &TwinRun, p: Parity) {
    if p.params {
        assert_eq!(a.params, b.params, "{label}: global params diverged");
    }
    assert_eq!(
        a.summary.rounds.len(),
        b.summary.rounds.len(),
        "{label}: round counts differ"
    );
    assert_round_parity(label, &a.summary.rounds, &b.summary.rounds, p);
    if p.metrics {
        assert_eq!(
            a.summary.final_acc.to_bits(),
            b.summary.final_acc.to_bits(),
            "{label}: final_acc {} vs {}",
            a.summary.final_acc,
            b.summary.final_acc
        );
    }
    if p.wire {
        assert_eq!(
            a.summary.total_upload_bytes, b.summary.total_upload_bytes,
            "{label}: total uplink ledger"
        );
        assert_eq!(
            a.summary.total_download_bytes, b.summary.total_download_bytes,
            "{label}: total downlink ledger"
        );
    }
    if p.device {
        assert_eq!(
            a.summary.total_device_transfer, b.summary.total_device_transfer,
            "{label}: total device ledger"
        );
    }
}

/// Round-by-round comparison over any two equally long round sequences.
/// Exposed separately so stitched runs (kill + resume) can chain their
/// segments against the uninterrupted twin.
pub fn assert_round_parity<'a, A, B>(label: &str, a: A, b: B, p: Parity)
where
    A: IntoIterator<Item = &'a RoundReport>,
    B: IntoIterator<Item = &'a RoundReport>,
{
    let mut ia = a.into_iter();
    let mut ib = b.into_iter();
    loop {
        let (x, y) = match (ia.next(), ib.next()) {
            (Some(x), Some(y)) => (x, y),
            (None, None) => break,
            _ => panic!("{label}: round sequences have different lengths"),
        };
        let r = x.round;
        assert_eq!(r, y.round, "{label}: round index mismatch");
        if p.metrics {
            assert_eq!(
                x.eval_acc.to_bits(),
                y.eval_acc.to_bits(),
                "{label} round {r}: eval_acc {} vs {}",
                x.eval_acc,
                y.eval_acc
            );
            assert_eq!(
                x.mean_loss.to_bits(),
                y.mean_loss.to_bits(),
                "{label} round {r}: mean_loss"
            );
            assert_eq!(
                x.mean_sparsity.to_bits(),
                y.mean_sparsity.to_bits(),
                "{label} round {r}: mean_sparsity"
            );
        }
        if p.wire {
            assert_eq!(x.upload_bytes, y.upload_bytes, "{label} round {r}: uplink bytes");
            assert_eq!(
                x.download_bytes, y.download_bytes,
                "{label} round {r}: downlink bytes"
            );
            assert_eq!(
                x.envelope_bytes, y.envelope_bytes,
                "{label} round {r}: envelope bytes"
            );
            assert_eq!(
                x.uplink_survivors, y.uplink_survivors,
                "{label} round {r}: uplink survivors"
            );
            assert_eq!(
                x.downlink_survivors, y.downlink_survivors,
                "{label} round {r}: downlink survivors"
            );
        }
        if p.schedule {
            assert_eq!(x.version, y.version, "{label} round {r}: model version");
            assert_eq!(x.dispatched, y.dispatched, "{label} round {r}: dispatched");
            assert_eq!(x.cohort, y.cohort, "{label} round {r}: cohort");
            assert_eq!(x.dropped, y.dropped, "{label} round {r}: dropouts");
            assert_eq!(
                x.dense_downlinks, y.dense_downlinks,
                "{label} round {r}: dense resyncs"
            );
            assert_eq!(
                x.chained_downlinks, y.chained_downlinks,
                "{label} round {r}: chained resyncs"
            );
            assert_eq!(
                x.downlink_retries, y.downlink_retries,
                "{label} round {r}: retries"
            );
            assert_eq!(x.late_reports, y.late_reports, "{label} round {r}: late folds");
            assert_eq!(
                x.stale_weight_mass.to_bits(),
                y.stale_weight_mass.to_bits(),
                "{label} round {r}: stale mass"
            );
            assert_eq!(
                x.corrupt_frames, y.corrupt_frames,
                "{label} round {r}: corrupt frames"
            );
            assert_eq!(
                x.rejected_reports, y.rejected_reports,
                "{label} round {r}: rejected reports"
            );
        }
        if p.device {
            assert_eq!(
                x.worker_transfer, y.worker_transfer,
                "{label} round {r}: per-worker device ledger"
            );
            assert_eq!(
                x.device_transfer, y.device_transfer,
                "{label} round {r}: round device ledger"
            );
            assert_eq!(
                x.leader_eval_transfer, y.leader_eval_transfer,
                "{label} round {r}: leader eval ledger"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TransferStats;

    fn round(r: usize) -> RoundReport {
        RoundReport {
            round: r,
            version: r as u64 + 1,
            mean_loss: 1.5 - r as f64 * 0.1,
            mean_sparsity: 0.875,
            upload_bytes: 1000 + r as u64,
            download_bytes: 900,
            envelope_bytes: 96,
            transport_bytes: 0,
            dispatched: 2,
            dropped: Vec::new(),
            corrupt_frames: 0,
            rejected_reports: 0,
            downlink_retries: 0,
            dense_downlinks: if r == 0 { 2 } else { 0 },
            chained_downlinks: 0,
            cohort: Vec::new(),
            aggregators: 1,
            tier_upload_bytes: 0,
            late_reports: 0,
            stale_weight_mass: 0.0,
            uplink_survivors: 37,
            downlink_survivors: 12,
            eval_acc: 0.25 + r as f64 * 0.05,
            wall_secs: 0.5,
            leader_secs: 0.1,
            worker_secs: vec![0.2, 0.3],
            worker_transfer: vec![TransferStats::default(); 2],
            device_transfer: TransferStats::default(),
            leader_eval_transfer: TransferStats::default(),
        }
    }

    #[test]
    fn parity_passes_on_identical_rounds_and_ignores_timing() {
        let mut a = round(1);
        let mut b = round(1);
        // wall-clock fields are noise, never part of any family
        a.wall_secs = 0.1;
        b.wall_secs = 9.9;
        a.leader_secs = 0.01;
        b.leader_secs = 0.5;
        let (va, vb) = (vec![a], vec![b]);
        assert_round_parity("timing", &va, &vb, Parity::full());
    }

    #[test]
    #[should_panic(expected = "uplink bytes")]
    fn parity_catches_a_wire_drift() {
        let a = round(2);
        let mut b = round(2);
        b.upload_bytes += 1;
        let (va, vb) = (vec![a], vec![b]);
        assert_round_parity("wire", &va, &vb, Parity::full());
    }

    #[test]
    fn families_opt_out() {
        let a = round(0);
        let mut b = round(0);
        b.upload_bytes += 8; // wire drifts...
        let (va, vb) = (vec![a], vec![b]);
        // ...but a trajectory-only pin does not care
        assert_round_parity("traj", &va, &vb, Parity::trajectory());
    }

    #[test]
    #[should_panic(expected = "different lengths")]
    fn parity_catches_length_mismatch() {
        let va = vec![round(0), round(1)];
        let vb = vec![round(0)];
        assert_round_parity("len", &va, &vb, Parity::full());
    }

    #[test]
    #[should_panic(expected = "cohort")]
    fn parity_catches_a_cohort_drift() {
        let a = round(3);
        let mut b = round(3);
        b.cohort = vec![1, 2];
        let (va, vb) = (vec![a], vec![b]);
        assert_round_parity("cohort", &va, &vb, Parity::full());
    }

    #[test]
    fn transport_plane_bytes_are_not_in_the_wire_family() {
        // the TCP-vs-in-process pin depends on this: the twins must pass
        // a full-parity check even though only the TCP side pays a
        // (timing-dependent) heartbeat/handshake/length-prefix tax
        let a = round(5);
        let mut b = round(5);
        b.transport_bytes = 8_192;
        let (va, vb) = (vec![a], vec![b]);
        assert_round_parity("transport", &va, &vb, Parity::full());
    }

    #[test]
    fn tier_fields_are_not_in_the_wire_family() {
        // the two-tier acceptance pin depends on this: tiered vs flat
        // twins must pass a full-parity check even though their tier
        // ledgers differ
        let a = round(4);
        let mut b = round(4);
        b.aggregators = 4;
        b.tier_upload_bytes = 4096;
        let (va, vb) = (vec![a], vec![b]);
        assert_round_parity("tier", &va, &vb, Parity::full());
    }
}
