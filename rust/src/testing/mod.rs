//! Property-testing harness (no `proptest` offline; DESIGN.md
//! substitutions). Provides seeded generators and a `for_all` driver with
//! greedy input shrinking on failure — enough to express the coordinator
//! and simulator invariants the test plan calls for.

use crate::util::rng::Rng;

pub mod harness;

/// Number of cases per property (override with EFFICIENTGRAD_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("EFFICIENTGRAD_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// A generator of random values of `T`.
pub trait Gen<T> {
    fn sample(&self, rng: &mut Rng) -> T;
    /// Candidate smaller versions of a failing input (greedy shrink).
    fn shrink(&self, _value: &T) -> Vec<T> {
        Vec::new()
    }
}

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen<usize> for UsizeIn {
    fn sample(&self, rng: &mut Rng) -> usize {
        self.0 + rng.below((self.1 - self.0 + 1) as u64) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
        }
        out.dedup();
        out.retain(|x| x != v);
        out
    }
}

/// Uniform f64 in [lo, hi).
pub struct F64In(pub f64, pub f64);

impl Gen<f64> for F64In {
    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.uniform_in(self.0, self.1)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = (self.0 + self.1) / 2.0;
        if (*v - self.0).abs() > 1e-9 {
            vec![self.0, (self.0 + *v) / 2.0, mid]
        } else {
            vec![]
        }
    }
}

/// Vec<f32> of length in [1, max_len], N(0, sigma).
pub struct NormalVec {
    pub max_len: usize,
    pub sigma: f32,
}

impl Gen<Vec<f32>> for NormalVec {
    fn sample(&self, rng: &mut Rng) -> Vec<f32> {
        let n = 1 + rng.below(self.max_len as u64) as usize;
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v, self.sigma);
        v
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        out
    }
}

/// Run `prop` over `cases` random inputs; on failure, greedily shrink and
/// panic with the minimal failing input (Debug-printed).
pub fn for_all<T, G, F>(seed: u64, gen: &G, cases: usize, mut prop: F)
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    F: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut budget = 200;
            while improved && budget > 0 {
                improved = false;
                for cand in gen.shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed})\n  minimal input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Two-generator convenience.
pub fn for_all2<A, B, GA, GB, F>(seed: u64, ga: &GA, gb: &GB, cases: usize, mut prop: F)
where
    A: std::fmt::Debug + Clone,
    B: std::fmt::Debug + Clone,
    GA: Gen<A>,
    GB: Gen<B>,
    F: FnMut(&A, &B) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let a = ga.sample(&mut rng);
        let b = gb.sample(&mut rng);
        if let Err(msg) = prop(&a, &b) {
            panic!("property failed (case {case}, seed {seed})\n  input: ({a:?}, {b:?})\n  error: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        for_all(0, &UsizeIn(1, 100), 50, |&n| {
            if n >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        for_all(0, &UsizeIn(1, 1000), 200, |&n| {
            if n < 10 {
                Ok(())
            } else {
                Err(format!("{n} too big"))
            }
        });
    }

    #[test]
    fn normal_vec_lengths_in_range() {
        let g = NormalVec {
            max_len: 16,
            sigma: 1.0,
        };
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = g.sample(&mut rng);
            assert!((1..=16).contains(&v.len()));
        }
    }
}
