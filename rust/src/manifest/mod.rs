//! `artifacts/manifest.json` — the contract between the Python compile
//! path and the Rust runtime. Parsed once at startup into typed structs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// How a parameter / feedback tensor is initialized (mirrors the spec the
/// Python layer emitted; Rust owns actual initialization).
#[derive(Clone, Debug, PartialEq)]
pub enum Init {
    HeNormal { fan_in: usize },
    GlorotNormal { fan_in: usize, fan_out: usize },
    Ones,
    Zeros,
}

/// One parameter or feedback tensor.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl TensorSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One AOT-compiled HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub tag: String,
    pub file: PathBuf,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// Conv/dense layer descriptor for the accelerator simulator.
#[derive(Clone, Debug)]
pub struct LayerDesc {
    pub kind: LayerKind,
    pub name: String,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub ci: usize,
    pub co: usize,
    pub k: usize,
    pub stride: usize,
    pub oh: usize,
    pub ow: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Dense,
}

impl LayerDesc {
    /// Forward MACs of this layer.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                (self.n * self.oh * self.ow) as u64
                    * (self.k * self.k * self.ci * self.co) as u64
            }
            LayerKind::Dense => (self.n * self.ci * self.co) as u64,
        }
    }
}

/// One exported model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub params: Vec<TensorSpec>,
    pub feedback: Vec<TensorSpec>,
    pub batch: usize,
    pub image: [usize; 3],
    pub num_classes: usize,
    pub prune_rate: f64,
    pub param_count: usize,
    pub layers: Vec<LayerDesc>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ModelSpec {
    pub fn artifact(&self, tag: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(tag)
            .ok_or_else(|| anyhow!("model {} has no artifact {tag:?}", self.name))
    }

    /// Train-mode tags available (e.g. "bp", "efficientgrad").
    pub fn train_modes(&self) -> Vec<String> {
        self.artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("train_").map(String::from))
            .collect()
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub prune_rate: f64,
    pub models: BTreeMap<String, ModelSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Self::from_json(&j, dir)
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model {name:?}; have {:?}", self.models.keys()))
    }

    fn from_json(j: &Json, dir: &Path) -> Result<Self> {
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let prune_rate = j.get("prune_rate").and_then(Json::as_f64).unwrap_or(0.9);
        let mut models = BTreeMap::new();
        let mobj = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?;
        for (name, mj) in mobj {
            models.insert(name.clone(), parse_model(name, mj, dir)?);
        }
        Ok(Self {
            prune_rate,
            models,
            dir: dir.to_path_buf(),
        })
    }
}

fn parse_tensor_spec(j: &Json) -> Result<TensorSpec> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor spec missing name"))?
        .to_string();
    let shape: Vec<usize> = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("{name}: bad dim")))
        .collect::<Result<_>>()?;
    let init_j = j.get("init").ok_or_else(|| anyhow!("{name}: missing init"))?;
    let kind = init_j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{name}: missing init.kind"))?;
    let init = match kind {
        "he_normal" => Init::HeNormal {
            fan_in: init_j
                .get("fan_in")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{name}: he_normal needs fan_in"))?,
        },
        "glorot_normal" => Init::GlorotNormal {
            fan_in: init_j
                .get("fan_in")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{name}: glorot needs fan_in"))?,
            fan_out: init_j
                .get("fan_out")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{name}: glorot needs fan_out"))?,
        },
        "ones" => Init::Ones,
        "zeros" => Init::Zeros,
        other => bail!("{name}: unknown init kind {other:?}"),
    };
    Ok(TensorSpec { name, shape, init })
}

fn parse_model(name: &str, j: &Json, dir: &Path) -> Result<ModelSpec> {
    let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
        j.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: missing {key}"))?
            .iter()
            .map(parse_tensor_spec)
            .collect()
    };
    let params = parse_specs("params")?;
    let feedback = parse_specs("feedback")?;
    let image_arr = j
        .get("image")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: missing image"))?;
    if image_arr.len() != 3 {
        bail!("{name}: image must be rank 3");
    }
    let mut image = [0usize; 3];
    for (i, v) in image_arr.iter().enumerate() {
        image[i] = v.as_usize().ok_or_else(|| anyhow!("{name}: bad image dim"))?;
    }

    let mut layers = Vec::new();
    for lj in j
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: missing layers"))?
    {
        let kind = match lj.get("kind").and_then(Json::as_str) {
            Some("conv") => LayerKind::Conv,
            Some("dense") => LayerKind::Dense,
            other => bail!("{name}: bad layer kind {other:?}"),
        };
        let get = |k: &str| lj.get(k).and_then(Json::as_usize).unwrap_or(0);
        layers.push(LayerDesc {
            kind,
            name: lj
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string(),
            n: get("n"),
            h: get("h"),
            w: get("w"),
            ci: get("ci"),
            co: get("co"),
            k: get("k"),
            stride: get("stride").max(1),
            oh: get("oh"),
            ow: get("ow"),
        });
    }

    let mut artifacts = BTreeMap::new();
    for (tag, aj) in j
        .get("artifacts")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("{name}: missing artifacts"))?
    {
        let file = aj
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{name}/{tag}: missing file"))?;
        let names = |k: &str| -> Result<Vec<String>> {
            Ok(aj
                .get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}/{tag}: missing {k}"))?
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect())
        };
        artifacts.insert(
            tag.clone(),
            ArtifactSpec {
                tag: tag.clone(),
                file: dir.join(file),
                inputs: names("inputs")?,
                outputs: names("outputs")?,
            },
        );
    }

    Ok(ModelSpec {
        name: name.to_string(),
        params,
        feedback,
        batch: j
            .get("batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("{name}: missing batch"))?,
        image,
        num_classes: j.get("num_classes").and_then(Json::as_usize).unwrap_or(10),
        prune_rate: j.get("prune_rate").and_then(Json::as_f64).unwrap_or(0.9),
        param_count: j.get("param_count").and_then(Json::as_usize).unwrap_or(0),
        layers,
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> &'static str {
        r#"{
          "version": 1,
          "prune_rate": 0.9,
          "models": {
            "toy": {
              "params": [
                {"name": "w", "shape": [3,3,3,8], "dtype": "f32",
                 "init": {"kind": "he_normal", "fan_in": 27}},
                {"name": "g", "shape": [8], "dtype": "f32", "init": {"kind": "ones"}}
              ],
              "feedback": [
                {"name": "B", "shape": [3,3,3,8], "dtype": "f32",
                 "init": {"kind": "he_normal", "fan_in": 27}}
              ],
              "batch": 4, "image": [32,32,3], "num_classes": 10,
              "prune_rate": 0.9, "param_count": 224,
              "layers": [
                {"kind":"conv","name":"c","n":4,"h":32,"w":32,"ci":3,"co":8,
                 "k":3,"stride":1,"oh":32,"ow":32},
                {"kind":"dense","name":"fc","n":4,"ci":8,"co":10}
              ],
              "artifacts": {
                "train_bp": {"file": "toy_train_bp.hlo.txt",
                  "inputs": ["w","g","m.w","m.g","B","images","labels","lr","mu","seed"],
                  "outputs": ["out.w","out.g","out.m.w","out.m.g","loss","acc","sparsity[1]"]}
              }
            }
          }
        }"#
    }

    #[test]
    fn parses_toy_manifest() {
        let j = Json::parse(toy_manifest()).unwrap();
        let m = Manifest::from_json(&j, Path::new("/tmp/arts")).unwrap();
        let model = m.model("toy").unwrap();
        assert_eq!(model.params.len(), 2);
        assert_eq!(model.params[0].init, Init::HeNormal { fan_in: 27 });
        assert_eq!(model.params[0].len(), 216);
        assert_eq!(model.feedback.len(), 1);
        assert_eq!(model.batch, 4);
        assert_eq!(model.layers.len(), 2);
        assert_eq!(model.layers[0].macs(), 4 * 32 * 32 * 27 * 8);
        assert_eq!(model.layers[1].macs(), 4 * 8 * 10);
        let art = model.artifact("train_bp").unwrap();
        assert_eq!(art.inputs.len(), 10);
        assert!(art.file.ends_with("toy_train_bp.hlo.txt"));
        assert_eq!(model.train_modes(), vec!["bp".to_string()]);
    }

    #[test]
    fn rejects_bad_version() {
        let j = Json::parse(r#"{"version": 2, "models": {}}"#).unwrap();
        assert!(Manifest::from_json(&j, Path::new(".")).is_err());
    }

    #[test]
    fn missing_model_errors() {
        let j = Json::parse(toy_manifest()).unwrap();
        let m = Manifest::from_json(&j, Path::new(".")).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.model("toy").unwrap().artifact("nope").is_err());
    }
}
