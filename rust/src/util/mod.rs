//! Foundation substrates built in-repo (no network; see DESIGN.md
//! substitutions): PRNG, JSON, statistics, logging.

pub mod backoff;
pub mod fs;
pub mod json;
pub mod logging;
pub mod par;
pub mod rng;
pub mod simd;
pub mod stats;
