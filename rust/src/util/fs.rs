//! Atomic file writes: stage into a temp sibling, then `rename` into
//! place. On POSIX the rename is atomic within a filesystem, so readers
//! (and a resume after a mid-write kill) see either the old file or the
//! complete new one — never a torn prefix. Every checkpoint, manifest
//! and bench-report write in the repo routes through here.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Temp sibling used while staging: `<name>.tmp.<pid>` next to the
/// target, so the final `rename` never crosses a filesystem boundary.
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(format!(".tmp.{}", std::process::id()));
    path.with_file_name(name)
}

/// Write `bytes` to `path` atomically (temp sibling + rename), creating
/// parent directories as needed.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = tmp_sibling(path);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("effgrad_fs_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn writes_and_overwrites_without_leftovers() {
        let dir = tmpdir("rw");
        let path = dir.join("nested/report.json");
        atomic_write(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        atomic_write(&path, b"two-longer").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two-longer");
        // no staging files left behind
        let leftovers: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .filter(|n| n.to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "staging leftovers: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bare_filename_has_no_parent_to_create() {
        // path with an empty parent component must not try create_dir_all("")
        let cwd_file = tmpdir("bare").join("x.bin");
        atomic_write(&cwd_file, &[1, 2, 3]).unwrap();
        assert_eq!(std::fs::read(&cwd_file).unwrap(), vec![1, 2, 3]);
        let _ = std::fs::remove_dir_all(cwd_file.parent().unwrap());
    }
}
