//! Vectorized host kernels with a scalar bit-for-bit oracle.
//!
//! Every hot loop in the coordinator path (FedAvg folds, the eq. 3 threshold
//! pass, the sign bit-plane codec) funnels through this module. The scalar
//! implementations here are the *normative* definitions; the AVX2/BMI2 paths
//! (compiled only under `--features simd` on x86_64, selected only when the
//! CPU reports avx2+bmi2+popcnt at runtime) are pinned bit-for-bit against
//! them by the unit tests at the bottom of this file and by the federated
//! twin-run pin in `tests/federated.rs`.
//!
//! Why bit parity is achievable at all:
//!
//! * Elementwise kernels (`add_assign`, `axpy`, `scale`, `scaled`,
//!   `fold_delta`, `widen`, `narrow`, …) do the same IEEE ops per lane in the
//!   same order — a vector lane add is the same rounding as a scalar add. We
//!   never use FMA intrinsics: fused multiply-add rounds once where the
//!   scalar path rounds twice, which would change bits.
//! * Reductions are defined as *lane-striped* sums: `STRIPE` (= 8) f64
//!   accumulators, element `i` folding into accumulator `i % STRIPE`, lanes
//!   combined sequentially at the end. The scalar path implements exactly
//!   this shape, so the AVX2 path (two 4×f64 accumulators) produces the same
//!   bits. `util::par`'s fixed `CHUNK` boundaries then make the whole-tensor
//!   result independent of thread count, simd or not.
//! * The eq. 3 prune kernel consumes `Rng::uniform()` draws serially in
//!   element order (one draw per in-band element) even on the vector path,
//!   leaving the generator in an identical state.
//! * The sign bit-plane codec builds the same words: `presence` bit iff
//!   `v != 0.0` (true for NaN, false for ±0.0), sign bit iff `v < 0.0`
//!   (false for NaN) — `_CMP_NEQ_UQ` / `_CMP_LT_OQ` have exactly those
//!   semantics, and BMI2 `pext`/`pdep` reproduce the survivor-order bit
//!   compaction of the scalar push loop.
//!
//! Dispatch is per-call: `active()` is an atomic load plus a cached cpuid
//! check, cheap enough to sit inside per-chunk closures. `force_scalar(true)`
//! pins the oracle path for twin runs and benches; the `EFFICIENTGRAD_SIMD=0`
//! environment variable is a field kill-switch.

use std::sync::atomic::{AtomicBool, Ordering};

/// Number of f64 accumulator lanes in the striped reductions. Fixed by the
/// wire/ledger contract — changing it changes every σ and magnitude byte.
pub const STRIPE: usize = 8;

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Test/bench override: route every dispatching kernel to the scalar oracle.
/// Global (affects concurrent callers); that is safe precisely because the
/// two paths are pinned bit-identical — if the flag is observable in any
/// output, a parity test has already failed.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// True when the vector kernels are compiled into this build at all.
pub fn compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn detected() -> bool {
    static CAPS: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *CAPS.get_or_init(|| {
        is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("bmi2")
            && is_x86_feature_detected!("popcnt")
    })
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn env_enabled() -> bool {
    static ENV: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ENV.get_or_init(|| std::env::var("EFFICIENTGRAD_SIMD").map_or(true, |v| v != "0"))
}

/// True when vector kernels are compiled AND the CPU supports them
/// (ignores `force_scalar` and the environment kill-switch).
pub fn available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        detected()
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// True when the next dispatching kernel call will take the vector path.
pub fn active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        detected() && env_enabled() && !FORCE_SCALAR.load(Ordering::Relaxed)
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

// ---------------------------------------------------------------------------
// Elementwise kernels (dispatching). Identical per-lane IEEE ops both paths.
// ---------------------------------------------------------------------------

/// `dst[i] += src[i]`.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::add_assign_avx2(dst, src) };
        return;
    }
    add_assign_scalar(dst, src)
}

fn add_assign_scalar(dst: &mut [f32], src: &[f32]) {
    for (x, &y) in dst.iter_mut().zip(src) {
        *x += y;
    }
}

/// `dst[i] += alpha * src[i]` (mul then add — two roundings, never FMA).
pub fn axpy(dst: &mut [f32], alpha: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::axpy_avx2(dst, alpha, src) };
        return;
    }
    axpy_scalar(dst, alpha, src)
}

fn axpy_scalar(dst: &mut [f32], alpha: f32, src: &[f32]) {
    for (x, &y) in dst.iter_mut().zip(src) {
        *x += alpha * y;
    }
}

/// `dst[i] *= alpha`.
pub fn scale(dst: &mut [f32], alpha: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::scale_avx2(dst, alpha) };
        return;
    }
    scale_scalar(dst, alpha)
}

fn scale_scalar(dst: &mut [f32], alpha: f32) {
    for x in dst.iter_mut() {
        *x *= alpha;
    }
}

/// `dst[i] = alpha * src[i]`.
pub fn scaled(dst: &mut [f32], alpha: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::scaled_avx2(dst, alpha, src) };
        return;
    }
    scaled_scalar(dst, alpha, src)
}

fn scaled_scalar(dst: &mut [f32], alpha: f32, src: &[f32]) {
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = alpha * v;
    }
}

/// Residual fold: `res[i] += local[i] - reference[i]` (sub then add).
pub fn fold_delta(res: &mut [f32], local: &[f32], reference: &[f32]) {
    debug_assert_eq!(res.len(), local.len());
    debug_assert_eq!(res.len(), reference.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::fold_delta_avx2(res, local, reference) };
        return;
    }
    fold_delta_scalar(res, local, reference)
}

fn fold_delta_scalar(res: &mut [f32], local: &[f32], reference: &[f32]) {
    for (x, (&a, &b)) in res.iter_mut().zip(local.iter().zip(reference)) {
        *x += a - b;
    }
}

/// `dst[i] = src[i].abs()` (clears the sign bit, NaN included — same as
/// `f32::abs`).
pub fn abs_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::abs_into_avx2(dst, src) };
        return;
    }
    abs_into_scalar(dst, src)
}

fn abs_into_scalar(dst: &mut [f32], src: &[f32]) {
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = v.abs();
    }
}

/// `dst[i] = src[i] as f64` (exact widening).
pub fn widen(dst: &mut [f64], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::widen_avx2(dst, src) };
        return;
    }
    widen_scalar(dst, src)
}

fn widen_scalar(dst: &mut [f64], src: &[f32]) {
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = v as f64;
    }
}

/// `dst[i] += alpha * (src[i] as f64)` — the f64 FedAvg accumulator fold.
pub fn axpy_widen(dst: &mut [f64], alpha: f64, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::axpy_widen_avx2(dst, alpha, src) };
        return;
    }
    axpy_widen_scalar(dst, alpha, src)
}

fn axpy_widen_scalar(dst: &mut [f64], alpha: f64, src: &[f32]) {
    for (x, &v) in dst.iter_mut().zip(src) {
        *x += alpha * v as f64;
    }
}

/// `dst[i] = src[i] as f32` (round-to-nearest-even, same as `vcvtpd2ps`).
pub fn narrow(dst: &mut [f32], src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::narrow_avx2(dst, src) };
        return;
    }
    narrow_scalar(dst, src)
}

fn narrow_scalar(dst: &mut [f32], src: &[f64]) {
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = v as f32;
    }
}

// ---------------------------------------------------------------------------
// Striped reductions. Element i folds into lane i % STRIPE (as f64); lanes
// are combined sequentially. Both paths implement exactly this shape.
// ---------------------------------------------------------------------------

fn fold_lanes(acc: &[f64; STRIPE]) -> f64 {
    let mut s = 0.0;
    for &a in acc {
        s += a;
    }
    s
}

/// Striped Σ xᵢ in f64.
pub fn sum_striped(xs: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        return unsafe { x86::sum_striped_avx2(xs) };
    }
    sum_striped_scalar(xs)
}

fn sum_striped_scalar(xs: &[f32]) -> f64 {
    let mut acc = [0.0f64; STRIPE];
    for (i, &x) in xs.iter().enumerate() {
        acc[i % STRIPE] += x as f64;
    }
    fold_lanes(&acc)
}

/// Striped (Σ xᵢ, Σ xᵢ²) in one pass — the fused `std_dev` kernel.
pub fn sum_sumsq_striped(xs: &[f32]) -> (f64, f64) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        return unsafe { x86::sum_sumsq_striped_avx2(xs) };
    }
    sum_sumsq_striped_scalar(xs)
}

fn sum_sumsq_striped_scalar(xs: &[f32]) -> (f64, f64) {
    let mut sums = [0.0f64; STRIPE];
    let mut sqs = [0.0f64; STRIPE];
    for (i, &x) in xs.iter().enumerate() {
        let xd = x as f64;
        sums[i % STRIPE] += xd;
        sqs[i % STRIPE] += xd * xd;
    }
    (fold_lanes(&sums), fold_lanes(&sqs))
}

/// Striped Σ |xᵢ| in f64 — the shared-magnitude kernel of the sign codec.
pub fn abs_sum_striped(xs: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        return unsafe { x86::abs_sum_striped_avx2(xs) };
    }
    abs_sum_striped_scalar(xs)
}

fn abs_sum_striped_scalar(xs: &[f32]) -> f64 {
    let mut acc = [0.0f64; STRIPE];
    for (i, &x) in xs.iter().enumerate() {
        acc[i % STRIPE] += x.abs() as f64;
    }
    fold_lanes(&acc)
}

// ---------------------------------------------------------------------------
// Affine quantize kernels (the v2 wire, `comm::wire::QuantTensor`). Contract:
// `values` are survivor values — finite and nonzero by construction (they
// came through the prune threshold), with `zero = min` and
// `scale = (max−min)/levels` computed by `minmax` below. Bit parity holds
// because every float op both paths perform — sub, div, add, floor, mul — is
// exactly rounded IEEE (no FMA, no reciprocal-multiply), and the
// out-of-range clamps agree on everything the contract admits.
// ---------------------------------------------------------------------------

/// (min, max) over `values`; `(0.0, 0.0)` when empty. Exact — the min of a
/// finite multiset is order-independent, so the 8-lane tree reduction and
/// the scalar fold produce the same bits.
pub fn minmax(values: &[f32]) -> (f32, f32) {
    if values.is_empty() {
        return (0.0, 0.0);
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        return unsafe { x86::minmax_avx2(values) };
    }
    minmax_scalar(values)
}

fn minmax_scalar(values: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

/// The normative per-survivor code: `⌊(v − zero)/scale + 0.5⌋` clamped to
/// `0..=levels`. `floor(x + 0.5)`, *not* `round(x)` — scalar `round` is
/// half-away-from-zero while the vector rounding mode is nearest-even; the
/// add-then-floor form uses only exactly-rounded ops so both paths agree.
/// The `as u32` cast saturates (negatives and NaN to 0), matching the
/// vector clamp on every in-contract input.
#[inline]
fn quant_code(v: f32, zero: f32, scale: f32, levels: u32) -> u32 {
    (((v - zero) / scale + 0.5).floor() as u32).min(levels)
}

/// Quantize survivor values to packed 8-bit codes, 4 per u32 word
/// (little-endian within the word), into `out` (cleared first).
pub fn quantize_q8_into(values: &[f32], zero: f32, scale: f32, out: &mut Vec<u32>) {
    out.clear();
    out.resize(values.len().div_ceil(4), 0);
    if scale == 0.0 {
        // constant or empty survivors: every code is 0 by definition
        // (division by a zero scale is undefined on both paths)
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::quantize_q8_avx2(values, zero, scale, out) };
        return;
    }
    quantize_q8_scalar(values, zero, scale, out)
}

fn quantize_q8_scalar(values: &[f32], zero: f32, scale: f32, out: &mut [u32]) {
    for (j, &v) in values.iter().enumerate() {
        out[j / 4] |= quant_code(v, zero, scale, 255) << ((j % 4) * 8);
    }
}

/// Quantize survivor values to packed 4-bit codes, 8 per u32 word
/// (little-endian within the word), into `out` (cleared first).
pub fn quantize_q4_into(values: &[f32], zero: f32, scale: f32, out: &mut Vec<u32>) {
    out.clear();
    out.resize(values.len().div_ceil(8), 0);
    if scale == 0.0 {
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::quantize_q4_avx2(values, zero, scale, out) };
        return;
    }
    quantize_q4_scalar(values, zero, scale, out)
}

fn quantize_q4_scalar(values: &[f32], zero: f32, scale: f32, out: &mut [u32]) {
    for (j, &v) in values.iter().enumerate() {
        out[j / 8] |= quant_code(v, zero, scale, 15) << ((j % 8) * 4);
    }
}

/// Dequantize `nnz` packed 8-bit codes into survivor values
/// (`zero + scale·q`, mul then add — never FMA), into `out` (cleared
/// first). Panics if `codes` is shorter than `nnz` requires.
pub fn dequantize_q8_into(codes: &[u32], nnz: usize, zero: f32, scale: f32, out: &mut Vec<f32>) {
    out.clear();
    out.resize(nnz, 0.0);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::dequantize_q8_avx2(codes, zero, scale, out) };
        return;
    }
    dequantize_q8_scalar(codes, zero, scale, out)
}

fn dequantize_q8_scalar(codes: &[u32], zero: f32, scale: f32, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        let q = (codes[j / 4] >> ((j % 4) * 8)) & 0xFF;
        *o = zero + scale * q as f32;
    }
}

/// Dequantize `nnz` packed 4-bit codes into survivor values, into `out`
/// (cleared first).
pub fn dequantize_q4_into(codes: &[u32], nnz: usize, zero: f32, scale: f32, out: &mut Vec<f32>) {
    out.clear();
    out.resize(nnz, 0.0);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: active() verified avx2/bmi2/popcnt support.
        unsafe { x86::dequantize_q4_avx2(codes, zero, scale, out) };
        return;
    }
    dequantize_q4_scalar(codes, zero, scale, out)
}

fn dequantize_q4_scalar(codes: &[u32], zero: f32, scale: f32, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        let q = (codes[j / 8] >> ((j % 8) * 4)) & 0xF;
        *o = zero + scale * q as f32;
    }
}

// ---------------------------------------------------------------------------
// Vector-only entry points (cfg-gated). Callers gate on `active()`; the
// scalar oracles for these kernels live at their call sites (`sparsity` for
// the eq. 3 loop, `comm::wire` for the bit-plane codec) so the normative
// definitions stay next to the math they implement.
// ---------------------------------------------------------------------------

/// Vector eq. 3 threshold pass over one chunk. Draw-order and rng-state
/// identical to `sparsity`'s scalar loop. Requires `tau >= 0` (guaranteed by
/// `tau_from_rate`).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn prune_slice_vector(delta: &[f32], tau: f64, rng: &mut crate::util::rng::Rng, out: &mut [f32]) {
    debug_assert!(available());
    debug_assert!(tau >= 0.0);
    // SAFETY: caller gated on active(); available() re-checked above.
    unsafe { x86::prune_avx2(delta, tau, rng, out) }
}

/// Vector sign bit-plane encode: returns `(presence, signs, nnz)` with the
/// exact words/ordering of the scalar push loop in `comm::wire`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn sign_encode_planes(pruned: &[f32]) -> (Vec<u32>, Vec<u32>, u32) {
    debug_assert!(available());
    // SAFETY: caller gated on active(); available() re-checked above.
    unsafe { x86::sign_encode_planes_avx2(pruned) }
}

/// Vector sparse encode: appends survivor `(index, value)` pairs in element
/// order, identical to the scalar `v != 0.0` push loop.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn sparse_encode_into(pruned: &[f32], indices: &mut Vec<u32>, values: &mut Vec<f32>) {
    debug_assert!(available());
    // SAFETY: caller gated on active(); available() re-checked above.
    unsafe { x86::sparse_encode_avx2(pruned, indices, values) }
}

/// Vector dense decode of a sign tensor: survivor lanes get `±magnitude`,
/// everything else `+0.0` — same bits as the scalar survivor walk over a
/// zeroed buffer.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn sign_decode_into(presence: &[u32], signs: &[u32], magnitude: f32, out: &mut [f32]) {
    debug_assert!(available());
    // SAFETY: caller gated on active(); available() re-checked above.
    unsafe { x86::sign_decode_into_avx2(presence, signs, magnitude, out) }
}

/// Vector sign fold: `dst[i] += alpha * (±magnitude)` on survivor lanes,
/// non-survivor lanes left untouched (blend, not add-zero — preserves `-0.0`
/// and NaN payloads exactly like the scalar survivor walk).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn sign_axpy_f32(presence: &[u32], signs: &[u32], magnitude: f32, alpha: f32, dst: &mut [f32]) {
    debug_assert!(available());
    // SAFETY: caller gated on active(); available() re-checked above.
    unsafe { x86::sign_axpy_f32_avx2(presence, signs, magnitude, alpha, dst) }
}

/// Vector sign fold into an f64 accumulator:
/// `dst[i] += alpha * ((±magnitude) as f64)` on survivor lanes.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn sign_axpy_f64(presence: &[u32], signs: &[u32], magnitude: f32, alpha: f64, dst: &mut [f64]) {
    debug_assert!(available());
    // SAFETY: caller gated on active(); available() re-checked above.
    unsafe { x86::sign_axpy_f64_avx2(presence, signs, magnitude, alpha, dst) }
}

// ---------------------------------------------------------------------------
// AVX2/BMI2 implementations.
// ---------------------------------------------------------------------------

// Safety contract for every fn below: caller must have verified avx2 + bmi2 +
// popcnt at runtime (`available()`); slice arguments carry their own bounds
// and all raw-pointer arithmetic stays inside them.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[allow(clippy::missing_safety_doc)]
mod x86 {
    use core::arch::x86_64::*;

    use crate::util::rng::Rng;

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn add_assign_avx2(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let mut i = 0;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(dst.as_ptr().add(i));
            let b = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(a, b));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) += *src.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn axpy_avx2(dst: &mut [f32], alpha: f32, src: &[f32]) {
        let n = dst.len();
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            // mul then add: two roundings, matching the scalar `d + alpha*s`
            let r = _mm256_add_ps(d, _mm256_mul_ps(av, s));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) += alpha * *src.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn scale_avx2(dst: &mut [f32], alpha: f32) {
        let n = dst.len();
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_mul_ps(d, av));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) *= alpha;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn scaled_avx2(dst: &mut [f32], alpha: f32, src: &[f32]) {
        let n = dst.len();
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i + 8 <= n {
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_mul_ps(av, s));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = alpha * *src.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn fold_delta_avx2(res: &mut [f32], local: &[f32], reference: &[f32]) {
        let n = res.len();
        let mut i = 0;
        while i + 8 <= n {
            let r = _mm256_loadu_ps(res.as_ptr().add(i));
            let a = _mm256_loadu_ps(local.as_ptr().add(i));
            let b = _mm256_loadu_ps(reference.as_ptr().add(i));
            // sub then add, matching the scalar `r + (a - b)`
            let out = _mm256_add_ps(r, _mm256_sub_ps(a, b));
            _mm256_storeu_ps(res.as_mut_ptr().add(i), out);
            i += 8;
        }
        while i < n {
            *res.get_unchecked_mut(i) += *local.get_unchecked(i) - *reference.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn abs_into_avx2(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let mut i = 0;
        while i + 8 <= n {
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_and_ps(s, mask));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = src.get_unchecked(i).abs();
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn widen_avx2(dst: &mut [f64], src: &[f32]) {
        let n = dst.len();
        let mut i = 0;
        while i + 4 <= n {
            let s = _mm_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_cvtps_pd(s));
            i += 4;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = *src.get_unchecked(i) as f64;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn axpy_widen_avx2(dst: &mut [f64], alpha: f64, src: &[f32]) {
        let n = dst.len();
        let av = _mm256_set1_pd(alpha);
        let mut i = 0;
        while i + 4 <= n {
            let s = _mm256_cvtps_pd(_mm_loadu_ps(src.as_ptr().add(i)));
            let d = _mm256_loadu_pd(dst.as_ptr().add(i));
            let r = _mm256_add_pd(d, _mm256_mul_pd(av, s));
            _mm256_storeu_pd(dst.as_mut_ptr().add(i), r);
            i += 4;
        }
        while i < n {
            *dst.get_unchecked_mut(i) += alpha * *src.get_unchecked(i) as f64;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn narrow_avx2(dst: &mut [f32], src: &[f64]) {
        let n = dst.len();
        let mut i = 0;
        while i + 4 <= n {
            let s = _mm256_loadu_pd(src.as_ptr().add(i));
            _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm256_cvtpd_ps(s));
            i += 4;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = *src.get_unchecked(i) as f32;
            i += 1;
        }
    }

    // -- striped reductions -------------------------------------------------

    // One 8-wide f32 load splits into lanes 0..4 (low half) and 4..8 (high
    // half); `_mm256_cvtps_pd` preserves element order, so vector lane j of
    // (lo,hi) is exactly striped accumulator j of the scalar definition.

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn sum_striped_avx2(xs: &[f32]) -> f64 {
        let n = xs.len();
        let mut lo = _mm256_setzero_pd();
        let mut hi = _mm256_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(xs.as_ptr().add(i));
            lo = _mm256_add_pd(lo, _mm256_cvtps_pd(_mm256_castps256_ps128(v)));
            hi = _mm256_add_pd(hi, _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v)));
            i += 8;
        }
        let mut acc = [0.0f64; super::STRIPE];
        _mm256_storeu_pd(acc.as_mut_ptr(), lo);
        _mm256_storeu_pd(acc.as_mut_ptr().add(4), hi);
        while i < n {
            acc[i % super::STRIPE] += *xs.get_unchecked(i) as f64;
            i += 1;
        }
        super::fold_lanes(&acc)
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn sum_sumsq_striped_avx2(xs: &[f32]) -> (f64, f64) {
        let n = xs.len();
        let mut slo = _mm256_setzero_pd();
        let mut shi = _mm256_setzero_pd();
        let mut qlo = _mm256_setzero_pd();
        let mut qhi = _mm256_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(xs.as_ptr().add(i));
            let a = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
            let b = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
            slo = _mm256_add_pd(slo, a);
            shi = _mm256_add_pd(shi, b);
            qlo = _mm256_add_pd(qlo, _mm256_mul_pd(a, a));
            qhi = _mm256_add_pd(qhi, _mm256_mul_pd(b, b));
            i += 8;
        }
        let mut sums = [0.0f64; super::STRIPE];
        let mut sqs = [0.0f64; super::STRIPE];
        _mm256_storeu_pd(sums.as_mut_ptr(), slo);
        _mm256_storeu_pd(sums.as_mut_ptr().add(4), shi);
        _mm256_storeu_pd(sqs.as_mut_ptr(), qlo);
        _mm256_storeu_pd(sqs.as_mut_ptr().add(4), qhi);
        while i < n {
            let xd = *xs.get_unchecked(i) as f64;
            sums[i % super::STRIPE] += xd;
            sqs[i % super::STRIPE] += xd * xd;
            i += 1;
        }
        (super::fold_lanes(&sums), super::fold_lanes(&sqs))
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn abs_sum_striped_avx2(xs: &[f32]) -> f64 {
        let n = xs.len();
        let mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let mut lo = _mm256_setzero_pd();
        let mut hi = _mm256_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_and_ps(_mm256_loadu_ps(xs.as_ptr().add(i)), mask);
            lo = _mm256_add_pd(lo, _mm256_cvtps_pd(_mm256_castps256_ps128(v)));
            hi = _mm256_add_pd(hi, _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v)));
            i += 8;
        }
        let mut acc = [0.0f64; super::STRIPE];
        _mm256_storeu_pd(acc.as_mut_ptr(), lo);
        _mm256_storeu_pd(acc.as_mut_ptr().add(4), hi);
        while i < n {
            acc[i % super::STRIPE] += xs.get_unchecked(i).abs() as f64;
            i += 1;
        }
        super::fold_lanes(&acc)
    }

    // -- eq. 3 threshold pass ------------------------------------------------

    // Four elements per iteration (the magnitude test runs in f64, so a quad
    // of f32 promotes to one 4×f64 vector). The in-band uniform draws are
    // filled serially in lane order, so the generator consumes exactly one
    // draw per in-band element in element order — bit- and state-identical
    // to the scalar loop.
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn prune_avx2(delta: &[f32], tau: f64, rng: &mut Rng, out: &mut [f32]) {
        let n = delta.len();
        let tau_pd = _mm256_set1_pd(tau);
        let tau_ps = _mm_set1_ps(tau as f32);
        let sign_ps = _mm_castsi128_ps(_mm_set1_epi32(i32::MIN));
        let abs_ps = _mm_castsi128_ps(_mm_set1_epi32(0x7fff_ffff));
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm_loadu_ps(delta.as_ptr().add(i));
            let mag = _mm256_cvtps_pd(_mm_and_ps(d, abs_ps));
            // out-of-band: |δ| > τ (ordered: NaN stays in-band, as in scalar)
            let outb = _mm256_cmp_pd::<_CMP_GT_OQ>(mag, tau_pd);
            let ob = _mm256_movemask_pd(outb) as usize;
            let inb = !ob & 0xF;
            let mut draws = [0.0f64; 4];
            let mut bits = inb;
            while bits != 0 {
                let j = bits.trailing_zeros() as usize;
                draws[j] = rng.uniform();
                bits &= bits - 1;
            }
            let r = _mm256_loadu_pd(draws.as_ptr());
            // promote: |δ| ≥ r·τ (ordered: NaN never promotes)
            let keep = _mm256_cmp_pd::<_CMP_GE_OQ>(mag, _mm256_mul_pd(r, tau_pd));
            let kb = _mm256_movemask_pd(keep) as usize & inb;
            // promoted value: copysign(τ as f32, δ); τ ≥ 0 so OR the sign bit
            let promoted = _mm_or_ps(tau_ps, _mm_and_ps(d, sign_ps));
            let keep_ps = lane_mask4_ps(kb as u32);
            let outb_ps = lane_mask4_ps(ob as u32);
            // in-band lanes: keep ? promoted : +0.0 (masked AND, matching the
            // scalar literal 0.0); out-of-band lanes pass δ through
            let inval = _mm_and_ps(keep_ps, promoted);
            let res = _mm_or_ps(_mm_and_ps(outb_ps, d), _mm_andnot_ps(outb_ps, inval));
            _mm_storeu_ps(out.as_mut_ptr().add(i), res);
            i += 4;
        }
        if i < n {
            crate::sparsity::prune_slice_scalar(&delta[i..], tau, rng, &mut out[i..]);
        }
    }

    // -- sign bit-plane codec ------------------------------------------------

    // Survivor-order sign compaction shared by scalar tail and vector body:
    // a 64-bit buffer absorbs up to 32 bits per word and spills whole u32s.
    struct BitPacker {
        buf: u64,
        pos: u32,
    }

    impl BitPacker {
        fn new() -> Self {
            BitPacker { buf: 0, pos: 0 }
        }

        #[inline]
        fn push(&mut self, packed: u32, cnt: u32, signs: &mut Vec<u32>) {
            self.buf |= (packed as u64) << self.pos;
            self.pos += cnt;
            if self.pos >= 32 {
                signs.push(self.buf as u32);
                self.buf >>= 32;
                self.pos -= 32;
            }
        }

        #[inline]
        fn finish(self, signs: &mut Vec<u32>) {
            if self.pos > 0 {
                signs.push(self.buf as u32);
            }
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn sign_encode_planes_avx2(pruned: &[f32]) -> (Vec<u32>, Vec<u32>, u32) {
        let n = pruned.len();
        let words = n.div_ceil(32);
        let mut presence = vec![0u32; words];
        let mut signs: Vec<u32> = Vec::with_capacity(words);
        let mut nnz = 0u32;
        let mut packer = BitPacker::new();
        let zero = _mm256_setzero_ps();
        let mut w = 0;
        while (w + 1) * 32 <= n {
            let base = pruned.as_ptr().add(w * 32);
            let mut pres: u32 = 0;
            let mut neg: u32 = 0;
            for o in 0..4 {
                let v = _mm256_loadu_ps(base.add(o * 8));
                // presence: v != 0.0 (unordered: true for NaN, like scalar !=)
                let nz = _mm256_cmp_ps::<_CMP_NEQ_UQ>(v, zero);
                // sign: v < 0.0 (ordered: false for NaN, like scalar <)
                let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(v, zero);
                pres |= (_mm256_movemask_ps(nz) as u32 & 0xFF) << (o * 8);
                neg |= (_mm256_movemask_ps(lt) as u32 & 0xFF) << (o * 8);
            }
            presence[w] = pres;
            if pres != 0 {
                let cnt = pres.count_ones();
                packer.push(_pext_u32(neg, pres), cnt, &mut signs);
                nnz += cnt;
            }
            w += 1;
        }
        let tail = w * 32;
        if tail < n {
            let mut pres: u32 = 0;
            let mut neg: u32 = 0;
            for (j, &v) in pruned[tail..].iter().enumerate() {
                pres |= ((v != 0.0) as u32) << j;
                neg |= ((v < 0.0) as u32) << j;
            }
            presence[w] = pres;
            if pres != 0 {
                let cnt = pres.count_ones();
                packer.push(_pext_u32(neg, pres), cnt, &mut signs);
                nnz += cnt;
            }
        }
        packer.finish(&mut signs);
        (presence, signs, nnz)
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn sparse_encode_avx2(
        pruned: &[f32],
        indices: &mut Vec<u32>,
        values: &mut Vec<f32>,
    ) {
        let n = pruned.len();
        let zero = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(pruned.as_ptr().add(i));
            let nz = _mm256_cmp_ps::<_CMP_NEQ_UQ>(v, zero);
            let mut m = _mm256_movemask_ps(nz) as u32 & 0xFF;
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                indices.push((i + j) as u32);
                values.push(*pruned.get_unchecked(i + j));
                m &= m - 1;
            }
            i += 8;
        }
        while i < n {
            let v = *pruned.get_unchecked(i);
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
            i += 1;
        }
    }

    /// Survivor-order sign bits for one presence word: the `popcnt(word)`
    /// low bits of the window starting at survivor ordinal `ord`.
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    #[inline]
    unsafe fn sign_window(signs: &[u32], ord: usize) -> u32 {
        let wi = ord / 32;
        let sh = ord % 32;
        let lo = *signs.get_unchecked(wi) as u64;
        let hi = if wi + 1 < signs.len() {
            *signs.get_unchecked(wi + 1) as u64
        } else {
            0
        };
        ((lo | (hi << 32)) >> sh) as u32
    }

    /// All-ones/all-zero f32 lane masks from the low 8 bits of `bits`.
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    #[inline]
    unsafe fn lane_mask8_ps(bits: u32) -> __m256 {
        let wv = _mm256_set1_epi32((bits & 0xFF) as i32);
        let sel = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
        _mm256_castsi256_ps(_mm256_cmpeq_epi32(_mm256_and_si256(wv, sel), sel))
    }

    /// All-ones/all-zero f32 lane masks (SSE width) from the low 4 bits.
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    #[inline]
    unsafe fn lane_mask4_ps(bits: u32) -> __m128 {
        let wv = _mm_set1_epi32((bits & 0xF) as i32);
        let sel = _mm_setr_epi32(1, 2, 4, 8);
        _mm_castsi128_ps(_mm_cmpeq_epi32(_mm_and_si128(wv, sel), sel))
    }

    /// All-ones/all-zero f64 lane masks from the low 4 bits of `bits`.
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    #[inline]
    unsafe fn quad_mask4_pd(bits: u32) -> __m256d {
        let wv = _mm256_set1_epi64x((bits & 0xF) as i64);
        let sel = _mm256_setr_epi64x(1, 2, 4, 8);
        _mm256_castsi256_pd(_mm256_cmpeq_epi64(_mm256_and_si256(wv, sel), sel))
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn sign_decode_into_avx2(
        presence: &[u32],
        signs: &[u32],
        magnitude: f32,
        out: &mut [f32],
    ) {
        let n = out.len();
        let magv = _mm256_set1_ps(magnitude);
        let sign_ps = _mm256_castsi256_ps(_mm256_set1_epi32(i32::MIN));
        let mut ord = 0usize;
        for (w, &word) in presence.iter().enumerate() {
            let base = w * 32;
            if base + 32 <= n {
                if word == 0 {
                    for o in 0..4 {
                        _mm256_storeu_ps(out.as_mut_ptr().add(base + o * 8), _mm256_setzero_ps());
                    }
                    continue;
                }
                let negw = _pdep_u32(sign_window(signs, ord), word);
                ord += word.count_ones() as usize;
                for o in 0..4 {
                    let pm = lane_mask8_ps(word >> (o * 8));
                    let nm = lane_mask8_ps(negw >> (o * 8));
                    // ±magnitude: XOR the sign bit on negative lanes — the
                    // exact bit flip of scalar negation
                    let val = _mm256_xor_ps(magv, _mm256_and_ps(nm, sign_ps));
                    _mm256_storeu_ps(out.as_mut_ptr().add(base + o * 8), _mm256_and_ps(pm, val));
                }
            } else {
                // partial final word: scalar walk, same ops as the oracle
                for j in 0..(n - base) {
                    let mut v = 0.0f32;
                    if (word >> j) & 1 == 1 {
                        let negbit = (*signs.get_unchecked(ord / 32) >> (ord % 32)) & 1;
                        v = if negbit == 1 { -magnitude } else { magnitude };
                        ord += 1;
                    }
                    *out.get_unchecked_mut(base + j) = v;
                }
            }
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn sign_axpy_f32_avx2(
        presence: &[u32],
        signs: &[u32],
        magnitude: f32,
        alpha: f32,
        dst: &mut [f32],
    ) {
        let n = dst.len();
        let magv = _mm256_set1_ps(magnitude);
        let av = _mm256_set1_ps(alpha);
        let sign_ps = _mm256_castsi256_ps(_mm256_set1_epi32(i32::MIN));
        let mut ord = 0usize;
        for (w, &word) in presence.iter().enumerate() {
            if word == 0 {
                continue;
            }
            let base = w * 32;
            if base + 32 <= n {
                let negw = _pdep_u32(sign_window(signs, ord), word);
                ord += word.count_ones() as usize;
                for o in 0..4 {
                    let ob = (word >> (o * 8)) & 0xFF;
                    if ob == 0 {
                        continue;
                    }
                    let pm = lane_mask8_ps(ob);
                    let nm = lane_mask8_ps(negw >> (o * 8));
                    let val = _mm256_xor_ps(magv, _mm256_and_ps(nm, sign_ps));
                    let p = dst.as_mut_ptr().add(base + o * 8);
                    let d = _mm256_loadu_ps(p);
                    let sum = _mm256_add_ps(d, _mm256_mul_ps(av, val));
                    // blend, not add-zero: untouched lanes keep their bits
                    _mm256_storeu_ps(p, _mm256_blendv_ps(d, sum, pm));
                }
            } else {
                for j in 0..(n - base) {
                    if (word >> j) & 1 == 1 {
                        let negbit = (*signs.get_unchecked(ord / 32) >> (ord % 32)) & 1;
                        let v = if negbit == 1 { -magnitude } else { magnitude };
                        *dst.get_unchecked_mut(base + j) += alpha * v;
                        ord += 1;
                    }
                }
            }
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn sign_axpy_f64_avx2(
        presence: &[u32],
        signs: &[u32],
        magnitude: f32,
        alpha: f64,
        dst: &mut [f64],
    ) {
        let n = dst.len();
        let magv = _mm256_set1_pd(magnitude as f64);
        let av = _mm256_set1_pd(alpha);
        let sign_pd = _mm256_castsi256_pd(_mm256_set1_epi64x(i64::MIN));
        let mut ord = 0usize;
        for (w, &word) in presence.iter().enumerate() {
            if word == 0 {
                continue;
            }
            let base = w * 32;
            if base + 32 <= n {
                let negw = _pdep_u32(sign_window(signs, ord), word);
                ord += word.count_ones() as usize;
                for q in 0..8 {
                    let qb = (word >> (q * 4)) & 0xF;
                    if qb == 0 {
                        continue;
                    }
                    let pm = quad_mask4_pd(qb);
                    let nm = quad_mask4_pd(negw >> (q * 4));
                    // (±magnitude) as f64 == ±(magnitude as f64): the widening
                    // cast is exact and sign-preserving
                    let val = _mm256_xor_pd(magv, _mm256_and_pd(nm, sign_pd));
                    let p = dst.as_mut_ptr().add(base + q * 4);
                    let d = _mm256_loadu_pd(p);
                    let sum = _mm256_add_pd(d, _mm256_mul_pd(av, val));
                    _mm256_storeu_pd(p, _mm256_blendv_pd(d, sum, pm));
                }
            } else {
                for j in 0..(n - base) {
                    if (word >> j) & 1 == 1 {
                        let negbit = (*signs.get_unchecked(ord / 32) >> (ord % 32)) & 1;
                        let v = if negbit == 1 { -magnitude } else { magnitude };
                        *dst.get_unchecked_mut(base + j) += alpha * v as f64;
                        ord += 1;
                    }
                }
            }
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn minmax_avx2(xs: &[f32]) -> (f32, f32) {
        let n = xs.len();
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        let mut i = 0;
        if n >= 8 {
            let mut lov = _mm256_set1_ps(f32::INFINITY);
            let mut hiv = _mm256_set1_ps(f32::NEG_INFINITY);
            while i + 8 <= n {
                let v = _mm256_loadu_ps(xs.as_ptr().add(i));
                lov = _mm256_min_ps(lov, v);
                hiv = _mm256_max_ps(hiv, v);
                i += 8;
            }
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), lov);
            for &l in &lanes {
                lo = lo.min(l);
            }
            _mm256_storeu_ps(lanes.as_mut_ptr(), hiv);
            for &l in &lanes {
                hi = hi.max(l);
            }
        }
        while i < n {
            let v = *xs.get_unchecked(i);
            lo = lo.min(v);
            hi = hi.max(v);
            i += 1;
        }
        (lo, hi)
    }

    /// 8 clamped i32 codes → one byte each, at byte 0..4 of each 128-bit
    /// lane; the two extracted dwords are the packed little-endian bytes of
    /// lanes 0–3 and 4–7.
    #[inline]
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    unsafe fn pack8_codes(qi: __m256i) -> (u32, u32) {
        let shuf = _mm256_setr_epi8(
            0, 4, 8, 12, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, //
            0, 4, 8, 12, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1,
        );
        let packed = _mm256_shuffle_epi8(qi, shuf);
        (
            _mm256_extract_epi32::<0>(packed) as u32,
            _mm256_extract_epi32::<4>(packed) as u32,
        )
    }

    /// The vector twin of `quant_code`: sub, div, add, floor — each exactly
    /// rounded — then truncate-to-i32 and clamp. Post-floor the value is an
    /// integer, so truncation is exact; NaN converts to i32::MIN and clamps
    /// to 0, same as the scalar saturating cast.
    #[inline]
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    unsafe fn quant_codes8(v: __m256, zv: __m256, sv: __m256, half: __m256, top: __m256i) -> __m256i {
        let q = _mm256_floor_ps(_mm256_add_ps(_mm256_div_ps(_mm256_sub_ps(v, zv), sv), half));
        let qi = _mm256_cvttps_epi32(q);
        _mm256_min_epi32(_mm256_max_epi32(qi, _mm256_setzero_si256()), top)
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn quantize_q8_avx2(values: &[f32], zero: f32, scale: f32, out: &mut [u32]) {
        let n = values.len();
        let zv = _mm256_set1_ps(zero);
        let sv = _mm256_set1_ps(scale);
        let half = _mm256_set1_ps(0.5);
        let top = _mm256_set1_epi32(255);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(values.as_ptr().add(i));
            let (w0, w1) = pack8_codes(quant_codes8(v, zv, sv, half, top));
            // i is 8-aligned, so these two words are wholly owned by this
            // iteration and still hold their initial 0
            out[i / 4] = w0;
            out[i / 4 + 1] = w1;
            i += 8;
        }
        while i < n {
            let q = (((*values.get_unchecked(i) - zero) / scale + 0.5).floor() as u32).min(255);
            out[i / 4] |= q << ((i % 4) * 8);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn quantize_q4_avx2(values: &[f32], zero: f32, scale: f32, out: &mut [u32]) {
        let n = values.len();
        let zv = _mm256_set1_ps(zero);
        let sv = _mm256_set1_ps(scale);
        let half = _mm256_set1_ps(0.5);
        let top = _mm256_set1_epi32(15);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(values.as_ptr().add(i));
            let (w0, w1) = pack8_codes(quant_codes8(v, zv, sv, half, top));
            // each byte holds a 0..=15 code; pext compacts the 8 low
            // nibbles of the byte pair into one u32 word
            out[i / 8] = _pext_u64(w0 as u64 | ((w1 as u64) << 32), 0x0F0F_0F0F_0F0F_0F0F) as u32;
            i += 8;
        }
        while i < n {
            let q = (((*values.get_unchecked(i) - zero) / scale + 0.5).floor() as u32).min(15);
            out[i / 8] |= q << ((i % 8) * 4);
            i += 1;
        }
    }

    /// 8 little-endian code bytes (as a u64) → `zero + scale·q` into
    /// `out[j..j+8]`. Mul then add — same two rounded ops as the scalar
    /// dequantizer.
    #[inline]
    #[target_feature(enable = "avx2,bmi2,popcnt")]
    unsafe fn dequant8(bytes: u64, zv: __m256, sv: __m256, dst: *mut f32) {
        let qi = _mm256_cvtepu8_epi32(_mm_cvtsi64_si128(bytes as i64));
        let qf = _mm256_cvtepi32_ps(qi);
        _mm256_storeu_ps(dst, _mm256_add_ps(zv, _mm256_mul_ps(sv, qf)));
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn dequantize_q8_avx2(codes: &[u32], zero: f32, scale: f32, out: &mut [f32]) {
        let nnz = out.len();
        let zv = _mm256_set1_ps(zero);
        let sv = _mm256_set1_ps(scale);
        let mut j = 0;
        while j + 8 <= nnz {
            let bytes = codes[j / 4] as u64 | ((codes[j / 4 + 1] as u64) << 32);
            dequant8(bytes, zv, sv, out.as_mut_ptr().add(j));
            j += 8;
        }
        while j < nnz {
            let q = (codes[j / 4] >> ((j % 4) * 8)) & 0xFF;
            *out.get_unchecked_mut(j) = zero + scale * q as f32;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,bmi2,popcnt")]
    pub(super) unsafe fn dequantize_q4_avx2(codes: &[u32], zero: f32, scale: f32, out: &mut [f32]) {
        let nnz = out.len();
        let zv = _mm256_set1_ps(zero);
        let sv = _mm256_set1_ps(scale);
        let mut j = 0;
        while j + 8 <= nnz {
            // pdep spreads the word's 8 nibbles into 8 byte lanes
            let bytes = _pdep_u64(codes[j / 8] as u64, 0x0F0F_0F0F_0F0F_0F0F);
            dequant8(bytes, zv, sv, out.as_mut_ptr().add(j));
            j += 8;
        }
        while j < nnz {
            let q = (codes[j / 8] >> ((j % 8) * 4)) & 0xF;
            *out.get_unchecked_mut(j) = zero + scale * q as f32;
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Parity pins: every vector kernel against its scalar oracle, bit-for-bit,
// over lengths that cross vector-width and bit-plane word boundaries and
// data that includes ±0.0, NaN, and denormals. These call the x86 fns
// directly (no global force_scalar toggling), so they cannot race.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn striped_sum_matches_sequential_for_integers() {
        // integer-valued data sums exactly in any association
        let xs: Vec<f32> = (0..1000).map(|i| (i % 17) as f32 - 8.0).collect();
        let seq: f64 = xs.iter().map(|&x| x as f64).sum();
        assert_eq!(sum_striped_scalar(&xs), seq);
        let (s, q) = sum_sumsq_striped_scalar(&xs);
        assert_eq!(s, seq);
        let seq_q: f64 = xs.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert_eq!(q, seq_q);
    }

    #[test]
    fn force_scalar_pins_the_oracle_path() {
        force_scalar(true);
        assert!(!active());
        force_scalar(false);
        assert_eq!(active(), available() && std::env::var("EFFICIENTGRAD_SIMD").map_or(true, |v| v != "0"));
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    mod vector {
        use super::super::*;
        use crate::util::rng::Rng;

        /// Lengths that cross the 4/8-lane widths, the 32-bit plane words,
        /// and stay odd-tailed.
        const LENS: &[usize] = &[0, 1, 3, 4, 5, 7, 8, 9, 31, 32, 33, 63, 64, 65, 255, 1000];

        /// Deterministic data with hostile values mixed in.
        fn data(n: usize, seed: u64) -> Vec<f32> {
            let mut rng = Rng::new(seed);
            (0..n)
                .map(|i| match i % 13 {
                    0 => 0.0,
                    5 => -0.0,
                    7 if i % 39 == 7 => f32::NAN,
                    9 => f32::MIN_POSITIVE / 2.0, // denormal
                    11 => 3.4e37,
                    _ => (rng.uniform_in(-2.0, 2.0)) as f32,
                })
                .collect()
        }

        fn bits(xs: &[f32]) -> Vec<u32> {
            xs.iter().map(|x| x.to_bits()).collect()
        }

        fn bits64(xs: &[f64]) -> Vec<u64> {
            xs.iter().map(|x| x.to_bits()).collect()
        }

        #[test]
        fn elementwise_vector_kernels_bit_match_scalar() {
            if !available() {
                eprintln!("SKIP: cpu lacks avx2/bmi2/popcnt");
                return;
            }
            for &n in LENS {
                let src = data(n, 11 + n as u64);
                let base = data(n, 99 + n as u64);
                let refr = data(n, 7 + n as u64);

                let mut a = base.clone();
                let mut b = base.clone();
                add_assign_scalar(&mut a, &src);
                unsafe { x86::add_assign_avx2(&mut b, &src) };
                assert_eq!(bits(&a), bits(&b), "add_assign n={n}");

                let mut a = base.clone();
                let mut b = base.clone();
                axpy_scalar(&mut a, -0.37, &src);
                unsafe { x86::axpy_avx2(&mut b, -0.37, &src) };
                assert_eq!(bits(&a), bits(&b), "axpy n={n}");

                let mut a = base.clone();
                let mut b = base.clone();
                scale_scalar(&mut a, 1.7);
                unsafe { x86::scale_avx2(&mut b, 1.7) };
                assert_eq!(bits(&a), bits(&b), "scale n={n}");

                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                scaled_scalar(&mut a, -2.5, &src);
                unsafe { x86::scaled_avx2(&mut b, -2.5, &src) };
                assert_eq!(bits(&a), bits(&b), "scaled n={n}");

                let mut a = base.clone();
                let mut b = base.clone();
                fold_delta_scalar(&mut a, &src, &refr);
                unsafe { x86::fold_delta_avx2(&mut b, &src, &refr) };
                assert_eq!(bits(&a), bits(&b), "fold_delta n={n}");

                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                abs_into_scalar(&mut a, &src);
                unsafe { x86::abs_into_avx2(&mut b, &src) };
                assert_eq!(bits(&a), bits(&b), "abs_into n={n}");

                let mut a = vec![0.0f64; n];
                let mut b = vec![0.0f64; n];
                widen_scalar(&mut a, &src);
                unsafe { x86::widen_avx2(&mut b, &src) };
                assert_eq!(bits64(&a), bits64(&b), "widen n={n}");

                let mut a: Vec<f64> = base.iter().map(|&v| v as f64 * 0.5).collect();
                let mut b = a.clone();
                axpy_widen_scalar(&mut a, -0.125, &src);
                unsafe { x86::axpy_widen_avx2(&mut b, -0.125, &src) };
                assert_eq!(bits64(&a), bits64(&b), "axpy_widen n={n}");

                let wide: Vec<f64> = src.iter().map(|&v| v as f64 * 1.0000001).collect();
                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                narrow_scalar(&mut a, &wide);
                unsafe { x86::narrow_avx2(&mut b, &wide) };
                assert_eq!(bits(&a), bits(&b), "narrow n={n}");
            }
        }

        #[test]
        fn striped_reductions_bit_match_scalar() {
            if !available() {
                eprintln!("SKIP: cpu lacks avx2/bmi2/popcnt");
                return;
            }
            for &n in LENS {
                // finite-only data: NaN poisons every reduction identically,
                // but bit-compare of NaN payloads is not the contract
                let mut rng = Rng::new(n as u64 + 5);
                let xs: Vec<f32> = (0..n)
                    .map(|i| {
                        if i % 9 == 4 {
                            -0.0
                        } else {
                            rng.uniform_in(-3.0, 3.0) as f32
                        }
                    })
                    .collect();
                let a = sum_striped_scalar(&xs);
                let b = unsafe { x86::sum_striped_avx2(&xs) };
                assert_eq!(a.to_bits(), b.to_bits(), "sum n={n}");
                let (s0, q0) = sum_sumsq_striped_scalar(&xs);
                let (s1, q1) = unsafe { x86::sum_sumsq_striped_avx2(&xs) };
                assert_eq!(s0.to_bits(), s1.to_bits(), "fused sum n={n}");
                assert_eq!(q0.to_bits(), q1.to_bits(), "fused sumsq n={n}");
                let a = abs_sum_striped_scalar(&xs);
                let b = unsafe { x86::abs_sum_striped_avx2(&xs) };
                assert_eq!(a.to_bits(), b.to_bits(), "abs_sum n={n}");
            }
        }

        #[test]
        fn vector_prune_bit_matches_scalar_and_rng_state() {
            if !available() {
                eprintln!("SKIP: cpu lacks avx2/bmi2/popcnt");
                return;
            }
            for &n in LENS {
                for (tau, seed) in [(0.0f64, 1u64), (0.05, 2), (0.8, 3), (10.0, 4)] {
                    let delta = data(n, seed * 1000 + n as u64);
                    let mut rs = Rng::new(42 + seed);
                    let mut rv = Rng::new(42 + seed);
                    let mut os = vec![9.0f32; n];
                    let mut ov = vec![9.0f32; n];
                    crate::sparsity::prune_slice_scalar(&delta, tau, &mut rs, &mut os);
                    unsafe { x86::prune_avx2(&delta, tau, &mut rv, &mut ov) };
                    assert_eq!(bits(&os), bits(&ov), "prune n={n} tau={tau}");
                    assert_eq!(rs.state(), rv.state(), "rng state n={n} tau={tau}");
                }
            }
        }

        #[test]
        fn vector_sign_codec_bit_matches_scalar_walk() {
            if !available() {
                eprintln!("SKIP: cpu lacks avx2/bmi2/popcnt");
                return;
            }
            use crate::comm::wire::{SignTensor, TensorUpdate};
            for &n in LENS {
                // pruned-looking data: mostly zeros with ± survivors
                let mut rng = Rng::new(n as u64 + 77);
                let pruned: Vec<f32> = (0..n)
                    .map(|_| {
                        let u = rng.uniform();
                        if u < 0.7 {
                            0.0
                        } else if u < 0.85 {
                            0.25
                        } else {
                            -0.25
                        }
                    })
                    .collect();
                // encode: vector planes vs the scalar push-loop oracle
                let scalar = SignTensor::encode_scalar(&pruned);
                let (pres, signs, nnz) = sign_encode_planes(&pruned);
                assert_eq!(scalar.presence, pres, "presence n={n}");
                assert_eq!(scalar.signs, signs, "signs n={n}");
                assert_eq!(scalar.nnz, nnz, "nnz n={n}");

                // sparse encode
                let mut idx = Vec::new();
                let mut vals = Vec::new();
                sparse_encode_into(&pruned, &mut idx, &mut vals);
                let sidx: Vec<u32> = pruned
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(i, _)| i as u32)
                    .collect();
                assert_eq!(idx, sidx, "sparse indices n={n}");

                // decode / folds: vector vs the survivor-walk oracle
                let t = scalar;
                let mut dec_s = vec![0.0f32; n];
                t.for_each_survivor(|i, v| dec_s[i] = v);
                let mut dec_v = vec![7.0f32; n];
                sign_decode_into(&t.presence, &t.signs, t.magnitude, &mut dec_v);
                assert_eq!(bits(&dec_s), bits(&dec_v), "decode n={n}");

                let base = data(n, n as u64 + 3);
                let mut f32_s = base.clone();
                t.for_each_survivor(|i, v| f32_s[i] += -0.4 * v);
                let mut f32_v = base.clone();
                sign_axpy_f32(&t.presence, &t.signs, t.magnitude, -0.4, &mut f32_v);
                assert_eq!(bits(&f32_s), bits(&f32_v), "sign axpy f32 n={n}");

                let based: Vec<f64> = base.iter().map(|&v| v as f64 * 0.3).collect();
                let mut f64_s = based.clone();
                t.for_each_survivor(|i, v| f64_s[i] += 0.9 * v as f64);
                let mut f64_v = based;
                sign_axpy_f64(&t.presence, &t.signs, t.magnitude, 0.9, &mut f64_v);
                assert_eq!(bits64(&f64_s), bits64(&f64_v), "sign axpy f64 n={n}");

                // and the dispatching wrapper agrees with the oracle e2e
                let up = TensorUpdate::Sign(t);
                let dense = up.decode_dense();
                assert_eq!(bits(&dense), bits(&dec_s), "decode_dense n={n}");
            }
        }

        #[test]
        fn vector_quantize_kernels_bit_match_scalar() {
            if !available() {
                eprintln!("SKIP: cpu lacks avx2/bmi2/popcnt");
                return;
            }
            // in-contract data: finite survivor values with zero/scale
            // derived exactly as QuantTensor::from_survivors derives them
            // (survivors are never NaN/±0.0 by construction — see the
            // kernel contract at the top of the quantize section)
            for &n in LENS {
                let mut rng = Rng::new(n as u64 + 0x0DA7);
                let values: Vec<f32> = (0..n)
                    .map(|i| match i % 11 {
                        0 => 1.0e-4,
                        3 => -7.5,
                        6 => 1.0e3,
                        _ => rng.uniform_in(-4.0, 4.0) as f32,
                    })
                    .collect();

                let (lo_s, hi_s) = minmax_scalar(&values);
                if n > 0 {
                    let (lo_v, hi_v) = unsafe { x86::minmax_avx2(&values) };
                    assert_eq!(lo_s.to_bits(), lo_v.to_bits(), "min n={n}");
                    assert_eq!(hi_s.to_bits(), hi_v.to_bits(), "max n={n}");
                }

                for levels in [255u32, 15] {
                    let scale = if hi_s > lo_s {
                        (hi_s - lo_s) / levels as f32
                    } else {
                        0.0
                    };
                    if scale == 0.0 {
                        continue; // the wrapper's early-out, identical by construction
                    }
                    if levels == 255 {
                        let mut cs = vec![0u32; n.div_ceil(4)];
                        quantize_q8_scalar(&values, lo_s, scale, &mut cs);
                        let mut cv = vec![0u32; n.div_ceil(4)];
                        unsafe { x86::quantize_q8_avx2(&values, lo_s, scale, &mut cv) };
                        assert_eq!(cs, cv, "q8 codes n={n}");

                        let mut ds = vec![0.0f32; n];
                        dequantize_q8_scalar(&cs, lo_s, scale, &mut ds);
                        let mut dv = vec![0.0f32; n];
                        unsafe { x86::dequantize_q8_avx2(&cs, lo_s, scale, &mut dv) };
                        assert_eq!(bits(&ds), bits(&dv), "q8 dequant n={n}");
                    } else {
                        let mut cs = vec![0u32; n.div_ceil(8)];
                        quantize_q4_scalar(&values, lo_s, scale, &mut cs);
                        let mut cv = vec![0u32; n.div_ceil(8)];
                        unsafe { x86::quantize_q4_avx2(&values, lo_s, scale, &mut cv) };
                        assert_eq!(cs, cv, "q4 codes n={n}");

                        let mut ds = vec![0.0f32; n];
                        dequantize_q4_scalar(&cs, lo_s, scale, &mut ds);
                        let mut dv = vec![0.0f32; n];
                        unsafe { x86::dequantize_q4_avx2(&cs, lo_s, scale, &mut dv) };
                        assert_eq!(bits(&ds), bits(&dv), "q4 dequant n={n}");
                    }
                }
            }
        }
    }
}
