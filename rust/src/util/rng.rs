//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! `SplitMix64` for seeding, `Xoshiro256pp` as the workhorse generator,
//! plus normal/uniform/permutation helpers. The generator is deliberately
//! simple and fully deterministic from a `u64` seed so training runs,
//! synthetic datasets and federated shard assignments are reproducible
//! bit-for-bit across machines.

/// SplitMix64: used to expand a user seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from the Box-Muller pair
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    /// Derive an independent child stream (for per-worker / per-layer use;
    /// mirrors jax.random.fold_in's role on the Python side).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut sm = SplitMix64::new(self.s[0] ^ data.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let _ = sm.next_u64();
        Rng::new(sm.next_u64() ^ self.s[3].rotate_left(17))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = std::f64::consts::TAU * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// N(mu, sigma^2)
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with N(0, sigma^2) f32s.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() as f32) * sigma;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Snapshot the generator state for crash/resume persistence.
    ///
    /// Only the xoshiro words are captured — the cached Box-Muller
    /// spare is **not** — so the snapshot/restore roundtrip is exact
    /// only for streams consumed via `next_u64`/`uniform`-family draws
    /// (which is what the federated coordinator's persisted streams
    /// use). Snapshotting mid-`normal()` pair would drop the spare.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Rng::state`] (spare deviate empty).
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s, spare: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<u32>>());
    }

    #[test]
    fn state_snapshot_resumes_the_stream_exactly() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            let _ = a.uniform();
        }
        let snap = a.state();
        let ahead: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(ahead, resumed);
    }

    #[test]
    fn fold_in_gives_independent_streams() {
        let base = Rng::new(9);
        let mut c1 = base.fold_in(1);
        let mut c2 = base.fold_in(2);
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
        // and deterministic
        let mut c1b = base.fold_in(1);
        assert_eq!(a[0], c1b.next_u64());
    }
}
