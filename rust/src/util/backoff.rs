//! Seeded exponential backoff with deterministic jitter.
//!
//! Shared by the transport reconnect loop (`net::client`) and the
//! coordinator's bounded downlink retry: both need "try again, later,
//! but not forever" with delays that are reproducible from the run
//! seed so twin runs schedule retries identically. The jitter stream
//! is a dedicated RNG lane (`seed ^ 0xB0FF`) so consuming backoff
//! delays never perturbs the training/fault/sampling streams.
//!
//! Delay schedule: attempt `k` (0-based) draws uniformly from
//! `[ceil/2, ceil]` where `ceil = min(cap_ms, base_ms << k)` —
//! "decorrelated-half" jitter keeps retries from synchronising across
//! workers while never collapsing below half the exponential ceiling.

use crate::util::rng::Rng;

/// Dedicated stream tag for backoff jitter (see module docs).
const BACKOFF_STREAM: u64 = 0xB0FF;

/// Seeded exponential backoff with bounded attempts.
#[derive(Clone, Debug)]
pub struct Backoff {
    rng: Rng,
    base_ms: u64,
    cap_ms: u64,
    max_attempts: u32,
    attempt: u32,
}

impl Backoff {
    /// A backoff whose jitter stream is derived from `seed ^ 0xB0FF`.
    /// `base_ms` is the first-attempt ceiling, `cap_ms` clamps the
    /// exponential growth, and `max_attempts` bounds total retries.
    pub fn new(seed: u64, base_ms: u64, cap_ms: u64, max_attempts: u32) -> Self {
        Self {
            rng: Rng::new(seed ^ BACKOFF_STREAM),
            base_ms,
            cap_ms,
            max_attempts,
            attempt: 0,
        }
    }

    /// A degenerate backoff that allows `max_attempts` retries with no
    /// delay — the in-process retry discipline (PR 6's bounded downlink
    /// retry), where sleeping would only slow the twin-run harness.
    pub fn immediate(max_attempts: u32) -> Self {
        Self::new(0, 0, 0, max_attempts)
    }

    /// Next delay in milliseconds, or `None` once attempts are exhausted.
    /// Consuming a delay advances both the attempt counter and the
    /// jitter stream, so two `Backoff`s built from the same seed yield
    /// identical schedules.
    pub fn next_delay_ms(&mut self) -> Option<u64> {
        if self.attempt >= self.max_attempts {
            return None;
        }
        // shift clamp: past 2^20 * base the cap has long since taken over
        let ceil = self.cap_ms.min(self.base_ms << self.attempt.min(20));
        self.attempt += 1;
        if ceil == 0 {
            return Some(0);
        }
        let half = ceil / 2;
        Some(half + self.rng.below(ceil - half + 1))
    }

    /// True once every attempt has been consumed.
    pub fn exhausted(&self) -> bool {
        self.attempt >= self.max_attempts
    }

    /// Attempts consumed so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Rearm after a success: resets the attempt counter (the jitter
    /// stream keeps advancing — determinism only requires that the same
    /// seed + same sequence of consume/reset calls replays identically).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_delays_respect_cap_and_grow() {
        let mut b = Backoff::new(7, 25, 2000, 16);
        let mut prev_ceil = 0u64;
        for k in 0..16u32 {
            let d = b.next_delay_ms().expect("attempts remain");
            let ceil = 2000u64.min(25u64 << k.min(20));
            assert!(d <= ceil, "attempt {k}: delay {d} above ceiling {ceil}");
            assert!(d >= ceil / 2, "attempt {k}: delay {d} below half-ceiling");
            assert!(ceil >= prev_ceil, "ceiling must be monotone");
            prev_ceil = ceil;
        }
        assert!(b.exhausted());
        assert_eq!(b.next_delay_ms(), None);
    }

    #[test]
    fn backoff_jitter_is_deterministic_per_seed() {
        let take = |seed: u64| -> Vec<u64> {
            let mut b = Backoff::new(seed, 25, 2000, 10);
            std::iter::from_fn(|| b.next_delay_ms()).collect()
        };
        assert_eq!(take(42), take(42), "same seed, same schedule");
        assert_ne!(take(42), take(43), "different seeds must diverge");
    }

    #[test]
    fn backoff_reset_rearms_attempts() {
        let mut b = Backoff::new(1, 10, 100, 2);
        assert!(b.next_delay_ms().is_some());
        assert!(b.next_delay_ms().is_some());
        assert!(b.exhausted());
        b.reset();
        assert!(!b.exhausted());
        assert_eq!(b.attempts(), 0);
        assert!(b.next_delay_ms().is_some());
    }

    #[test]
    fn backoff_immediate_is_zero_delay_bounded() {
        let mut b = Backoff::immediate(1);
        assert_eq!(b.next_delay_ms(), Some(0));
        assert!(b.exhausted());
        assert_eq!(b.next_delay_ms(), None);
    }
}
