//! Tiny leveled logger backing the `log` crate facade (no env_logger
//! offline). Level from `EFFICIENTGRAD_LOG` (error|warn|info|debug|trace),
//! default `info`. Timestamps are seconds since process start — enough for
//! correlating coordinator events without pulling in a clock/format crate.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

struct SimpleLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for SimpleLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<SimpleLogger> = OnceLock::new();

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("EFFICIENTGRAD_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| SimpleLogger {
        start: Instant::now(),
        level,
    });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
