//! Deterministic chunked parallelism for the coordinator's O(P) host
//! loops (FedAvg folds, codec delta/residual passes, eq. 3 pruning,
//! σ estimation).
//!
//! The contract every helper here upholds: **results are bit-identical
//! regardless of thread count.** Work is split at *fixed* element
//! boundaries ([`CHUNK`]), never at boundaries derived from the number
//! of available cores, and reductions combine per-chunk partials in
//! chunk order. A kernel parallelized through this module therefore
//! produces exactly the same bytes on a 1-core CI runner and a 64-core
//! workstation — which is what lets the pipelined federated leader stay
//! a bit-for-bit twin of the sequential oracle (`tests/federated.rs`)
//! while burning its hot loops on every core.
//!
//! Threads are plain `std::thread::scope` spawns (no pool kept alive —
//! the loops this serves run for milliseconds per call, and a scoped
//! spawn costs microseconds). Inputs at or below one [`CHUNK`] run
//! inline on the caller's thread, so small models never pay a spawn.
//! `EFFICIENTGRAD_PAR_THREADS` caps the worker count (set it to 1 to
//! force sequential execution; the results must not — and do not —
//! change).

use std::sync::OnceLock;

/// Fixed chunk length, in elements. Chunk *boundaries* are part of the
/// numeric contract (reductions combine per-chunk partials in order and
/// the partitioned pruner derives one RNG stream per chunk), so this is
/// a constant, not a function of the machine.
pub const CHUNK: usize = 1 << 16;

/// Worker-thread cap: `EFFICIENTGRAD_PAR_THREADS` if set, else the
/// available parallelism clamped to 8 (the leader's hot loops saturate
/// memory bandwidth long before they saturate a big box).
pub fn max_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Some(n) = std::env::var("EFFICIENTGRAD_PAR_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            return n.max(1);
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8)
    })
}

/// Run `f` over every task, distributing tasks round-robin across up to
/// [`max_threads`] scoped threads (inline when 0/1 tasks or 1 thread).
/// Execution order across threads is unspecified — callers must hand in
/// tasks whose effects are disjoint (the chunk helpers below do).
pub fn run_tasks<T: Send>(tasks: Vec<T>, f: impl Fn(T) + Sync) {
    let threads = max_threads().min(tasks.len());
    if threads <= 1 {
        for t in tasks {
            f(t);
        }
        return;
    }
    let mut parts: Vec<Vec<T>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, t) in tasks.into_iter().enumerate() {
        parts[i % threads].push(t);
    }
    let f = &f;
    std::thread::scope(|s| {
        for part in parts {
            s.spawn(move || {
                for t in part {
                    f(t);
                }
            });
        }
    });
}

/// `f(chunk_index, chunk)` over fixed-size chunks of `data`, in
/// parallel. Single-chunk inputs run inline.
pub fn for_each_chunk_mut<T: Send>(data: &mut [T], f: impl Fn(usize, &mut [T]) + Sync) {
    if data.is_empty() {
        return;
    }
    if data.len() <= CHUNK {
        f(0, data);
        return;
    }
    let tasks: Vec<(usize, &mut [T])> = data.chunks_mut(CHUNK).enumerate().collect();
    run_tasks(tasks, |(i, c)| f(i, c));
}

/// `f(chunk_index, dst_chunk, src_chunk)` over paired fixed-size chunks
/// of two equal-length slices (the axpy/scaled shape).
pub fn for_each_chunk_pair<A: Send, B: Sync>(
    a: &mut [A],
    b: &[B],
    f: impl Fn(usize, &mut [A], &[B]) + Sync,
) {
    assert_eq!(a.len(), b.len(), "chunk pair: {} vs {}", a.len(), b.len());
    if a.is_empty() {
        return;
    }
    if a.len() <= CHUNK {
        f(0, a, b);
        return;
    }
    let tasks: Vec<(usize, (&mut [A], &[B]))> =
        a.chunks_mut(CHUNK).zip(b.chunks(CHUNK)).enumerate().collect();
    run_tasks(tasks, |(i, (ca, cb))| f(i, ca, cb));
}

/// `f(chunk_index, dst_chunk, src1_chunk, src2_chunk)` over three
/// equal-length slices (the codec's `residual += local − reference`
/// fold).
pub fn for_each_chunk_triple<A: Send, B: Sync, C: Sync>(
    a: &mut [A],
    b: &[B],
    c: &[C],
    f: impl Fn(usize, &mut [A], &[B], &[C]) + Sync,
) {
    assert_eq!(a.len(), b.len(), "chunk triple: {} vs {}", a.len(), b.len());
    assert_eq!(a.len(), c.len(), "chunk triple: {} vs {}", a.len(), c.len());
    if a.is_empty() {
        return;
    }
    if a.len() <= CHUNK {
        f(0, a, b, c);
        return;
    }
    let tasks: Vec<(usize, ((&mut [A], &[B]), &[C]))> = a
        .chunks_mut(CHUNK)
        .zip(b.chunks(CHUNK))
        .zip(c.chunks(CHUNK))
        .enumerate()
        .collect();
    run_tasks(tasks, |(i, ((ca, cb), cc))| f(i, ca, cb, cc));
}

/// Map every fixed-size chunk to a value, returning the per-chunk
/// results **in chunk order** — the deterministic-reduction primitive
/// (combine the returned partials in order and the total is independent
/// of thread count).
pub fn map_chunks<T: Sync, R: Send>(data: &[T], f: impl Fn(&[T]) -> R + Sync) -> Vec<R> {
    if data.is_empty() {
        return Vec::new();
    }
    if data.len() <= CHUNK {
        return vec![f(data)];
    }
    let chunks: Vec<&[T]> = data.chunks(CHUNK).collect();
    let mut out: Vec<Option<R>> = (0..chunks.len()).map(|_| None).collect();
    let tasks: Vec<(&[T], &mut Option<R>)> = chunks.into_iter().zip(out.iter_mut()).collect();
    run_tasks(tasks, |(c, slot)| *slot = Some(f(c)));
    out.into_iter().map(|r| r.expect("chunk not mapped")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_mut_covers_every_element_once() {
        let mut big = vec![0u32; CHUNK * 3 + 17];
        for_each_chunk_mut(&mut big, |ci, c| {
            for v in c.iter_mut() {
                *v += 1 + ci as u32;
            }
        });
        // chunk 0 got +1, chunk 1 +2, … — and nothing was touched twice
        assert!(big[..CHUNK].iter().all(|&v| v == 1));
        assert!(big[CHUNK..2 * CHUNK].iter().all(|&v| v == 2));
        assert_eq!(big[3 * CHUNK], 4);
        let mut empty: Vec<u32> = Vec::new();
        for_each_chunk_mut(&mut empty, |_, _| panic!("empty input must not call f"));
    }

    #[test]
    fn pair_and_triple_line_up_chunks() {
        let n = CHUNK + 100;
        let src: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut dst = vec![0f32; n];
        for_each_chunk_pair(&mut dst, &src, |_, d, s| {
            for (x, &y) in d.iter_mut().zip(s) {
                *x = 2.0 * y;
            }
        });
        assert_eq!(dst[0], 0.0);
        assert_eq!(dst[n - 1], 2.0 * (n - 1) as f32);
        let ones = vec![1f32; n];
        let mut acc = vec![0f32; n];
        for_each_chunk_triple(&mut acc, &dst, &ones, |_, a, b, c| {
            for ((x, &y), &z) in a.iter_mut().zip(b).zip(c) {
                *x = y - z;
            }
        });
        assert_eq!(acc[n - 1], 2.0 * (n - 1) as f32 - 1.0);
    }

    #[test]
    fn map_chunks_returns_partials_in_chunk_order() {
        let data: Vec<f32> = (0..(2 * CHUNK + 5)).map(|i| i as f32).collect();
        let lens = map_chunks(&data, |c| c.len());
        assert_eq!(lens, vec![CHUNK, CHUNK, 5]);
        // order-sensitive fingerprint: first element of each chunk
        let firsts = map_chunks(&data, |c| c[0]);
        assert_eq!(firsts, vec![0.0, CHUNK as f32, (2 * CHUNK) as f32]);
        assert!(map_chunks(&Vec::<f32>::new(), |_| 0u8).is_empty());
    }

    #[test]
    fn results_independent_of_task_distribution() {
        // the determinism contract: a reduction over map_chunks partials
        // combined in order gives the same bits as a plain sequential
        // fold over the same chunk boundaries
        let data: Vec<f32> = (0..(3 * CHUNK + 999)).map(|i| (i as f32).sin()).collect();
        let par: f64 = map_chunks(&data, |c| c.iter().map(|&x| x as f64).sum::<f64>())
            .iter()
            .sum();
        let seq: f64 = data
            .chunks(CHUNK)
            .map(|c| c.iter().map(|&x| x as f64).sum::<f64>())
            .sum();
        assert_eq!(par.to_bits(), seq.to_bits());
    }
}
