//! Minimal JSON parser + writer (the `serde` facade is unavailable
//! offline). Parses the full JSON grammar into a [`Json`] tree; used for
//! `artifacts/manifest.json` and for the reports the figure generators
//! emit. Not performance-critical: manifests are < 1 MB and read once.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path access: `j.at(&["models", "convnet_s", "batch"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.pos..self.pos + 4],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 4;
                                    char::from_u32(
                                        0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) => {
                    // consume one UTF-8 scalar
                    let len = utf8_len(c);
                    let s = std::str::from_utf8(&self.b[self.pos..self.pos + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders used by report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.at(&["b", "c"]), Some(&Json::Bool(true)));
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn parses_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.25").unwrap().as_f64(), Some(-0.25));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn nested_path_access() {
        let j = Json::parse(r#"{"m":{"x":{"batch":32}}}"#).unwrap();
        assert_eq!(j.at(&["m", "x", "batch"]).unwrap().as_usize(), Some(32));
        assert!(j.at(&["m", "y"]).is_none());
    }
}
