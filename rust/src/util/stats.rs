//! Statistics helpers shared by the trainer metrics, the sparsity module
//! and the bench harness: moments, percentiles, histograms, cosine angle,
//! and Φ/Φ⁻¹ (the inverse normal CDF behind the paper's eq. 5).

/// Mean of a slice (0.0 for empty).
///
/// The per-chunk sum is the lane-striped reduction of
/// [`crate::util::simd`] (`STRIPE` f64 accumulators, element `i` folding
/// into lane `i % STRIPE`, lanes combined sequentially), chunked at the
/// fixed [`crate::util::par::CHUNK`] boundary with partials combined in
/// chunk order — so the result is bit-identical whether the chunks run
/// sequentially or in parallel, and whether a chunk runs scalar or AVX2.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    if xs.len() <= crate::util::par::CHUNK {
        // single chunk stays inline and allocation-free
        return crate::util::simd::sum_striped(xs) / xs.len() as f64;
    }
    let partials = crate::util::par::map_chunks(xs, crate::util::simd::sum_striped);
    partials.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation — the σ of the paper's eq. 5, on the
/// codec's per-tensor hot path, so big tensors run it on every core.
///
/// One *fused* pass per chunk accumulates Σx and Σx² together (striped,
/// f64 — see [`mean`] for the determinism contract), then
/// σ = √max(0, Σx²/n − mean²); the max guards the moment identity
/// against f64 rounding when the variance underflows toward zero.
/// Replaces the old two-sweep (mean, then Σ(x−m)²) formulation: half the
/// memory traffic, and the two agree to f64 rounding (pinned by a test
/// below) — for zero-centred gradient deltas at f32 scale the moment
/// form loses no meaningful precision.
pub fn std_dev(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let (sum, sumsq) = if xs.len() <= crate::util::par::CHUNK {
        crate::util::simd::sum_sumsq_striped(xs)
    } else {
        crate::util::par::map_chunks(xs, crate::util::simd::sum_sumsq_striped)
            .iter()
            .fold((0.0, 0.0), |(s, q), &(cs, cq)| (s + cs, q + cq))
    };
    let n = xs.len() as f64;
    let m = sum / n;
    (sumsq / n - m * m).max(0.0).sqrt()
}

/// Fraction of exact zeros (realized pruning sparsity).
pub fn zero_fraction(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x == 0.0).count() as f64 / xs.len() as f64
}

/// Cosine of the angle between two flat vectors (Fig. 3b's metric).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-300)
}

/// Angle in degrees between two vectors.
pub fn angle_degrees(a: &[f32], b: &[f32]) -> f64 {
    cosine(a, b).clamp(-1.0, 1.0).acos().to_degrees()
}

/// p-th percentile (0..=100) with linear interpolation; sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Fixed-range histogram; values outside [lo, hi) are clamped to the edge
/// bins (matches jnp.histogram's behaviour closely enough for Fig. 3a).
pub fn histogram(xs: &[f32], lo: f64, hi: f64, bins: usize) -> Vec<u64> {
    let mut out = vec![0u64; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let mut i = ((x as f64 - lo) / w) as i64;
        i = i.clamp(0, bins as i64 - 1);
        out[i as usize] += 1;
    }
    out
}

/// Standard normal CDF Φ via erf.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Error function, |relative err| < ~1e-14: Maclaurin series for |x| <= 2
/// (no catastrophic cancellation there), Lentz continued fraction for the
/// complementary function beyond.
pub fn erf(x: f64) -> f64 {
    let ax = x.abs();
    if ax <= 2.0 {
        // erf(x) = 2/sqrt(pi) * sum (-1)^n x^(2n+1) / (n! (2n+1))
        let mut term = x;
        let mut sum = x;
        let x2 = x * x;
        for n in 1..200 {
            term *= -x2 / n as f64;
            let add = term / (2 * n + 1) as f64;
            sum += add;
            if add.abs() < 1e-18 * sum.abs().max(1e-30) {
                break;
            }
        }
        2.0 / std::f64::consts::PI.sqrt() * sum
    } else {
        let e = erfc_large(ax);
        if x > 0.0 {
            1.0 - e
        } else {
            e - 1.0
        }
    }
}

/// erfc for x > 2 via the classical continued fraction
/// erfc(x) = exp(-x^2)/sqrt(pi) * 1/(x + (1/2)/(x + 1/(x + (3/2)/(x + ...))))
/// evaluated with modified Lentz.
fn erfc_large(x: f64) -> f64 {
    let tiny = 1e-300;
    let mut f: f64 = x;
    let mut c: f64 = x;
    let mut d: f64 = 0.0;
    for k in 1..200 {
        let a = k as f64 / 2.0; // a_k = k/2
        // recurrence: b = x, a_k alternating k/2
        d = x + a * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = x + a / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x * x).exp() / std::f64::consts::PI.sqrt() / f
}

/// Inverse standard normal CDF Φ⁻¹ (Acklam's algorithm + one Halley
/// refinement; |relative err| < 1e-9). This is the `ndtri` the paper's
/// eq. 5 uses to map pruning rate P to threshold τ.
pub fn ndtri(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "ndtri domain: {p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    // Acklam coefficients
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    let x = if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // one Halley refinement against Φ
    let e = normal_cdf(x) - p;
    let u = e * (std::f64::consts::TAU).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.118033988749895).abs() < 1e-9);
    }

    /// The old two-sweep std_dev (mean, then Σ(x−m)²) is the numerical
    /// reference the fused moment form is held against. True bit parity
    /// between the formulations is impossible (different associations);
    /// the contract is agreement to f64 rounding at gradient-like scale.
    fn std_dev_two_sweep(xs: &[f32]) -> f64 {
        let m = mean(xs);
        (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
    }

    #[test]
    fn fused_std_dev_matches_two_sweep_reference() {
        let mut rng = crate::util::rng::Rng::new(31);
        for &n in &[5usize, 1000, crate::util::par::CHUNK + 17] {
            let mut xs = vec![0f32; n];
            rng.fill_normal(&mut xs, 0.05); // gradient-like scale
            let fused = std_dev(&xs);
            let two = std_dev_two_sweep(&xs);
            assert!(
                (fused - two).abs() <= 1e-9 * two.max(1e-12),
                "n={n}: fused {fused} vs two-sweep {two}"
            );
        }
        // exactly representable data: the two agree exactly
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(std_dev(&xs), std_dev_two_sweep(&xs));
    }

    /// Pin the fused kernel's exact shape: striped lanes folded in order,
    /// chunk partials combined in chunk order. A reimplementation here
    /// must match bit for bit at any size — this is what makes the value
    /// independent of thread count and (with the simd parity pins in
    /// `util::simd`) of the scalar/vector choice.
    #[test]
    fn fused_std_dev_chunk_fold_is_bit_deterministic() {
        let mut rng = crate::util::rng::Rng::new(32);
        let n = 2 * crate::util::par::CHUNK + 123;
        let mut xs = vec![0f32; n];
        rng.fill_normal(&mut xs, 1.0);
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for chunk in xs.chunks(crate::util::par::CHUNK) {
            let mut sums = [0.0f64; crate::util::simd::STRIPE];
            let mut sqs = [0.0f64; crate::util::simd::STRIPE];
            for (i, &x) in chunk.iter().enumerate() {
                let xd = x as f64;
                sums[i % crate::util::simd::STRIPE] += xd;
                sqs[i % crate::util::simd::STRIPE] += xd * xd;
            }
            sum += sums.iter().sum::<f64>();
            sumsq += sqs.iter().sum::<f64>();
        }
        let m = sum / n as f64;
        let want = (sumsq / n as f64 - m * m).max(0.0).sqrt();
        assert_eq!(std_dev(&xs).to_bits(), want.to_bits());
    }

    #[test]
    fn cosine_orthogonal_and_parallel() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 2.0], &[2.0, 4.0]) - 1.0).abs() < 1e-9);
        assert!((angle_degrees(&[1.0, 0.0], &[0.0, 1.0]) - 90.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        assert!((percentile(&xs, 50.0) - 1.5).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[-10.0, -0.5, 0.5, 10.0], -1.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 1e-9);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 1e-9);
        assert!((erf(2.0) - 0.9953222650189527).abs() < 1e-7);
    }

    #[test]
    fn ndtri_matches_scipy_values() {
        // scipy.special.ndtri references
        for (p, want) in [
            (0.5, 0.0),
            (0.975, 1.959963984540054),
            (0.95, 1.6448536269514722),
            (0.9, 1.2815515655446004),
            (0.1, -1.2815515655446004),
            (0.999, 3.090232306167813),
        ] {
            let got = ndtri(p);
            assert!((got - want).abs() < 1e-7, "ndtri({p}) = {got}, want {want}");
        }
    }

    #[test]
    fn ndtri_roundtrips_cdf() {
        for &p in &[0.01, 0.2, 0.5, 0.73, 0.99] {
            assert!((normal_cdf(ndtri(p)) - p).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_fraction_counts() {
        assert_eq!(zero_fraction(&[0.0, 1.0, 0.0, 2.0]), 0.5);
    }
}
