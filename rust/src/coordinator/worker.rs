//! Edge worker: a thread owning a data shard + train-step executable.
//!
//! Workers model the paper's edge devices: they receive the global model,
//! run `local_steps` of EfficientGrad training on their private shard, and
//! ship back updated parameters plus telemetry (loss, realized gradient
//! sparsity — the input the accelerator energy model needs). A `slowdown`
//! factor simulates stragglers; the simulated time is reported without
//! actually sleeping so tests stay fast.
//!
//! With `cfg.residency == Resident` (default) the worker's training state
//! stays in device buffers for the whole round: the broadcast params are
//! uploaded once per round, `local_steps` execute buffer-to-buffer, and
//! the O(model) download happens once at the round boundary — the
//! software analogue of the paper's on-chip-reuse argument. The literal
//! path remains selectable as a fallback.
//!
//! The *network* tier is compressed independently
//! ([`crate::config::CommMode`]): each worker keeps a `reference` replica
//! of the params the leader believes it holds, advanced only by applying
//! the leader's downlink [`ModelUpdate`]s — dense snapshots replace it,
//! pruned deltas accumulate into it, and chained deltas replay the
//! per-round downlinks a dropped round made it miss — so leader and
//! worker replicas stay bit-identical. The uplink is the worker's own
//! pruned delta (`local − reference`) through its error-feedback
//! [`DeltaCodec`], tagged with the model version it was computed against
//! ([`WorkerReport::base_version`]) so the quorum leader can fold it
//! late with the right staleness weight; in `dense` mode both directions
//! ship full snapshots exactly as before.

use std::sync::mpsc::{self, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::comm::{DeltaCodec, ModelUpdate};
use crate::config::{CommMode, CommPruner, TrainConfig};
use crate::data::batcher::Prefetcher;
use crate::data::Dataset;
use crate::manifest::{ArtifactSpec, ModelSpec};
use crate::params::ParamStore;
use crate::runtime::{Runtime, StepDriver, TransferStats};
use crate::util::rng::Rng;

/// Network-tier settings a worker's uplink codec is built from (one
/// bundle so the spawn signature stays readable).
#[derive(Clone, Copy)]
pub struct CommSetup {
    pub mode: CommMode,
    pub rate: f64,
    pub pruner: CommPruner,
}

/// One round's work order.
pub struct WorkerTask {
    pub round: usize,
    /// the model version this task's payload brings the worker to — the
    /// version its uplink will be computed against. Tags the round's
    /// wire exchange so the leader can fold a late report with the right
    /// staleness weight.
    pub version: u64,
    /// the downlink: a dense snapshot (first round / resync beyond the
    /// retained window / `dense` mode), the pruned global delta, or a
    /// chain of the retained per-round deltas (a worker ≤ `max_chain`
    /// versions behind — replays the missed downlinks bit-identically
    /// and keeps the error-feedback residual alive)
    pub payload: ModelUpdate,
    pub local_steps: usize,
    /// straggler slowdown factor (1.0 = healthy)
    pub slowdown: f64,
    /// wall-clock straggler injection (`federated.straggler_sleep`):
    /// actually hold the round for `(slowdown − 1)×` the measured work
    /// time before replying, so schedule benchmarks see a real straggler
    /// on the leader's clock. Off (the default), the slowdown is only
    /// *reported* through `sim_secs` and tests stay fast.
    pub sleep: bool,
    pub reply: mpsc::Sender<WorkerReport>,
}

/// One round's result.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker_id: usize,
    pub round: usize,
    /// the model version `update` was computed against
    /// (= [`WorkerTask::version`]); the leader's staleness weight for a
    /// late fold is `λ^(current − base_version)`
    pub base_version: u64,
    /// the uplink: dense params in `dense` mode, the worker's pruned
    /// delta vs its reference otherwise
    pub update: ModelUpdate,
    pub examples: usize,
    pub mean_loss: f64,
    pub mean_sparsity: f64,
    /// measured wall time x slowdown (what a real deployment would see)
    pub sim_secs: f64,
    /// this worker's host↔device ledger for the round (reset at task
    /// receipt, so it covers broadcast upload + local steps + host sync)
    pub transfer: TransferStats,
}

enum Msg {
    Task(WorkerTask),
    Stop,
}

/// Handle to a running worker thread.
pub struct WorkerHandle {
    pub id: usize,
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn the worker thread. The `xla` crate's handles are not `Send`,
    /// so the thread creates its *own* PJRT client and compiles the train
    /// artifact itself — exactly like a real edge device bringing up its
    /// own accelerator. Compile failures surface through the `ready`
    /// handshake so `spawn` stays synchronous and fallible.
    pub fn spawn(
        id: usize,
        shard: Dataset,
        train_art: ArtifactSpec,
        model: &ModelSpec,
        cfg: TrainConfig,
        comm: CommSetup,
    ) -> Result<Self> {
        let mut store = ParamStore::init(model, cfg.seed); // momenta + B local
        let batch = model.batch;
        if shard.n < batch {
            return Err(anyhow!(
                "worker {id}: shard has {} examples < batch {batch}",
                shard.n
            ));
        }
        let shard_n = shard.n;
        let model = model.clone();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("edge-worker-{id}"))
            .spawn(move || {
                let mut driver = match (|| -> Result<StepDriver> {
                    let rt = Runtime::cpu()?;
                    StepDriver::new(cfg.residency, &rt, rt.load(&train_art)?, &model, &store)
                })() {
                    Ok(d) => {
                        let _ = ready_tx.send(Ok(()));
                        d
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // shard moves to the prefetch thread; gather/shuffle
                // overlap with the train step
                let mut batcher = Prefetcher::new(shard, batch, cfg.seed ^ id as u64, 2);
                // the leader's view of this worker's params, advanced
                // only by downlink payloads (kept bit-identical to the
                // leader's reference replica), plus the uplink codec with
                // its error-feedback residual
                let mut reference: Vec<crate::tensor::Tensor> = Vec::new();
                let mut codec = DeltaCodec::with_pruner(comm.mode, comm.rate, comm.pruner);
                let uplink_rng = Rng::new(cfg.seed ^ 0x5EED_C0DE).fold_in(id as u64);
                while let Ok(Msg::Task(task)) = rx.recv() {
                    let t0 = Instant::now();
                    // per-round ledger: everything from the broadcast
                    // upload to the round-boundary sync lands in the
                    // report's TransferStats
                    driver.reset_transfer_stats();
                    // materialize the downlink into the reference
                    // replica, then hand the device its copy. In dense
                    // *mode* no reference is kept at all — the snapshot
                    // moves straight into load_params, exactly the
                    // pre-comm path (zero extra O(model) copies)
                    let device_params = match task.payload {
                        ModelUpdate::Dense(p) => {
                            // a snapshot erases whatever divergence the
                            // carried residual described
                            codec.reset_residual();
                            if codec.mode() == CommMode::Dense {
                                p
                            } else {
                                reference = p;
                                reference.clone()
                            }
                        }
                        // a chain replays the missed per-round deltas in
                        // order — same float ops an always-on peer ran, so
                        // the replica lands bit-identical and the carried
                        // EF residual stays valid (no reset, unlike a
                        // dense resync which erases the divergence the
                        // residual described)
                        u @ (ModelUpdate::Delta(_) | ModelUpdate::Chain(_)) => {
                            if reference.is_empty() {
                                log::error!(
                                    "worker {id}: delta downlink before any snapshot; \
                                     skipping round"
                                );
                                continue;
                            }
                            if let Err(e) = u.apply(&mut reference) {
                                // the replica is now an unknown number of
                                // versions behind whatever the leader will
                                // dispatch next (it may already have queued
                                // further deltas under pipeline depth > 1)
                                // — poison it so every delta is rejected
                                // until a dense snapshot resyncs us
                                reference.clear();
                                codec.reset_residual();
                                log::error!("worker {id}: broadcast rejected: {e:#}");
                                continue;
                            }
                            reference.clone()
                        }
                    };
                    if let Err(e) = driver.load_params(&mut store, device_params) {
                        log::error!("worker {id}: broadcast rejected: {e:#}");
                        continue;
                    }
                    let mut losses = 0.0;
                    let mut spars = 0.0;
                    let mut ok = true;
                    for _ in 0..task.local_steps {
                        let batch = batcher.next_batch();
                        match driver.step(
                            &mut store,
                            &batch,
                            cfg.lr as f32,
                            cfg.momentum as f32,
                        ) {
                            Ok(out) => {
                                losses += out.loss as f64;
                                spars += crate::util::stats::mean(&out.sparsity);
                            }
                            Err(e) => {
                                log::error!("worker {id}: step failed: {e:#}");
                                ok = false;
                                break;
                            }
                        }
                    }
                    // round boundary: the one place the resident path
                    // downloads the O(model) state
                    if ok {
                        if let Err(e) = driver.sync_to_host(&mut store) {
                            log::error!("worker {id}: host sync failed: {e:#}");
                            ok = false;
                        }
                    }
                    if !ok {
                        // drop the reply sender: the leader aggregates
                        // the reports that did arrive and records this
                        // worker as dropped for the round
                        continue;
                    }
                    // uplink: dense snapshot or pruned delta vs reference
                    let update = match codec.mode() {
                        CommMode::Dense => ModelUpdate::Dense(store.params.clone()),
                        _ => {
                            let mut rng = uplink_rng.fold_in(task.round as u64);
                            match codec.encode(&store.params, &reference, &mut rng) {
                                Ok(u) => u,
                                Err(e) => {
                                    log::error!("worker {id}: uplink encode failed: {e:#}");
                                    continue;
                                }
                            }
                        }
                    };
                    let n = task.local_steps.max(1) as f64;
                    // straggling: either genuinely hold the round on the
                    // wall clock (sleep injection — the reply, and with
                    // it the leader's barrier, waits) or only report the
                    // inflated simulated time
                    let sim_secs = if task.sleep && task.slowdown > 1.0 {
                        let work = t0.elapsed();
                        std::thread::sleep(work.mul_f64(task.slowdown - 1.0));
                        t0.elapsed().as_secs_f64()
                    } else {
                        t0.elapsed().as_secs_f64() * task.slowdown
                    };
                    let _ = task.reply.send(WorkerReport {
                        worker_id: id,
                        round: task.round,
                        base_version: task.version,
                        update,
                        examples: shard_n,
                        mean_loss: losses / n,
                        mean_sparsity: spars / n,
                        sim_secs,
                        transfer: driver.transfer_stats(),
                    });
                }
            })
            .map_err(|e| anyhow!("spawning worker {id}: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker {id} died during startup"))?
            .map_err(|e| e.context(format!("worker {id} failed to compile artifact")))?;
        Ok(Self {
            id,
            tx,
            join: Some(join),
        })
    }

    pub fn submit(&self, task: WorkerTask) -> Result<()> {
        self.tx
            .send(Msg::Task(task))
            .map_err(|_| anyhow!("worker {} channel closed", self.id))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
