//! Edge worker: a thread owning a data shard + train-step executable.
//!
//! Workers model the paper's edge devices: they receive the global model,
//! run `local_steps` of EfficientGrad training on their private shard, and
//! ship back updated parameters plus telemetry (loss, realized gradient
//! sparsity — the input the accelerator energy model needs). A `slowdown`
//! factor simulates stragglers; the simulated time is reported without
//! actually sleeping so tests stay fast.
//!
//! With `cfg.residency == Resident` (default) the worker's training state
//! stays in device buffers for the whole round: the broadcast params are
//! uploaded once per round, `local_steps` execute buffer-to-buffer, and
//! the O(model) download happens once at the round boundary — the
//! software analogue of the paper's on-chip-reuse argument. The literal
//! path remains selectable as a fallback.
//!
//! The *network* tier is compressed independently
//! ([`crate::config::CommMode`]): each worker keeps a `reference` replica
//! of the params the leader believes it holds, advanced only by applying
//! the leader's downlink [`ModelUpdate`]s — dense snapshots replace it,
//! pruned deltas accumulate into it, and chained deltas replay the
//! per-round downlinks a dropped round made it miss — so leader and
//! worker replicas stay bit-identical. The uplink is the worker's own
//! pruned delta (`local − reference`) through its error-feedback
//! [`DeltaCodec`], tagged with the model version it was computed against
//! ([`WorkerReport::base_version`]) so the quorum leader can fold it
//! late with the right staleness weight; in `dense` mode both directions
//! ship full snapshots exactly as before.
//!
//! Both directions travel as sealed [`Frame`]s (magic, schema version,
//! length, FNV-1a checksum — [`crate::comm::envelope`]). A downlink frame
//! that fails its checks, or an update that fails to apply, is *rejected,
//! never applied*: the worker poisons its replica (clears the reference
//! and the error-feedback residual) and replies with a
//! [`FrameKind::Nack`] so the leader can retry with a dense snapshot and,
//! failing that, dense-resync next round. A [`crate::faults::FaultPlan`]
//! injects chaos at the same boundary a real radio or process would fail:
//! uplink frames can be corrupted, truncated, duplicated or reordered at
//! send, and a crash-at-step-`k` decision makes the worker run exactly
//! `k` steps and go silent — no report, no nack, its state written off
//! until the next dense resync (a simulated device reboot).

use std::rc::Rc;
use std::sync::mpsc::{self, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::comm::envelope::{decode_update, read_update, write_update, ByteReader, ByteWriter};
use crate::comm::{DeltaCodec, Frame, FrameKind, ModelUpdate};
use crate::config::{CommMode, CommPruner, TrainConfig, WireQuant};
use crate::data::batcher::Prefetcher;
use crate::data::Dataset;
use crate::faults::{FaultPlan, WireFault};
use crate::manifest::{ArtifactSpec, ModelSpec};
use crate::params::ParamStore;
use crate::runtime::{Executable, Runtime, StepDriver, TransferStats};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Network-tier settings a worker's uplink codec is built from (one
/// bundle so the spawn signature stays readable).
#[derive(Clone, Copy)]
pub struct CommSetup {
    pub mode: CommMode,
    pub rate: f64,
    pub pruner: CommPruner,
    /// v2 survivor-value quantization (`federated.wire_quant`); `Off`
    /// keeps the legacy f32 wire bit-for-bit
    pub quant: WireQuant,
}

/// One round's work order.
pub struct WorkerTask {
    pub round: usize,
    /// the model version this task's payload brings the worker to — the
    /// version its uplink will be computed against. Tags the round's
    /// wire exchange so the leader can fold a late report with the right
    /// staleness weight.
    pub version: u64,
    /// the downlink, sealed: a serialized [`ModelUpdate`] — dense
    /// snapshot (first round / resync beyond the retained window /
    /// `dense` mode), pruned global delta, or chain of retained
    /// per-round deltas — inside an integrity-checked [`Frame`]. The
    /// worker opens and decodes it itself; a frame that fails any check
    /// is nacked, never applied.
    pub frame: Frame,
    pub local_steps: usize,
    /// straggler slowdown factor (1.0 = healthy)
    pub slowdown: f64,
    /// wall-clock straggler injection (`federated.straggler_sleep`):
    /// actually hold the round for `(slowdown − 1)×` the measured work
    /// time before replying, so schedule benchmarks see a real straggler
    /// on the leader's clock. Off (the default), the slowdown is only
    /// *reported* through `sim_secs` and tests stay fast.
    pub sleep: bool,
    /// uplink transport: `(worker id, sealed frame)`. The id rides
    /// outside the seal — it is channel addressing, not payload — and
    /// the leader cross-checks it against the sealed report's own
    /// `worker_id` before folding.
    pub reply: mpsc::Sender<(usize, Frame)>,
}

/// One round's result.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker_id: usize,
    pub round: usize,
    /// the model version `update` was computed against
    /// (= [`WorkerTask::version`]); the leader's staleness weight for a
    /// late fold is `λ^(current − base_version)`
    pub base_version: u64,
    /// the uplink: dense params in `dense` mode, the worker's pruned
    /// delta vs its reference otherwise
    pub update: ModelUpdate,
    pub examples: usize,
    pub mean_loss: f64,
    pub mean_sparsity: f64,
    /// measured wall time x slowdown (what a real deployment would see)
    pub sim_secs: f64,
    /// this worker's host↔device ledger for the round (reset at task
    /// receipt, so it covers broadcast upload + local steps + host sync)
    pub transfer: TransferStats,
}

impl WorkerReport {
    /// Serialize into a [`FrameKind::Report`] payload: the scalar fields
    /// little-endian, then the update through the shared
    /// [`crate::comm::envelope`] encoding.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u32(self.worker_id as u32);
        w.put_u32(self.round as u32);
        w.put_u64(self.base_version);
        w.put_u64(self.examples as u64);
        w.put_f64(self.mean_loss);
        w.put_f64(self.mean_sparsity);
        w.put_f64(self.sim_secs);
        let t = &self.transfer;
        for v in [t.state_up, t.state_down, t.batch_up, t.metrics_down, t.steps, t.evals] {
            w.put_u64(v);
        }
        write_update(&mut w, &self.update);
        w.into_bytes()
    }

    /// Decode a report payload (after [`Frame::open`] verified the
    /// envelope). Every length and index inside is re-validated; NaN
    /// scalars decode honestly and are rejected at the fold boundary,
    /// not here.
    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(payload);
        let worker_id = r.get_u32()? as usize;
        let round = r.get_u32()? as usize;
        let base_version = r.get_u64()?;
        let examples = r.get_u64()? as usize;
        let mean_loss = r.get_f64()?;
        let mean_sparsity = r.get_f64()?;
        let sim_secs = r.get_f64()?;
        let transfer = TransferStats {
            state_up: r.get_u64()?,
            state_down: r.get_u64()?,
            batch_up: r.get_u64()?,
            metrics_down: r.get_u64()?,
            steps: r.get_u64()?,
            evals: r.get_u64()?,
        };
        let update = read_update(&mut r)?;
        r.finish()?;
        Ok(Self {
            worker_id,
            round,
            base_version,
            update,
            examples,
            mean_loss,
            mean_sparsity,
            sim_secs,
            transfer,
        })
    }
}

/// Everything a worker's cross-round state amounts to, for the durable
/// run store: the network-tier replica (reference + error-feedback
/// residual), the device-tier training state that persists across rounds
/// (momenta + step counter — params are overwritten by every downlink,
/// so they need no capture), and the batcher position. Restoring a
/// snapshot into a fresh worker reproduces the uninterrupted run
/// bit-for-bit.
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    /// the downlink-advanced reference replica (empty = never synced /
    /// poisoned — the next dispatch dense-resyncs)
    pub reference: Vec<Tensor>,
    /// the uplink codec's error-feedback residual (empty = fresh)
    pub residual: Vec<Vec<f32>>,
    /// batches drawn from the prefetcher so far — a restored worker
    /// fast-forwards its batcher to this position
    pub batches_drawn: u64,
    /// momentum buffers (device-resident across rounds, so they are
    /// state the downlink does NOT carry)
    pub momenta: Vec<Tensor>,
    /// device step counter (drives the per-step dropconnect RNG seed)
    pub step: u64,
}

enum Msg {
    Task(WorkerTask),
    /// Sync the device state down and send back a [`WorkerSnapshot`]
    /// (run-store persistence at a round boundary).
    Capture(mpsc::Sender<WorkerSnapshot>),
    /// Install a persisted snapshot (resume): momenta + step go into the
    /// store *before* the step driver is rebuilt, the reference/residual
    /// replace the replica, and the batcher fast-forwards.
    Restore {
        snap: Box<WorkerSnapshot>,
        ack: mpsc::Sender<Result<()>>,
    },
    Stop,
}

/// Handle to a running worker thread.
pub struct WorkerHandle {
    pub id: usize,
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn the worker thread. The `xla` crate's handles are not `Send`,
    /// so the thread creates its *own* PJRT client and compiles the train
    /// artifact itself — exactly like a real edge device bringing up its
    /// own accelerator. Compile failures surface through the `ready`
    /// handshake so `spawn` stays synchronous and fallible. `faults`
    /// carries the run's chaos schedule (uplink wire faults and
    /// crash-at-step-k fire worker-side); `None` is the clean channel.
    pub fn spawn(
        id: usize,
        shard: Dataset,
        train_art: ArtifactSpec,
        model: &ModelSpec,
        cfg: TrainConfig,
        comm: CommSetup,
        faults: Option<FaultPlan>,
    ) -> Result<Self> {
        let mut store = ParamStore::init(model, cfg.seed); // momenta + B local
        let batch = model.batch;
        if shard.n < batch {
            return Err(anyhow!(
                "worker {id}: shard has {} examples < batch {batch}",
                shard.n
            ));
        }
        let shard_n = shard.n;
        let model = model.clone();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("edge-worker-{id}"))
            .spawn(move || {
                // runtime + executable stay alive in thread scope so a
                // Restore can rebuild the step driver against them
                let built = (|| -> Result<(Runtime, Rc<Executable>, StepDriver)> {
                    let rt = Runtime::cpu()?;
                    let exe = rt.load(&train_art)?;
                    let driver =
                        StepDriver::new(cfg.residency, &rt, exe.clone(), &model, &store)?;
                    Ok((rt, exe, driver))
                })();
                let (rt, exe, mut driver) = match built {
                    Ok(x) => {
                        let _ = ready_tx.send(Ok(()));
                        x
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // shard moves to the prefetch thread; gather/shuffle
                // overlap with the train step
                let mut batcher = Prefetcher::new(shard, batch, cfg.seed ^ id as u64, 2);
                let mut batches_drawn: u64 = 0;
                // the leader's view of this worker's params, advanced
                // only by downlink payloads (kept bit-identical to the
                // leader's reference replica), plus the uplink codec with
                // its error-feedback residual
                let mut reference: Vec<Tensor> = Vec::new();
                let mut codec = DeltaCodec::with_pruner(comm.mode, comm.rate, comm.pruner)
                    .with_quant(comm.quant);
                let uplink_rng = Rng::new(cfg.seed ^ 0x5EED_C0DE).fold_in(id as u64);
                // an absent plan is the all-zero plan: decisions are
                // pure functions of (site, round, worker), so the zero
                // plan never fires and never perturbs any RNG stream
                let plan = faults.unwrap_or_default();
                loop {
                    let task = match rx.recv() {
                        Ok(Msg::Task(task)) => task,
                        Ok(Msg::Capture(reply)) => {
                            // bring the host store current first (dirty-
                            // gated: free right after a round's sync,
                            // and correct right after a crash, whose
                            // advanced momenta the snapshot must carry)
                            match driver.sync_to_host(&mut store) {
                                Ok(()) => {
                                    let _ = reply.send(WorkerSnapshot {
                                        reference: reference.clone(),
                                        residual: codec.residual().to_vec(),
                                        batches_drawn,
                                        momenta: store.momenta.clone(),
                                        step: store.step,
                                    });
                                }
                                // dropping `reply` unsent surfaces the
                                // failure as a leader-side recv error
                                Err(e) => {
                                    log::error!("worker {id}: capture sync failed: {e:#}")
                                }
                            }
                            continue;
                        }
                        Ok(Msg::Restore { snap, ack }) => {
                            let result = (|| -> Result<()> {
                                let snap = *snap;
                                if snap.batches_drawn < batches_drawn {
                                    bail!(
                                        "worker {id}: cannot rewind batcher from \
                                         {batches_drawn} to {}",
                                        snap.batches_drawn
                                    );
                                }
                                // momenta + step land in the store BEFORE
                                // the driver rebuild: DeviceState::new
                                // uploads them and seeds the device step
                                // counter (per-step RNG) from store.step
                                store.momenta = snap.momenta;
                                store.step = snap.step;
                                driver = StepDriver::new(
                                    cfg.residency,
                                    &rt,
                                    exe.clone(),
                                    &model,
                                    &store,
                                )?;
                                reference = snap.reference;
                                codec.set_residual(snap.residual);
                                for _ in batches_drawn..snap.batches_drawn {
                                    let _ = batcher.next_batch();
                                }
                                batches_drawn = snap.batches_drawn;
                                Ok(())
                            })();
                            let _ = ack.send(result);
                            continue;
                        }
                        Ok(Msg::Stop) | Err(_) => break,
                    };
                    let t0 = Instant::now();
                    // per-round ledger: everything from the broadcast
                    // upload to the round-boundary sync lands in the
                    // report's TransferStats
                    driver.reset_transfer_stats();
                    // open the seal: magic, schema version, kind, length
                    // and checksum must all hold before any payload byte
                    // is parsed. A frame that fails — corrupted or
                    // truncated in flight — is rejected, never applied.
                    let opened = task.frame.open().and_then(|(kind, payload)| {
                        if kind != FrameKind::Update {
                            bail!("downlink frame kind {kind:?}, wanted Update");
                        }
                        decode_update(payload)
                    });
                    let update = match opened {
                        Ok(u) => u,
                        Err(e) => {
                            // the replica may or may not have missed real
                            // state — poison it and nack; the leader
                            // retries with a dense snapshot
                            log::error!("worker {id}: downlink rejected: {e:#}");
                            reference.clear();
                            codec.reset_residual();
                            let _ = task.reply.send((id, Frame::seal(FrameKind::Nack, &[])));
                            continue;
                        }
                    };
                    // materialize the downlink into the reference
                    // replica, then hand the device its copy. In dense
                    // *mode* no reference is kept at all — the snapshot
                    // moves straight into load_params, exactly the
                    // pre-comm path (zero extra O(model) copies)
                    let device_params = match update {
                        ModelUpdate::Dense(p) => {
                            // a snapshot erases whatever divergence the
                            // carried residual described
                            codec.reset_residual();
                            if codec.mode() == CommMode::Dense {
                                p
                            } else {
                                reference = p;
                                reference.clone()
                            }
                        }
                        // a chain replays the missed per-round deltas in
                        // order — same float ops an always-on peer ran, so
                        // the replica lands bit-identical and the carried
                        // EF residual stays valid (no reset, unlike a
                        // dense resync which erases the divergence the
                        // residual described)
                        u @ (ModelUpdate::Delta(_) | ModelUpdate::Chain(_)) => {
                            if reference.is_empty() {
                                // nothing to apply a delta to — nack so
                                // the leader sends the dense snapshot
                                // this replica actually needs
                                log::error!(
                                    "worker {id}: delta downlink before any snapshot"
                                );
                                let _ =
                                    task.reply.send((id, Frame::seal(FrameKind::Nack, &[])));
                                continue;
                            }
                            if let Err(e) = u.apply(&mut reference) {
                                // the replica is now an unknown number of
                                // versions behind whatever the leader will
                                // dispatch next — poison it so every delta
                                // is rejected until a dense snapshot
                                // resyncs us
                                reference.clear();
                                codec.reset_residual();
                                log::error!("worker {id}: broadcast rejected: {e:#}");
                                let _ =
                                    task.reply.send((id, Frame::seal(FrameKind::Nack, &[])));
                                continue;
                            }
                            reference.clone()
                        }
                    };
                    if let Err(e) = driver.load_params(&mut store, device_params) {
                        log::error!("worker {id}: broadcast rejected: {e:#}");
                        continue;
                    }
                    // crash injection: the device dies after exactly k
                    // local steps — it still consumed k batches and its
                    // device momenta advanced, but nothing is synced or
                    // reported. Silence is the only leader-visible signal.
                    let crash_at = plan.crash_point(task.round, id, task.local_steps);
                    let steps_to_run = crash_at.unwrap_or(task.local_steps);
                    let mut losses = 0.0;
                    let mut spars = 0.0;
                    let mut ok = true;
                    for _ in 0..steps_to_run {
                        let batch = batcher.next_batch();
                        batches_drawn += 1;
                        match driver.step(
                            &mut store,
                            &batch,
                            cfg.lr as f32,
                            cfg.momentum as f32,
                        ) {
                            Ok(out) => {
                                losses += out.loss as f64;
                                spars += crate::util::stats::mean(&out.sparsity);
                            }
                            Err(e) => {
                                log::error!("worker {id}: step failed: {e:#}");
                                ok = false;
                                break;
                            }
                        }
                    }
                    if crash_at.is_some() {
                        // simulated reboot: whatever the device held is
                        // written off; poison the replica so the next
                        // dispatch dense-resyncs it
                        reference.clear();
                        codec.reset_residual();
                        continue;
                    }
                    // round boundary: the one place the resident path
                    // downloads the O(model) state
                    if ok {
                        if let Err(e) = driver.sync_to_host(&mut store) {
                            log::error!("worker {id}: host sync failed: {e:#}");
                            ok = false;
                        }
                    }
                    if !ok {
                        // drop the reply sender: the leader aggregates
                        // the reports that did arrive and records this
                        // worker as dropped for the round
                        continue;
                    }
                    // uplink: dense snapshot or pruned delta vs reference
                    let update = match codec.mode() {
                        CommMode::Dense => ModelUpdate::Dense(store.params.clone()),
                        _ => {
                            let mut rng = uplink_rng.fold_in(task.round as u64);
                            match codec.encode(&store.params, &reference, &mut rng) {
                                Ok(u) => u,
                                Err(e) => {
                                    log::error!("worker {id}: uplink encode failed: {e:#}");
                                    continue;
                                }
                            }
                        }
                    };
                    let n = task.local_steps.max(1) as f64;
                    // straggling: either genuinely hold the round on the
                    // wall clock (sleep injection — the reply, and with
                    // it the leader's barrier, waits) or only report the
                    // inflated simulated time
                    let sim_secs = if task.sleep && task.slowdown > 1.0 {
                        let work = t0.elapsed();
                        std::thread::sleep(work.mul_f64(task.slowdown - 1.0));
                        t0.elapsed().as_secs_f64()
                    } else {
                        t0.elapsed().as_secs_f64() * task.slowdown
                    };
                    let report = WorkerReport {
                        worker_id: id,
                        round: task.round,
                        base_version: task.version,
                        update,
                        examples: shard_n,
                        mean_loss: losses / n,
                        mean_sparsity: spars / n,
                        sim_secs,
                        transfer: driver.transfer_stats(),
                    };
                    let mut frame = Frame::seal(FrameKind::Report, &report.encode());
                    // transport-site delay: the link is slow, not wrong —
                    // the report arrives late but intact, same injection
                    // point on both transports (a real sleep, so the
                    // frame genuinely races the other workers' sends)
                    let lag = plan.net_delay_ms(task.round, id);
                    if lag > 0 {
                        std::thread::sleep(Duration::from_millis(lag));
                    }
                    // uplink wire faults fire at send — after the seal,
                    // exactly where a radio would damage the bytes
                    match plan.uplink(task.round, id) {
                        Some(f @ (WireFault::Corrupt | WireFault::Truncate)) => {
                            plan.mutate(&mut frame, f, task.round, id, 0);
                            let _ = task.reply.send((id, frame));
                        }
                        Some(WireFault::Duplicate) => {
                            let _ = task.reply.send((id, frame.clone()));
                            let _ = task.reply.send((id, frame));
                        }
                        Some(WireFault::Reorder) => {
                            // a real delay, so the frame genuinely races
                            // the other workers' sends
                            let ms = plan.reorder_delay_ms(task.round, id);
                            std::thread::sleep(Duration::from_millis(ms));
                            let _ = task.reply.send((id, frame));
                        }
                        None => {
                            let _ = task.reply.send((id, frame));
                        }
                    }
                }
            })
            .map_err(|e| anyhow!("spawning worker {id}: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker {id} died during startup"))?
            .map_err(|e| e.context(format!("worker {id} failed to compile artifact")))?;
        Ok(Self {
            id,
            tx,
            join: Some(join),
        })
    }

    pub fn submit(&self, task: WorkerTask) -> Result<()> {
        self.tx
            .send(Msg::Task(task))
            .map_err(|_| anyhow!("worker {} channel closed", self.id))
    }

    /// Round-boundary snapshot for the run store: syncs the worker's
    /// device state down and returns its cross-round state. Blocks
    /// behind any queued tasks (the snapshot is taken *between* rounds).
    pub fn capture(&self) -> Result<WorkerSnapshot> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Capture(reply))
            .map_err(|_| anyhow!("worker {} channel closed", self.id))?;
        rx.recv()
            .map_err(|_| anyhow!("worker {}: capture failed (state not syncable)", self.id))
    }

    /// Install a persisted snapshot (resume). Queued ahead of the first
    /// task by mpsc ordering; errors propagate through the ack.
    pub fn restore(&self, snap: WorkerSnapshot) -> Result<()> {
        let (ack, rx) = mpsc::channel();
        self.tx
            .send(Msg::Restore {
                snap: Box::new(snap),
                ack,
            })
            .map_err(|_| anyhow!("worker {} channel closed", self.id))?;
        rx.recv()
            .map_err(|_| anyhow!("worker {} died during restore", self.id))?
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The leader-facing surface every worker implementation speaks: sealed
/// [`Frame`] tasks in, sealed report frames out on `task.reply`, and
/// snapshot capture/restore at round boundaries. Two implementations
/// exist — the thread-per-device [`WorkerHandle`] (real PJRT training)
/// and the in-process [`LiteWorker`] (fleet-scale simulation) — and a
/// driver written against this trait runs unchanged on either.
pub trait Worker {
    fn id(&self) -> usize;
    /// Hand the worker one round's work order. The report lands on
    /// `task.reply` — asynchronously for a threaded worker, before
    /// `submit` returns for a [`LiteWorker`].
    fn submit(&mut self, task: WorkerTask) -> Result<()>;
    /// Round-boundary snapshot of the worker's cross-round state.
    fn capture(&mut self) -> Result<WorkerSnapshot>;
    /// Install a persisted snapshot (resume).
    fn restore(&mut self, snap: WorkerSnapshot) -> Result<()>;
    fn shutdown(self)
    where
        Self: Sized;
}

impl Worker for WorkerHandle {
    fn id(&self) -> usize {
        self.id
    }
    fn submit(&mut self, task: WorkerTask) -> Result<()> {
        WorkerHandle::submit(self, task)
    }
    fn capture(&mut self) -> Result<WorkerSnapshot> {
        WorkerHandle::capture(self)
    }
    fn restore(&mut self, snap: WorkerSnapshot) -> Result<()> {
        WorkerHandle::restore(self, snap)
    }
    fn shutdown(self) {
        WorkerHandle::shutdown(self)
    }
}

/// Nominal shard size a [`LiteWorker`] reports — the fedavg weight every
/// lite worker folds with (uniform fleet).
const LITE_SHARD_N: usize = 64;

/// A memory-bounded stand-in for a full edge worker: no thread, no PJRT
/// client, no data shard — just the *protocol* state machine, so one
/// process can host 100k of them. Everything wire-facing is the real
/// thing: downlink frames are opened and validated by the same
/// [`Frame`] checks, bad frames poison the replica and nack, deltas and
/// chains apply through [`ModelUpdate::apply`], and the uplink goes
/// through the worker's own error-feedback [`DeltaCodec`] with the real
/// per-round RNG derivation (`seed ^ 0x5EED_C0DE`, folded by id and
/// round). Only the training itself is synthetic: instead of running
/// local steps, the worker perturbs its replica with a deterministic
/// pruned-gradient-shaped drift (a pure function of `(seed, id, round)`,
/// so capture/restore reproduces the trajectory exactly like the real
/// worker's).
///
/// Memory: the reference replica is `Arc`-shared — a fleet resynced to
/// the same model version via [`LiteWorker::resync_shared`] holds ONE
/// copy of those params, and a delta downlink clones on write. Live
/// O(model) state (materialized params + codec residual) therefore
/// scales with the workers actually *sampled*, not the fleet size.
pub struct LiteWorker {
    id: usize,
    /// downlink-advanced reference replica, shared across same-version
    /// workers (empty = never synced / poisoned → nack until dense resync)
    reference: std::sync::Arc<Vec<Tensor>>,
    codec: DeltaCodec,
    /// uplink keep-rate (drives the synthetic drift's nonzero fraction)
    rate: f64,
    batches_drawn: u64,
    /// uplink codec RNG base — same derivation as the threaded worker
    uplink_rng: Rng,
    /// synthetic-drift RNG base (lite-only stream, disjoint from every
    /// leader and worker stream)
    drift_rng: Rng,
}

impl LiteWorker {
    pub fn new(id: usize, seed: u64, comm: CommSetup) -> Self {
        Self {
            id,
            reference: std::sync::Arc::new(Vec::new()),
            codec: DeltaCodec::with_pruner(comm.mode, comm.rate, comm.pruner)
                .with_quant(comm.quant),
            rate: comm.rate,
            batches_drawn: 0,
            uplink_rng: Rng::new(seed ^ 0x5EED_C0DE).fold_in(id as u64),
            drift_rng: Rng::new(seed ^ 0xF1EE7).fold_in(id as u64),
        }
    }

    /// Dense-resync to a cached model version *without copying*: the
    /// fleet driver keeps one `Arc<Vec<Tensor>>` per retained version
    /// and hands every same-version worker the same allocation. The
    /// error-feedback residual resets exactly as on a dense downlink.
    pub fn resync_shared(&mut self, params: std::sync::Arc<Vec<Tensor>>) {
        self.codec.reset_residual();
        self.reference = params;
    }

    /// True once this worker holds a usable replica (dense-synced and
    /// not poisoned since).
    pub fn synced(&self) -> bool {
        !self.reference.is_empty()
    }

    fn poison(&mut self) {
        self.reference = std::sync::Arc::new(Vec::new());
        self.codec.reset_residual();
    }

    fn nack(&self, task: &WorkerTask) {
        let _ = task.reply.send((self.id, Frame::seal(FrameKind::Nack, &[])));
    }
}

impl Worker for LiteWorker {
    fn id(&self) -> usize {
        self.id
    }

    /// The whole round, synchronously: open the downlink seal, advance
    /// the replica, drift, encode the uplink, reply. Mirrors the
    /// threaded worker's control flow decision-for-decision (nack on bad
    /// frame / delta-before-snapshot / failed apply; dense resets the
    /// residual; chains replay without a reset).
    fn submit(&mut self, task: WorkerTask) -> Result<()> {
        let update = match task
            .frame
            .open()
            .and_then(|(kind, payload)| {
                if kind != FrameKind::Update {
                    bail!("downlink frame kind {kind:?}, wanted Update");
                }
                decode_update(payload)
            }) {
            Ok(u) => u,
            Err(_) => {
                self.poison();
                self.nack(&task);
                return Ok(());
            }
        };
        match update {
            ModelUpdate::Dense(p) => {
                self.codec.reset_residual();
                self.reference = std::sync::Arc::new(p);
            }
            u @ (ModelUpdate::Delta(_) | ModelUpdate::Chain(_)) => {
                if self.reference.is_empty() {
                    self.nack(&task);
                    return Ok(());
                }
                // clone-on-write: a shared replica is copied out of the
                // version cache only when this worker actually diverges
                let params = std::sync::Arc::make_mut(&mut self.reference);
                if u.apply(params).is_err() {
                    self.poison();
                    self.nack(&task);
                    return Ok(());
                }
            }
        }
        // synthetic local training: a pruned-gradient-shaped drift —
        // only a codec-rate-sized fraction of coordinates move, each by
        // a small uniform step. Pure function of (seed, id, round).
        let keep = match self.codec.mode() {
            CommMode::Dense => 1.0,
            _ => self.rate.clamp(0.01, 1.0),
        };
        let mut rng = self.drift_rng.fold_in(task.round as u64);
        let mut local: Vec<Tensor> = (*self.reference).clone();
        for t in &mut local {
            for v in t.data_mut() {
                if rng.uniform() < keep {
                    *v += rng.uniform_in(-0.01, 0.01) as f32;
                }
            }
        }
        self.batches_drawn += task.local_steps as u64;
        let update = match self.codec.mode() {
            CommMode::Dense => ModelUpdate::Dense(local),
            _ => {
                let mut rng = self.uplink_rng.fold_in(task.round as u64);
                match self.codec.encode(&local, &self.reference, &mut rng) {
                    Ok(u) => u,
                    Err(_) => {
                        self.nack(&task);
                        return Ok(());
                    }
                }
            }
        };
        let report = WorkerReport {
            worker_id: self.id,
            round: task.round,
            base_version: task.version,
            update,
            examples: LITE_SHARD_N,
            mean_loss: 1.0 / (1.0 + task.round as f64),
            mean_sparsity: 1.0 - keep,
            sim_secs: task.slowdown * task.local_steps as f64 * 1e-3,
            transfer: TransferStats {
                state_up: 0,
                state_down: 0,
                batch_up: 0,
                metrics_down: 0,
                steps: task.local_steps as u64,
                evals: 0,
            },
        };
        let _ = task
            .reply
            .send((self.id, Frame::seal(FrameKind::Report, &report.encode())));
        Ok(())
    }

    fn capture(&mut self) -> Result<WorkerSnapshot> {
        Ok(WorkerSnapshot {
            reference: (*self.reference).clone(),
            residual: self.codec.residual().to_vec(),
            batches_drawn: self.batches_drawn,
            // no device tier: nothing survives a round outside the
            // replica + residual
            momenta: Vec::new(),
            step: 0,
        })
    }

    fn restore(&mut self, snap: WorkerSnapshot) -> Result<()> {
        self.reference = std::sync::Arc::new(snap.reference);
        self.codec.set_residual(snap.residual);
        self.batches_drawn = snap.batches_drawn;
        Ok(())
    }

    fn shutdown(self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::wire::{SignTensor, SparseTensor, TensorUpdate};

    fn sample_report(update: ModelUpdate) -> WorkerReport {
        WorkerReport {
            worker_id: 3,
            round: 7,
            base_version: 41,
            update,
            examples: 512,
            mean_loss: 1.25,
            mean_sparsity: 0.875,
            sim_secs: 0.03125,
            transfer: TransferStats {
                state_up: 1,
                state_down: 2,
                batch_up: 3,
                metrics_down: 4,
                steps: 5,
                evals: 6,
            },
        }
    }

    #[test]
    fn report_roundtrips_through_the_wire_encoding() {
        let pruned = [0.0f32, 2.0, 0.0, -1.5];
        for update in [
            ModelUpdate::Dense(vec![Tensor::new(vec![2, 2], vec![1.0, -2.0, 0.5, 4.0])]),
            ModelUpdate::Delta(vec![
                TensorUpdate::Sparse(SparseTensor::encode(&pruned)),
                TensorUpdate::Sign(SignTensor::encode(&pruned)),
            ]),
        ] {
            let r = sample_report(update);
            let back = WorkerReport::decode(&r.encode()).unwrap();
            assert_eq!(back.worker_id, r.worker_id);
            assert_eq!(back.round, r.round);
            assert_eq!(back.base_version, r.base_version);
            assert_eq!(back.update, r.update);
            assert_eq!(back.examples, r.examples);
            assert_eq!(back.mean_loss, r.mean_loss);
            assert_eq!(back.mean_sparsity, r.mean_sparsity);
            assert_eq!(back.sim_secs, r.sim_secs);
            assert_eq!(back.transfer, r.transfer);
        }
    }

    fn lite_setup() -> CommSetup {
        CommSetup {
            mode: CommMode::Pruned,
            rate: 0.3,
            pruner: CommPruner::Stochastic,
            quant: WireQuant::Off,
        }
    }

    fn lite_round(
        w: &mut LiteWorker,
        round: usize,
        version: u64,
        update: &ModelUpdate,
    ) -> Frame {
        let (tx, rx) = mpsc::channel();
        let frame = Frame::seal(FrameKind::Update, &crate::comm::envelope::encode_update(update));
        Worker::submit(
            w,
            WorkerTask {
                round,
                version,
                frame,
                local_steps: 3,
                slowdown: 1.0,
                sleep: false,
                reply: tx,
            },
        )
        .unwrap();
        let (id, frame) = rx.recv().unwrap();
        assert_eq!(id, w.id());
        frame
    }

    fn params() -> Vec<Tensor> {
        vec![Tensor::new(vec![6], vec![0.5, -0.25, 1.0, 0.0, -1.5, 2.0])]
    }

    #[test]
    fn lite_worker_speaks_the_wire_protocol() {
        let mut w = LiteWorker::new(3, 7, lite_setup());
        assert!(!w.synced());
        let frame = lite_round(&mut w, 0, 1, &ModelUpdate::Dense(params()));
        let (kind, payload) = frame.open().unwrap();
        assert_eq!(kind, FrameKind::Report);
        let report = WorkerReport::decode(payload).unwrap();
        assert_eq!(report.worker_id, 3);
        assert_eq!(report.round, 0);
        assert_eq!(report.base_version, 1);
        assert_eq!(report.examples, LITE_SHARD_N);
        assert_eq!(report.transfer.steps, 3);
        // pruned mode uplinks a delta vs the replica, applicable in place
        assert!(matches!(report.update, ModelUpdate::Delta(_)));
        let mut replica = params();
        report.update.apply(&mut replica).unwrap();
        assert!(replica[0].data().iter().all(|v| v.is_finite()));
        // a pruned *downlink* delta advances the same replica state
        let delta = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[
            0.0, 0.1, 0.0, 0.0, -0.2, 0.0,
        ]))]);
        let frame = lite_round(&mut w, 1, 2, &delta);
        let (_, payload) = frame.open().unwrap();
        let r2 = WorkerReport::decode(payload).unwrap();
        assert_eq!(r2.base_version, 2);
        assert!(w.synced());
    }

    #[test]
    fn lite_worker_trajectory_is_deterministic_and_restorable() {
        let setup = lite_setup();
        let delta = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[
            0.0, 0.1, 0.0, 0.0, -0.2, 0.0,
        ]))]);
        let mut a = LiteWorker::new(5, 11, setup);
        let mut b = LiteWorker::new(5, 11, setup);
        let fa = lite_round(&mut a, 0, 1, &ModelUpdate::Dense(params()));
        let fb = lite_round(&mut b, 0, 1, &ModelUpdate::Dense(params()));
        assert_eq!(fa.as_bytes(), fb.as_bytes(), "same (seed, id) diverged");
        // capture at the round boundary, restore into a fresh worker,
        // and the continuation is bit-identical to the uninterrupted one
        let snap = Worker::capture(&mut a).unwrap();
        let mut c = LiteWorker::new(5, 11, setup);
        Worker::restore(&mut c, snap).unwrap();
        let fa = lite_round(&mut a, 1, 2, &delta);
        let fc = lite_round(&mut c, 1, 2, &delta);
        assert_eq!(fa.as_bytes(), fc.as_bytes(), "restore broke the trajectory");
        // a different worker id yields a different uplink
        let mut d = LiteWorker::new(6, 11, setup);
        let fd = lite_round(&mut d, 0, 1, &ModelUpdate::Dense(params()));
        assert_ne!(fb.as_bytes(), fd.as_bytes());
    }

    #[test]
    fn lite_worker_nacks_and_poisons_like_the_real_one() {
        let mut w = LiteWorker::new(0, 3, lite_setup());
        let delta = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[
            0.1, 0.0, 0.0, 0.0, 0.0, 0.0,
        ]))]);
        // delta before any snapshot: nothing to apply it to
        let frame = lite_round(&mut w, 0, 1, &delta);
        assert_eq!(frame.open().unwrap().0, FrameKind::Nack);
        // sync, then corrupt the next downlink in flight — the seal
        // catches it, the replica poisons, and a valid delta still nacks
        // until a dense resync
        lite_round(&mut w, 1, 2, &ModelUpdate::Dense(params()));
        assert!(w.synced());
        let (tx, rx) = mpsc::channel();
        let mut bad = Frame::seal(
            FrameKind::Update,
            &crate::comm::envelope::encode_update(&delta),
        );
        let n = bad.as_bytes().len();
        bad.bytes_mut()[n / 2] ^= 0x40;
        Worker::submit(
            &mut w,
            WorkerTask {
                round: 2,
                version: 3,
                frame: bad,
                local_steps: 3,
                slowdown: 1.0,
                sleep: false,
                reply: tx,
            },
        )
        .unwrap();
        assert_eq!(rx.recv().unwrap().1.open().unwrap().0, FrameKind::Nack);
        assert!(!w.synced());
        let frame = lite_round(&mut w, 3, 3, &delta);
        assert_eq!(frame.open().unwrap().0, FrameKind::Nack);
        let frame = lite_round(&mut w, 4, 4, &ModelUpdate::Dense(params()));
        assert_eq!(frame.open().unwrap().0, FrameKind::Report);
    }

    #[test]
    fn shared_replicas_clone_on_write() {
        let cache = std::sync::Arc::new(params());
        let mut a = LiteWorker::new(0, 9, lite_setup());
        let mut b = LiteWorker::new(1, 9, lite_setup());
        a.resync_shared(cache.clone());
        b.resync_shared(cache.clone());
        // one allocation for the whole same-version cohort
        assert_eq!(std::sync::Arc::strong_count(&cache), 3);
        assert!(a.synced() && b.synced());
        // a delta downlink makes worker `a` diverge: it clones out of
        // the cache, the cache itself stays untouched
        let delta = ModelUpdate::Delta(vec![TensorUpdate::Sparse(SparseTensor::encode(&[
            0.3, 0.0, 0.0, 0.0, 0.0, 0.0,
        ]))]);
        lite_round(&mut a, 0, 1, &delta);
        assert_eq!(std::sync::Arc::strong_count(&cache), 2);
        assert_eq!(cache[0].data(), params()[0].data());
    }

    #[test]
    fn report_decode_rejects_damage() {
        let r = sample_report(ModelUpdate::Dense(vec![Tensor::new(vec![2], vec![1.0, 2.0])]));
        let bytes = r.encode();
        // truncation at any scalar boundary errors cleanly
        assert!(WorkerReport::decode(&bytes[..bytes.len() - 1]).is_err());
        assert!(WorkerReport::decode(&bytes[..10]).is_err());
        // trailing garbage is a schema violation
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(WorkerReport::decode(&padded).is_err());
        // NaN scalars decode honestly — the fold boundary rejects them
        let mut nan = r.clone();
        nan.mean_loss = f64::NAN;
        let back = WorkerReport::decode(&nan.encode()).unwrap();
        assert!(back.mean_loss.is_nan());
    }
}
