//! Federated edge-training coordinator — the L3 systems contribution.
//!
//! The paper motivates EfficientGrad with federated learning: edge devices
//! must *train locally* and ship model updates, not data (§1). This module
//! implements that deployment: a leader drives rounds of local training on
//! N simulated edge workers (std threads, each with its own data shard and
//! PJRT executables), aggregates with FedAvg, and accounts communication
//! and (via the accel simulator's energy model) on-device training energy
//! per round.
//!
//! Worker execution is genuinely concurrent: the `xla` handles are not
//! `Send`, so each worker thread brings up its own PJRT client and
//! compiles its own executable — exactly like a fleet of edge devices,
//! each with its own accelerator and its own ParamStore replica.
//!
//! Transfer model: with the default resident step backend
//! (`runtime::resident`), each worker's host↔device traffic is one
//! params upload + one params/momenta download *per round*, not per
//! step; the leader's network accounting (`RoundReport::upload_bytes`)
//! is unchanged — residency moves bytes off the device bus, the
//! federated uplink was already per-round. Each round now also carries
//! the device-bus ledger end-to-end: every worker reports its per-round
//! [`TransferStats`], the leader sums them next to the FedAvg aggregate
//! ([`RoundReport::device_transfer`]) and accounts its own eval sweep
//! ([`RoundReport::leader_eval_transfer`]) — with resident eval the
//! leader uploads the new global params once per round instead of once
//! per test batch. Formulas: `docs/TRANSFER_MODEL.md`.

pub mod fedavg;
pub mod worker;

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::FedConfig;
use crate::data::synthetic::{generate, SynthConfig};
use crate::data::Dataset;
use crate::manifest::Manifest;
use crate::params::ParamStore;
use crate::runtime::{Runtime, TransferStats};
use crate::util::rng::Rng;

pub use fedavg::{fedavg, weighted_fedavg};
pub use worker::{WorkerHandle, WorkerReport, WorkerTask};

/// Outcome of one federated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// round index (0-based)
    pub round: usize,
    /// mean of the workers' mean local-step losses
    pub mean_loss: f64,
    /// mean realized gradient sparsity across workers
    pub mean_sparsity: f64,
    /// bytes shipped up (worker->leader) this round
    pub upload_bytes: u64,
    /// bytes broadcast down (leader->worker) this round
    pub download_bytes: u64,
    /// global-model accuracy on the leader's test set after aggregation
    pub eval_acc: f64,
    /// leader-measured wall time for the whole round
    pub wall_secs: f64,
    /// per-worker simulated wall time (stragglers show here)
    pub worker_secs: Vec<f64>,
    /// per-worker host↔device ledgers for the round, sorted by worker id
    /// (broadcast upload + local steps + round-boundary sync)
    pub worker_transfer: Vec<TransferStats>,
    /// sum of `worker_transfer` — the round's fleet-wide device-bus
    /// traffic, aggregated alongside the FedAvg params
    pub device_transfer: TransferStats,
    /// the leader's own eval-sweep ledger for this round
    pub leader_eval_transfer: TransferStats,
}

impl RoundReport {
    /// Every device-bus byte this round moved, fleet + leader eval.
    pub fn device_bytes(&self) -> u64 {
        self.device_transfer.total_bytes() + self.leader_eval_transfer.total_bytes()
    }
}

/// Full run summary.
#[derive(Clone, Debug)]
pub struct FedSummary {
    /// per-round reports in order
    pub rounds: Vec<RoundReport>,
    /// last round's eval accuracy
    pub final_acc: f64,
    /// total worker->leader network bytes across the run
    pub total_upload_bytes: u64,
    /// total leader->worker network bytes across the run
    pub total_download_bytes: u64,
    /// total device-bus ledger across the run (all workers' rounds plus
    /// the leader's eval sweeps)
    pub total_device_transfer: TransferStats,
}

/// The federated leader.
pub struct Leader {
    cfg: FedConfig,
    global: ParamStore,
    workers: Vec<WorkerHandle>,
    test: Dataset,
    eval: crate::runtime::exec::EvalState,
    model_batch: usize,
}

impl Leader {
    /// Build leader + workers. Shards the synthetic dataset across
    /// workers (IID or label-skewed per config).
    pub fn new(rt: &Runtime, manifest: &Manifest, cfg: FedConfig) -> Result<Self> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        let model = manifest.model(&cfg.train.model)?.clone();
        let full = generate(&SynthConfig {
            n: cfg.train.train_examples + cfg.train.test_examples,
            difficulty: cfg.train.difficulty as f32,
            seed: cfg.train.seed,
            ..Default::default()
        });
        let (train, test) = full.split(cfg.train.train_examples);
        let shards = train.shard(cfg.workers, cfg.iid, cfg.train.seed ^ 0x5A4D);

        let tag = format!("train_{}", cfg.train.mode);
        let art = model.artifact(&tag).with_context(|| {
            format!("mode {:?} not exported for {}", cfg.train.mode, model.name)
        })?;
        let eval_exe = rt.load(model.artifact("fwd")?)?;
        // resident eval uploads the post-FedAvg params once per round
        // (fingerprint cache) instead of once per test batch
        let eval =
            crate::runtime::exec::EvalState::new(rt, eval_exe, &model, cfg.train.eval_residency)?;

        let workers = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                WorkerHandle::spawn(i, shard, art.clone(), &model, cfg.train.clone())
            })
            .collect::<Result<Vec<_>>>()?;

        let global = ParamStore::init(&model, cfg.train.seed);
        Ok(Self {
            cfg,
            global,
            workers,
            test,
            eval,
            model_batch: model.batch,
        })
    }

    /// Bytes of one model broadcast (params only; momenta stay local,
    /// feedback B is derived from the shared seed — a real EfficientGrad
    /// deployment never ships B).
    fn model_bytes(&self) -> u64 {
        (self.global.param_elements() * 4) as u64
    }

    /// Run all rounds.
    pub fn run(&mut self) -> Result<FedSummary> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        let mut straggler_rng = Rng::new(self.cfg.train.seed ^ 0x57AA);
        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            // broadcast current global params
            let (tx, rx) = mpsc::channel::<WorkerReport>();
            let mut dispatched = 0usize;
            for w in &self.workers {
                let slowdown = if straggler_rng.uniform() < self.cfg.straggler_prob {
                    self.cfg.straggler_slowdown
                } else {
                    1.0
                };
                w.submit(WorkerTask {
                    round,
                    params: self.global.params.clone(),
                    local_steps: self.cfg.local_steps,
                    slowdown,
                    reply: tx.clone(),
                })?;
                dispatched += 1;
            }
            drop(tx);

            // gather
            let mut reports = Vec::with_capacity(dispatched);
            for _ in 0..dispatched {
                reports.push(rx.recv().context("worker died mid-round")?);
            }
            reports.sort_by_key(|r| r.worker_id);

            // aggregate (examples-weighted FedAvg)
            let weights: Vec<f64> = reports.iter().map(|r| r.examples as f64).collect();
            let updates: Vec<&Vec<crate::tensor::Tensor>> =
                reports.iter().map(|r| &r.params).collect();
            self.global.params = weighted_fedavg(&updates, &weights)?;

            let mean_loss = reports.iter().map(|r| r.mean_loss).sum::<f64>()
                / reports.len() as f64;
            let mean_sparsity = reports.iter().map(|r| r.mean_sparsity).sum::<f64>()
                / reports.len() as f64;
            // per-worker device-bus ledgers, aggregated like the params
            let worker_transfer: Vec<TransferStats> =
                reports.iter().map(|r| r.transfer).collect();
            let device_transfer = worker_transfer
                .iter()
                .fold(TransferStats::default(), |acc, &t| acc + t);
            self.eval.reset_transfer_stats();
            let eval_acc = self.evaluate()?;
            let leader_eval_transfer = self.eval.transfer_stats();
            let report = RoundReport {
                round,
                mean_loss,
                mean_sparsity,
                upload_bytes: self.model_bytes() * dispatched as u64,
                download_bytes: self.model_bytes() * dispatched as u64,
                eval_acc,
                wall_secs: t0.elapsed().as_secs_f64(),
                worker_secs: reports.iter().map(|r| r.sim_secs).collect(),
                worker_transfer,
                device_transfer,
                leader_eval_transfer,
            };
            log::info!(
                "round {round:3} loss {mean_loss:.4} acc {eval_acc:.4} sparsity {mean_sparsity:.3} \
                 device {:.1} KB ({:.2}s)",
                report.device_bytes() as f64 / 1e3,
                report.wall_secs
            );
            rounds.push(report);
        }
        let final_acc = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
        let total_upload_bytes = rounds.iter().map(|r| r.upload_bytes).sum();
        let total_download_bytes = rounds.iter().map(|r| r.download_bytes).sum();
        let total_device_transfer = rounds.iter().fold(TransferStats::default(), |acc, r| {
            acc + r.device_transfer + r.leader_eval_transfer
        });
        Ok(FedSummary {
            rounds,
            final_acc,
            total_upload_bytes,
            total_download_bytes,
            total_device_transfer,
        })
    }

    fn evaluate(&self) -> Result<f64> {
        let mut correct = 0.0;
        let mut total = 0usize;
        for idx in crate::data::batcher::eval_batches(&self.test, self.model_batch) {
            let batch = self.test.gather(&idx);
            correct += self.eval.accuracy(&self.global, &batch)? * idx.len() as f64;
            total += idx.len();
        }
        if total == 0 {
            bail!("test set smaller than one batch");
        }
        Ok(correct / total as f64)
    }

    /// Graceful shutdown (joins worker threads).
    pub fn shutdown(self) {
        for w in self.workers {
            w.shutdown();
        }
    }
}
