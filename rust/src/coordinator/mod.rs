//! Federated edge-training coordinator — the L3 systems contribution.
//!
//! The paper motivates EfficientGrad with federated learning: edge devices
//! must *train locally* and ship model updates, not data (§1). This module
//! implements that deployment: a leader drives rounds of local training on
//! N simulated edge workers (std threads, each with its own data shard and
//! PJRT executables), aggregates with FedAvg, and accounts communication
//! and (via the accel simulator's energy model) on-device training energy
//! per round.
//!
//! Worker execution is genuinely concurrent: the `xla` handles are not
//! `Send`, so each worker thread brings up its own PJRT client and
//! compiles its own executable — exactly like a fleet of edge devices,
//! each with its own accelerator and its own ParamStore replica.
//!
//! Transfer model: with the default resident step backend
//! (`runtime::resident`), each worker's host↔device traffic is one
//! params upload + one params/momenta download *per round*, not per
//! step. Each round carries the device-bus ledger end-to-end: every
//! worker reports its per-round [`TransferStats`], the leader sums them
//! next to the FedAvg aggregate ([`RoundReport::device_transfer`]) and
//! accounts its own eval sweep ([`RoundReport::leader_eval_transfer`]).
//!
//! The *network* tier ([`RoundReport::upload_bytes`] /
//! [`RoundReport::download_bytes`]) is measured from the actual wire
//! messages ([`crate::comm`]): with `comm = dense` both directions ship
//! full `4·P` snapshots (the legacy exchange, bit for bit); with
//! `comm = pruned|sign` workers uplink error-feedback pruned deltas, the
//! leader folds them into the global params in O(nnz)
//! ([`weighted_sparse_fedavg`]) and downlinks the global delta through
//! the same codec — dense snapshots remain only for the first round and
//! for resyncing workers that missed a downlink. Rounds degrade
//! gracefully: a worker that goes silent (dropout injection, dispatch
//! failure, failed step) is recorded in [`RoundReport::dropped`] and
//! FedAvg re-weights over the reports that did arrive. Formulas:
//! `docs/TRANSFER_MODEL.md`.

pub mod fedavg;
pub mod worker;

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::accel::energy::{EnergyTable, LinkEnergy};
use crate::comm::{DeltaCodec, ModelUpdate, TensorUpdate};
use crate::config::{CommMode, FedConfig};
use crate::data::synthetic::{generate, SynthConfig};
use crate::data::Dataset;
use crate::manifest::Manifest;
use crate::params::ParamStore;
use crate::runtime::{Runtime, TransferStats};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub use fedavg::{fedavg, weighted_fedavg, weighted_sparse_fedavg};
pub use worker::{WorkerHandle, WorkerReport, WorkerTask};

/// Outcome of one federated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// round index (0-based)
    pub round: usize,
    /// mean of the workers' mean local-step losses (0.0 on a round where
    /// every worker dropped — see `dropped`/`worker_transfer`)
    pub mean_loss: f64,
    /// mean realized gradient sparsity across workers
    pub mean_sparsity: f64,
    /// measured wire bytes shipped up (worker->leader) this round
    pub upload_bytes: u64,
    /// measured wire bytes broadcast down (leader->worker) this round
    pub download_bytes: u64,
    /// workers the leader dispatched a task to this round
    pub dispatched: usize,
    /// worker ids that missed the round (offline at dispatch, dispatch
    /// failure, or went silent mid-round); FedAvg re-weighted over the
    /// rest, and offline workers resync from a dense snapshot next round
    pub dropped: Vec<usize>,
    /// downlink payloads that were dense snapshots (first round, resync,
    /// or `comm = dense`); the rest were pruned deltas
    pub dense_downlinks: usize,
    /// surviving (nonzero) delta coordinates across all uplink messages
    /// (0 in dense mode — every element travels)
    pub uplink_survivors: u64,
    /// surviving delta coordinates summed across downlink payloads
    pub downlink_survivors: u64,
    /// global-model accuracy on the leader's test set after aggregation
    pub eval_acc: f64,
    /// leader-measured wall time for the whole round
    pub wall_secs: f64,
    /// per-worker simulated wall time (stragglers show here)
    pub worker_secs: Vec<f64>,
    /// per-worker host↔device ledgers for the round, sorted by worker id
    /// (broadcast upload + local steps + round-boundary sync)
    pub worker_transfer: Vec<TransferStats>,
    /// sum of `worker_transfer` — the round's fleet-wide device-bus
    /// traffic, aggregated alongside the FedAvg params
    pub device_transfer: TransferStats,
    /// the leader's own eval-sweep ledger for this round
    pub leader_eval_transfer: TransferStats,
}

impl RoundReport {
    /// Every device-bus byte this round moved, fleet + leader eval.
    pub fn device_bytes(&self) -> u64 {
        self.device_transfer.total_bytes() + self.leader_eval_transfer.total_bytes()
    }

    /// Every network byte this round moved, both directions.
    pub fn network_bytes(&self) -> u64 {
        self.upload_bytes + self.download_bytes
    }

    /// Simulated Joules of this round's *measured* device-bus traffic at
    /// `table`'s DRAM energy point — the ledger feeds the energy model,
    /// not an analytic byte estimate.
    pub fn device_joules(&self, table: &EnergyTable) -> f64 {
        table.bus_joules(self.device_bytes())
    }

    /// Simulated Joules of this round's measured network traffic over
    /// `link` (reported next to [`RoundReport::device_joules`]).
    pub fn network_joules(&self, link: &LinkEnergy) -> f64 {
        link.joules(self.network_bytes())
    }
}

/// Full run summary.
#[derive(Clone, Debug)]
pub struct FedSummary {
    /// per-round reports in order
    pub rounds: Vec<RoundReport>,
    /// last round's eval accuracy
    pub final_acc: f64,
    /// total worker->leader network bytes across the run
    pub total_upload_bytes: u64,
    /// total leader->worker network bytes across the run
    pub total_download_bytes: u64,
    /// total device-bus ledger across the run (all workers' rounds plus
    /// the leader's eval sweeps)
    pub total_device_transfer: TransferStats,
}

/// The federated leader.
pub struct Leader {
    cfg: FedConfig,
    global: ParamStore,
    /// the params every in-sync worker holds — advanced only by applying
    /// the same downlink updates the workers apply, so leader and worker
    /// replicas stay bit-identical. Compressed modes only; `dense` ships
    /// `global.params` snapshots directly.
    reference: Vec<Tensor>,
    /// per-worker: has it received every downlink so far? A worker that
    /// misses one gets a dense snapshot (and is marked in-sync again).
    in_sync: Vec<bool>,
    /// the pruned global delta computed at the end of the previous round
    /// (`None` before round 1: everyone starts from a dense snapshot)
    pending_down: Option<ModelUpdate>,
    /// downlink error-feedback codec (compressed modes): since every
    /// aggregation rebases `global` on `reference`, the codec residual
    /// is what carries un-shipped downlink mass into the next round
    down_codec: DeltaCodec,
    workers: Vec<WorkerHandle>,
    test: Dataset,
    eval: crate::runtime::exec::EvalState,
    model_batch: usize,
}

impl Leader {
    /// Build leader + workers. Shards the synthetic dataset across
    /// workers (IID or label-skewed per config).
    pub fn new(rt: &Runtime, manifest: &Manifest, cfg: FedConfig) -> Result<Self> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        cfg.validate()?; // programmatic construction gets the same checks
        let model = manifest.model(&cfg.train.model)?.clone();
        let full = generate(&SynthConfig {
            n: cfg.train.train_examples + cfg.train.test_examples,
            difficulty: cfg.train.difficulty as f32,
            seed: cfg.train.seed,
            ..Default::default()
        });
        let (train, test) = full.split(cfg.train.train_examples);
        let shards = train.shard(cfg.workers, cfg.iid, cfg.train.seed ^ 0x5A4D);

        let tag = format!("train_{}", cfg.train.mode);
        let art = model.artifact(&tag).with_context(|| {
            format!("mode {:?} not exported for {}", cfg.train.mode, model.name)
        })?;
        let eval_exe = rt.load(model.artifact("fwd")?)?;
        // resident eval uploads the post-FedAvg params once per round
        // (fingerprint cache) instead of once per test batch
        let eval =
            crate::runtime::exec::EvalState::new(rt, eval_exe, &model, cfg.train.eval_residency)?;

        let workers = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                WorkerHandle::spawn(
                    i,
                    shard,
                    art.clone(),
                    &model,
                    cfg.train.clone(),
                    cfg.comm,
                    cfg.comm_rate,
                )
            })
            .collect::<Result<Vec<_>>>()?;

        let global = ParamStore::init(&model, cfg.train.seed);
        Ok(Self {
            reference: global.params.clone(),
            in_sync: vec![false; cfg.workers],
            pending_down: None,
            down_codec: DeltaCodec::new(cfg.comm, cfg.comm_rate),
            cfg,
            global,
            workers,
            test,
            eval,
            model_batch: model.batch,
        })
    }

    /// The aggregated global parameters (current as of the last round).
    pub fn global_params(&self) -> &[Tensor] {
        &self.global.params
    }

    /// Run all rounds.
    pub fn run(&mut self) -> Result<FedSummary> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        let mut straggler_rng = Rng::new(self.cfg.train.seed ^ 0x57AA);
        let mut dropout_rng = Rng::new(self.cfg.train.seed ^ 0xD50F);
        let mut downlink_rng = Rng::new(self.cfg.train.seed ^ 0xD0C0DE);
        let energy = EnergyTable::smic14();
        let link = LinkEnergy::wifi();
        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            // broadcast: dense snapshots in dense mode; the pending
            // global delta to in-sync workers otherwise (dense fallback
            // for round 0 and resyncs)
            let (tx, rx) = mpsc::channel::<WorkerReport>();
            let mut dispatched_ids = Vec::with_capacity(self.workers.len());
            let mut dropped = Vec::new();
            let mut download_bytes = 0u64;
            let mut downlink_survivors = 0u64;
            let mut dense_downlinks = 0usize;
            for w in &self.workers {
                if dropout_rng.uniform() < self.cfg.dropout_prob {
                    // unreachable this round: misses the downlink, ships
                    // nothing — resync with a dense snapshot next round
                    dropped.push(w.id);
                    self.in_sync[w.id] = false;
                    continue;
                }
                let slowdown = if straggler_rng.uniform() < self.cfg.straggler_prob {
                    self.cfg.straggler_slowdown
                } else {
                    1.0
                };
                let payload = if self.cfg.comm == CommMode::Dense {
                    ModelUpdate::Dense(self.global.params.clone())
                } else if self.in_sync[w.id] && self.pending_down.is_some() {
                    self.pending_down.as_ref().unwrap().clone()
                } else {
                    self.in_sync[w.id] = true;
                    ModelUpdate::Dense(self.reference.clone())
                };
                let (wire, survivors, is_dense) =
                    (payload.wire_bytes(), payload.survivors(), payload.is_dense());
                match w.submit(WorkerTask {
                    round,
                    payload,
                    local_steps: self.cfg.local_steps,
                    slowdown,
                    reply: tx.clone(),
                }) {
                    Ok(()) => {
                        // ledger counts delivered messages only — a
                        // dispatch failure ships nothing
                        dispatched_ids.push(w.id);
                        download_bytes += wire;
                        downlink_survivors += survivors;
                        if is_dense {
                            dense_downlinks += 1;
                        }
                    }
                    Err(e) => {
                        log::warn!("round {round}: worker {} unreachable: {e:#}", w.id);
                        dropped.push(w.id);
                        self.in_sync[w.id] = false;
                    }
                }
            }
            drop(tx);

            // gather whatever arrives: a worker that fails its round
            // drops its reply sender without sending, so the channel
            // closes once every dispatched task is resolved
            let mut reports: Vec<WorkerReport> = rx.iter().collect();
            reports.sort_by_key(|r| r.worker_id);
            for &id in &dispatched_ids {
                if !reports.iter().any(|r| r.worker_id == id) {
                    // went silent mid-round. Usually a failed step/sync
                    // (downlink already applied), but the failure may
                    // also have been in the apply itself — we cannot
                    // tell from here, so treat its replica as suspect
                    // and resync it with a dense snapshot next round
                    dropped.push(id);
                    self.in_sync[id] = false;
                }
            }
            dropped.sort_unstable();
            if reports.is_empty() {
                // a fleet-wide outage round: nothing to aggregate, the
                // global model stands, and the dropout record tells the
                // story — a long-running deployment must not die to it
                log::warn!(
                    "round {round}: every worker missed the round ({} dropped)",
                    dropped.len()
                );
            }

            // aggregate (examples-weighted FedAvg over the survivors)
            let weights: Vec<f64> = reports.iter().map(|r| r.examples as f64).collect();
            let upload_bytes: u64 = reports.iter().map(|r| r.update.wire_bytes()).sum();
            let uplink_survivors: u64 = reports.iter().map(|r| r.update.survivors()).sum();
            if !reports.is_empty() {
                match self.cfg.comm {
                    CommMode::Dense => {
                        let updates = reports
                            .iter()
                            .map(|r| match &r.update {
                                ModelUpdate::Dense(p) => Ok(p),
                                ModelUpdate::Delta(_) => {
                                    bail!("worker {} sent a delta in dense mode", r.worker_id)
                                }
                            })
                            .collect::<Result<Vec<&Vec<Tensor>>>>()?;
                        self.global.params = weighted_fedavg(&updates, &weights)?;
                    }
                    _ => {
                        let updates = reports
                            .iter()
                            .map(|r| match &r.update {
                                ModelUpdate::Delta(u) => Ok(u),
                                ModelUpdate::Dense(_) => {
                                    bail!("worker {} sent dense params in delta mode", r.worker_id)
                                }
                            })
                            .collect::<Result<Vec<&Vec<TensorUpdate>>>>()?;
                        // O(nnz) per worker on top of the reference copy
                        // — the leader never materializes dense
                        // per-worker tensors
                        self.global.params =
                            weighted_sparse_fedavg(&self.reference, &updates, &weights)?;
                    }
                }
            }

            let n_reports = reports.len().max(1) as f64;
            let mean_loss = reports.iter().map(|r| r.mean_loss).sum::<f64>() / n_reports;
            let mean_sparsity =
                reports.iter().map(|r| r.mean_sparsity).sum::<f64>() / n_reports;
            // per-worker device-bus ledgers, aggregated like the params
            let worker_transfer: Vec<TransferStats> =
                reports.iter().map(|r| r.transfer).collect();
            let device_transfer = worker_transfer
                .iter()
                .fold(TransferStats::default(), |acc, &t| acc + t);
            self.eval.reset_transfer_stats();
            let eval_acc = self.evaluate()?;
            let leader_eval_transfer = self.eval.transfer_stats();

            // next round's downlink: the global delta vs the workers'
            // reference, through the same error-feedback codec as the
            // uplink; the leader advances its reference replica by the
            // *decoded* update, exactly like the workers will. The
            // carried residual is load-bearing: aggregation *rebases*
            // `global` on `reference` every round, so any downlink mass
            // the codec failed to deliver would otherwise vanish from
            // all state — the residual is the only thing that re-feeds
            // it into the next round's delta
            if self.cfg.comm != CommMode::Dense {
                let update = self.down_codec.encode(
                    &self.global.params,
                    &self.reference,
                    &mut downlink_rng,
                )?;
                update.apply(&mut self.reference)?;
                self.pending_down = Some(update);
            }

            let report = RoundReport {
                round,
                mean_loss,
                mean_sparsity,
                upload_bytes,
                download_bytes,
                dispatched: dispatched_ids.len(),
                dropped,
                dense_downlinks,
                uplink_survivors,
                downlink_survivors,
                eval_acc,
                wall_secs: t0.elapsed().as_secs_f64(),
                worker_secs: reports.iter().map(|r| r.sim_secs).collect(),
                worker_transfer,
                device_transfer,
                leader_eval_transfer,
            };
            log::info!(
                "round {round:3} loss {mean_loss:.4} acc {eval_acc:.4} sparsity {mean_sparsity:.3} \
                 net {:.1} KB ({:.1} mJ) device {:.1} KB ({:.2} mJ) dropped {:?} ({:.2}s)",
                report.network_bytes() as f64 / 1e3,
                report.network_joules(&link) * 1e3,
                report.device_bytes() as f64 / 1e3,
                report.device_joules(&energy) * 1e3,
                report.dropped,
                report.wall_secs
            );
            rounds.push(report);
        }
        let final_acc = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
        let total_upload_bytes = rounds.iter().map(|r| r.upload_bytes).sum();
        let total_download_bytes = rounds.iter().map(|r| r.download_bytes).sum();
        let total_device_transfer = rounds.iter().fold(TransferStats::default(), |acc, r| {
            acc + r.device_transfer + r.leader_eval_transfer
        });
        Ok(FedSummary {
            rounds,
            final_acc,
            total_upload_bytes,
            total_download_bytes,
            total_device_transfer,
        })
    }

    fn evaluate(&self) -> Result<f64> {
        let mut correct = 0.0;
        let mut total = 0usize;
        for idx in crate::data::batcher::eval_batches(&self.test, self.model_batch) {
            let batch = self.test.gather(&idx);
            correct += self.eval.accuracy(&self.global, &batch)? * idx.len() as f64;
            total += idx.len();
        }
        if total == 0 {
            bail!("test set smaller than one batch");
        }
        Ok(correct / total as f64)
    }

    /// Graceful shutdown (joins worker threads).
    pub fn shutdown(self) {
        for w in self.workers {
            w.shutdown();
        }
    }
}
