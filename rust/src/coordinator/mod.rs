//! Federated edge-training coordinator — the L3 systems contribution.
//!
//! The paper motivates EfficientGrad with federated learning: edge devices
//! must *train locally* and ship model updates, not data (§1). This module
//! implements that deployment: a leader drives rounds of local training on
//! N simulated edge workers (std threads, each with its own data shard and
//! PJRT executables), aggregates with FedAvg, and accounts communication
//! and (via the accel simulator's energy model) on-device training energy
//! per round.
//!
//! Worker execution is genuinely concurrent: the `xla` handles are not
//! `Send`, so each worker thread brings up its own PJRT client and
//! compiles its own executable — exactly like a fleet of edge devices,
//! each with its own accelerator and its own ParamStore replica.
//!
//! ## Round schedules
//!
//! Two leader schedules, selected by `federated.pipeline` / `--pipeline`
//! and **bit-identical in every result** (params, `eval_acc`, byte
//! ledgers — pinned in `tests/federated.rs`); they differ only in wall
//! time:
//!
//! * **sequential** (default, the oracle): barrier on every worker →
//!   decode + FedAvg → full test-set eval sweep → downlink encode, all
//!   serialized on the leader thread. Round wall time = slowest worker
//!   + all leader work.
//! * **pipelined**: each `WorkerReport` is decoded the moment it arrives
//!   off the mpsc channel ([`fedavg::StreamingAggregator`] — a straggler
//!   delays only its own decode), the final fold still runs in
//!   (version, worker-id) order into f64 accumulators (arrival order
//!   cannot change a bit), and the eval sweep moves to a dedicated
//!   [`evaluator::Evaluator`] thread whose results join the reports
//!   asynchronously — the leader encodes the downlink and dispatches
//!   round r+1 while accuracy computes.
//!   [`RoundReport::leader_secs`] / [`RoundReport::worker_secs`]
//!   split the round's wall time so the overlap is visible;
//!   `runtime_hotpath` benches the two schedules against each other
//!   under an injected straggler.
//!
//! Orthogonally to both, the round *barrier* itself is elastic
//! (`federated.quorum` / `--quorum`, default 1.0 = the full barrier,
//! bit-for-bit today's behavior — see `docs/TRANSFER_MODEL.md` §Model
//! versions & staleness):
//!
//! * **Versioned references.** The leader retains a bounded ring of
//!   [`versions::ModelVersion`] snapshots (version id + reference params
//!   + the encoded per-round delta); every task and report is tagged
//!   with the version it was computed against.
//! * **Quorum rounds.** At `quorum < 1.0` the leader folds as soon as
//!   `⌈quorum·dispatched⌉` reports arrive and dispatches round r+1
//!   against the new version while round r's stragglers are still in
//!   flight (pipeline depth ≥ 2); a straggler's report is folded into
//!   the round it arrives in with staleness weight `examples · λ^k`
//!   (`federated.staleness_decay`, k = versions behind), and
//!   `federated.pipeline_depth` bounds how many rounds may stay in
//!   flight — and with it the worst-case staleness k. Fold order is
//!   keyed on (version, worker-id), never arrival, so any given fold
//!   membership produces the same bits.
//! * **Chained downlinks.** A worker whose replica is `k ≤
//!   federated.max_chain` versions behind (a dropout that came back) is
//!   resynced with the *chain* of the retained per-round deltas —
//!   bit-identical to catching every downlink, `8 + Σ link` wire bytes
//!   instead of a dense `4·P` snapshot, and its error-feedback residual
//!   survives (a dense resync resets it).
//! * **Encode/eval overlap.** The O(P) downlink encode runs on its own
//!   thread between the fold and the next dispatch, overlapping the
//!   eval sweep (sequential) or the eval handoff (pipelined); the
//!   caller's RNG draw is taken on the leader thread in round order, so
//!   the encoded bits are identical to the serial schedule's.
//!
//! The O(P) host loops both schedules share (FedAvg folds, codec
//! delta/residual passes, eq. 3 comm pruning, σ) chunk across a scoped
//! thread pool at fixed boundaries (`util::par`), which keeps them
//! deterministic while using every core.
//!
//! Transfer model: with the default resident step backend
//! (`runtime::resident`), each worker's host↔device traffic is one
//! params upload + one params/momenta download *per round*, not per
//! step. Each round carries the device-bus ledger end-to-end: every
//! worker reports its per-round [`TransferStats`], the leader sums them
//! next to the FedAvg aggregate ([`RoundReport::device_transfer`]) and
//! accounts its own eval sweep ([`RoundReport::leader_eval_transfer`]).
//!
//! The *network* tier ([`RoundReport::upload_bytes`] /
//! [`RoundReport::download_bytes`]) is measured from the actual wire
//! messages ([`crate::comm`]): with `comm = dense` both directions ship
//! full `4·P` snapshots (the legacy exchange, bit for bit); with
//! `comm = pruned|sign` workers uplink error-feedback pruned deltas, the
//! leader folds them into the global params in O(nnz)
//! ([`weighted_sparse_fedavg`]) and downlinks the global delta through
//! the same codec — dense snapshots remain only for the first round and
//! for resyncing workers that missed a downlink. Rounds degrade
//! gracefully: a worker that goes silent (dropout injection, dispatch
//! failure, failed step) is recorded in [`RoundReport::dropped`] and
//! FedAvg re-weights over the reports that did arrive; a fleet-wide
//! outage round reports NaN means (skipped by the summary averages), not
//! fake zeros. Formulas: `docs/TRANSFER_MODEL.md`.

pub mod evaluator;
pub mod fedavg;
pub mod versions;
pub mod worker;

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::accel::energy::{EnergyTable, LinkEnergy};
use crate::accel::{simulate_training, AccelConfig, Workload};
use crate::comm::{DeltaCodec, ModelUpdate};
use crate::config::{CommMode, FedConfig};
use crate::data::synthetic::{generate, SynthConfig};
use crate::data::Dataset;
use crate::manifest::{ArtifactSpec, Manifest, ModelSpec};
use crate::params::ParamStore;
use crate::runtime::{Runtime, TransferStats};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub use evaluator::{EvalOutcome, Evaluator};
pub use fedavg::{fedavg, weighted_fedavg, weighted_sparse_fedavg, StreamingAggregator};
pub use versions::{ModelVersion, VersionRing};
pub use worker::{CommSetup, WorkerHandle, WorkerReport, WorkerTask};

/// Outcome of one federated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// round index (0-based)
    pub round: usize,
    /// the model version this round's fold produced (round r dispatches
    /// against version r and folds version r+1; version 0 is the shared
    /// init)
    pub version: u64,
    /// mean of the workers' mean local-step losses. **NaN** on a
    /// fleet-wide outage round (no reports arrived — there is no
    /// measurement, and a fake 0.0 would poison any averaged
    /// trajectory); the [`FedSummary`] averages skip NaN rounds
    pub mean_loss: f64,
    /// mean realized gradient sparsity across workers (NaN on an outage
    /// round, like `mean_loss`)
    pub mean_sparsity: f64,
    /// measured wire bytes shipped up (worker->leader) this round
    pub upload_bytes: u64,
    /// measured wire bytes broadcast down (leader->worker) this round
    pub download_bytes: u64,
    /// workers the leader dispatched a task to this round
    pub dispatched: usize,
    /// worker ids that missed a round (offline at dispatch, dispatch
    /// failure, or went silent mid-round); FedAvg re-weighted over the
    /// rest. Under a quorum schedule a silent worker is recorded in the
    /// round the leader *learns* of it (its stashed straggler channel
    /// disconnecting), which may be after the round it failed in.
    /// Offline workers resync next dispatch — chained if within the
    /// `max_chain` window, dense beyond it
    pub dropped: Vec<usize>,
    /// downlink payloads that were dense snapshots (first round, resync
    /// beyond the chain window, or `comm = dense`); the rest were pruned
    /// deltas or chains
    pub dense_downlinks: usize,
    /// downlink payloads that were chained deltas — workers
    /// `2 ..= max_chain` versions behind replaying the rounds they
    /// missed instead of paying a dense resync
    pub chained_downlinks: usize,
    /// straggler reports from earlier rounds folded into THIS round's
    /// FedAvg (quorum < 1.0 only; λ = 0 discards arrive-but-unfolded).
    /// Their wire bytes, device ledgers and loss/sparsity means land in
    /// this round's accounting — arrival-time bookkeeping
    pub late_reports: usize,
    /// Σ λ^k over the folded late reports: the fresh-report weight mass
    /// the stragglers retained after staleness discounting (equals
    /// `late_reports` at λ = 1, 0.0 when none folded)
    pub stale_weight_mass: f64,
    /// surviving (nonzero) delta coordinates across all uplink messages
    /// (0 in dense mode — every element travels)
    pub uplink_survivors: u64,
    /// surviving delta coordinates summed across downlink payloads
    pub downlink_survivors: u64,
    /// global-model accuracy on the leader's test set after aggregation.
    /// Sequential schedule: computed inline. Pipelined: joined
    /// asynchronously from the evaluator thread — NaN until joined, and
    /// every round is joined by the time [`Leader::run`] returns its
    /// [`FedSummary`]
    pub eval_acc: f64,
    /// leader-measured wall time for the whole round (dispatch through
    /// report construction; a pipelined round does not wait for its own
    /// eval, which overlaps the next round)
    pub wall_secs: f64,
    /// the slice of `wall_secs` the leader itself spent working —
    /// report decode, FedAvg fold, and the eval sweep (sequential
    /// schedule only). The downlink encode runs on its own thread
    /// overlapping the eval, so only its spawn/join shows here. The
    /// remainder of `wall_secs` is spent waiting on workers; pipelining
    /// shrinks `leader_secs` by moving eval off-thread and overlapping
    /// decode with the barrier
    pub leader_secs: f64,
    /// per-worker simulated wall time (stragglers show here)
    pub worker_secs: Vec<f64>,
    /// per-worker host↔device ledgers for the round, sorted by worker id
    /// (broadcast upload + local steps + round-boundary sync)
    pub worker_transfer: Vec<TransferStats>,
    /// sum of `worker_transfer` — the round's fleet-wide device-bus
    /// traffic, aggregated alongside the FedAvg params
    pub device_transfer: TransferStats,
    /// the leader's own eval-sweep ledger for this round (pipelined:
    /// joined with `eval_acc`)
    pub leader_eval_transfer: TransferStats,
}

impl RoundReport {
    /// Every device-bus byte this round moved, fleet + leader eval.
    pub fn device_bytes(&self) -> u64 {
        self.device_transfer.total_bytes() + self.leader_eval_transfer.total_bytes()
    }

    /// Every network byte this round moved, both directions.
    pub fn network_bytes(&self) -> u64 {
        self.upload_bytes + self.download_bytes
    }

    /// Simulated Joules of this round's *measured* device-bus traffic at
    /// `table`'s DRAM energy point — the ledger feeds the energy model,
    /// not an analytic byte estimate.
    pub fn device_joules(&self, table: &EnergyTable) -> f64 {
        table.bus_joules(self.device_bytes())
    }

    /// Simulated Joules of this round's measured network traffic over
    /// `link` (reported next to [`RoundReport::device_joules`]).
    pub fn network_joules(&self, link: &LinkEnergy) -> f64 {
        link.joules(self.network_bytes())
    }

    /// Simulated Joules of this round's *on-device training compute*:
    /// one simulated training step of `workload` on `cfg` — with the
    /// backward-phase sparsity gating driven by the round's **measured**
    /// survivor fraction `1 − mean_sparsity` instead of the static
    /// `expected_survivor_fraction(P)` — times the fleet's executed
    /// steps this round (the sum of the worker ledgers' step counts).
    /// 0.0 on an outage round: no steps ran, no compute was spent.
    /// Reported per round next to [`RoundReport::device_joules`] /
    /// [`RoundReport::network_joules`].
    pub fn compute_joules(&self, cfg: &AccelConfig, workload: &Workload) -> f64 {
        let steps: u64 = self.worker_transfer.iter().map(|t| t.steps).sum();
        if steps == 0 || !self.mean_sparsity.is_finite() {
            return 0.0;
        }
        let survivor = (1.0 - self.mean_sparsity).clamp(0.0, 1.0);
        simulate_training(cfg, workload, survivor).total_energy_j() * steps as f64
    }
}

/// Full run summary.
#[derive(Clone, Debug)]
pub struct FedSummary {
    /// per-round reports in order (pipelined eval results all joined)
    pub rounds: Vec<RoundReport>,
    /// last round's eval accuracy
    pub final_acc: f64,
    /// total worker->leader network bytes across the run
    pub total_upload_bytes: u64,
    /// total leader->worker network bytes across the run
    pub total_download_bytes: u64,
    /// total device-bus ledger across the run (all workers' rounds plus
    /// the leader's eval sweeps)
    pub total_device_transfer: TransferStats,
}

impl FedSummary {
    fn nan_mean(values: impl Iterator<Item = f64>) -> f64 {
        let mut n = 0usize;
        let mut sum = 0.0;
        for v in values {
            if v.is_finite() {
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    /// Mean per-round loss over the rounds that measured one —
    /// fleet-wide outage rounds carry NaN and are skipped, never
    /// averaged in as zeros.
    pub fn mean_round_loss(&self) -> f64 {
        Self::nan_mean(self.rounds.iter().map(|r| r.mean_loss))
    }

    /// Mean realized gradient sparsity over the measured rounds (outage
    /// rounds skipped, like [`FedSummary::mean_round_loss`]).
    pub fn mean_round_sparsity(&self) -> f64 {
        Self::nan_mean(self.rounds.iter().map(|r| r.mean_sparsity))
    }
}

/// Per-report scalars captured at decode time, slotted by worker id so
/// both schedules aggregate them in the same order regardless of when
/// each report arrived (the update itself moves into the
/// [`StreamingAggregator`]).
#[derive(Clone, Copy)]
struct ReportMeta {
    mean_loss: f64,
    mean_sparsity: f64,
    sim_secs: f64,
    transfer: TransferStats,
    wire_bytes: u64,
    survivors: u64,
}

impl ReportMeta {
    fn of(r: &WorkerReport) -> Self {
        Self {
            mean_loss: r.mean_loss,
            mean_sparsity: r.mean_sparsity,
            sim_secs: r.sim_secs,
            transfer: r.transfer,
            wire_bytes: r.update.wire_bytes(),
            survivors: r.update.survivors(),
        }
    }
}

/// One quorum round still awaiting straggler reports: the round's reply
/// channel plus the dispatched workers that had not reported when the
/// round closed at its quorum. Resolved by later rounds — arrivals fold
/// late with a staleness weight, a disconnect with reports still
/// outstanding means those workers failed mid-round.
struct InFlightRound {
    round: usize,
    rx: mpsc::Receiver<WorkerReport>,
    /// dispatched workers that had not reported at the quorum cutoff
    /// (each report carries its own `base_version` tag for the
    /// staleness weight)
    outstanding: Vec<usize>,
}

/// What the off-thread downlink encode hands back at join: the codec
/// (with its residual advanced), the encoded update, and the reference
/// params the update advances the head to.
type EncodeResult = Result<(DeltaCodec, ModelUpdate, Vec<Tensor>)>;

/// The federated leader.
pub struct Leader {
    cfg: FedConfig,
    global: ParamStore,
    /// bounded ring of version-tagged reference snapshots. The head is
    /// the params every current worker holds — advanced only by applying
    /// the same downlink updates the workers apply, so leader and worker
    /// replicas stay bit-identical; retained predecessors (and their
    /// per-round deltas) are what chained downlinks replay. Dense mode
    /// pushes snapshot-only versions so version tagging is uniform.
    ring: VersionRing,
    /// per-worker replica version: `Some(v)` = the worker holds
    /// reference version v (stale is fine — chain or resync at next
    /// dispatch); `None` = unknown/diverged (never dispatched, went
    /// silent mid-round, or dispatch failed) → dense resync
    worker_version: Vec<Option<u64>>,
    /// downlink error-feedback codec (compressed modes): since every
    /// aggregation rebases `global` on the reference head, the codec
    /// residual is what carries un-shipped downlink mass into the next
    /// round. `None` only while an encode is in flight on the overlap
    /// thread (the thread owns it and hands it back at join).
    down_codec: Option<DeltaCodec>,
    workers: Vec<WorkerHandle>,
    test: Dataset,
    /// the sequential schedule's eval driver. `None` under
    /// `cfg.pipeline`: the evaluator thread owns the sweep there, and a
    /// leader-side `EvalState` would only duplicate the fwd compile and
    /// the resident param-buffer allocation
    eval: Option<crate::runtime::exec::EvalState>,
    /// model spec (batch, layers for the compute-energy workload, and
    /// everything the pipelined evaluator thread needs to bring up its
    /// own replica)
    model: ModelSpec,
    /// fwd artifact — compiled again by the evaluator thread in
    /// pipelined mode (PJRT handles are not `Send`)
    fwd_art: ArtifactSpec,
}

impl Leader {
    /// Build leader + workers. Shards the synthetic dataset across
    /// workers (IID or label-skewed per config).
    pub fn new(rt: &Runtime, manifest: &Manifest, cfg: FedConfig) -> Result<Self> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        cfg.validate()?; // programmatic construction gets the same checks
        let model = manifest.model(&cfg.train.model)?.clone();
        let full = generate(&SynthConfig {
            n: cfg.train.train_examples + cfg.train.test_examples,
            difficulty: cfg.train.difficulty as f32,
            seed: cfg.train.seed,
            ..Default::default()
        });
        let (train, test) = full.split(cfg.train.train_examples);
        let shards = train.shard(cfg.workers, cfg.iid, cfg.train.seed ^ 0x5A4D);

        let tag = format!("train_{}", cfg.train.mode);
        let art = model.artifact(&tag).with_context(|| {
            format!("mode {:?} not exported for {}", cfg.train.mode, model.name)
        })?;
        let fwd_art = model.artifact("fwd")?.clone();
        // resident eval uploads the post-FedAvg params once per round
        // (fingerprint cache) instead of once per test batch. Pipelined
        // runs skip the leader-side driver entirely — the evaluator
        // thread compiles its own (one Runtime per thread)
        let eval = if cfg.pipeline {
            None
        } else {
            let eval_exe = rt.load(&fwd_art)?;
            Some(crate::runtime::exec::EvalState::new(
                rt,
                eval_exe,
                &model,
                cfg.train.eval_residency,
            )?)
        };

        let workers = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                WorkerHandle::spawn(
                    i,
                    shard,
                    art.clone(),
                    &model,
                    cfg.train.clone(),
                    worker::CommSetup {
                        mode: cfg.comm,
                        rate: cfg.comm_rate,
                        pruner: cfg.comm_pruner,
                    },
                )
            })
            .collect::<Result<Vec<_>>>()?;

        let global = ParamStore::init(&model, cfg.train.seed);
        // retain enough history to chain a worker max_chain versions
        // behind (the chain needs the newest max_chain deltas, each
        // carried by its version entry, plus the head itself)
        let ring_cap = cfg.max_chain.max(1) + 1;
        Ok(Self {
            ring: VersionRing::new(ring_cap, global.params.clone()),
            worker_version: vec![None; cfg.workers],
            down_codec: Some(DeltaCodec::with_pruner(
                cfg.comm,
                cfg.comm_rate,
                cfg.comm_pruner,
            )),
            cfg,
            global,
            workers,
            test,
            eval,
            model,
            fwd_art,
        })
    }

    /// The aggregated global parameters (current as of the last round).
    pub fn global_params(&self) -> &[Tensor] {
        &self.global.params
    }

    /// The version-tagged reference ring (telemetry / tests).
    pub fn versions(&self) -> &VersionRing {
        &self.ring
    }

    /// Choose worker `id`'s downlink for the version at the ring head:
    /// dense snapshots in dense mode; otherwise the per-round delta for
    /// a replica one version behind, a chain of the retained deltas for
    /// one `2 ..= max_chain` behind, and a dense resync beyond that (or
    /// when the replica state is unknown — never dispatched, silent
    /// failure, or the needed history was evicted from the ring).
    fn downlink_payload(&self, id: usize) -> ModelUpdate {
        if self.cfg.comm == CommMode::Dense {
            return ModelUpdate::Dense(self.global.params.clone());
        }
        let head = self.ring.head();
        match self.worker_version[id] {
            Some(v) if head.version == v + 1 => match &head.delta {
                Some(us) => ModelUpdate::Delta(us.clone()),
                None => ModelUpdate::Dense(head.params.clone()),
            },
            Some(v)
                if v < head.version && (head.version - v) as usize <= self.cfg.max_chain =>
            {
                // replays the missed rounds bit-identically; falls back
                // to a snapshot if any link left the ring
                self.ring
                    .chain_from(v)
                    .unwrap_or_else(|| ModelUpdate::Dense(head.params.clone()))
            }
            _ => ModelUpdate::Dense(head.params.clone()),
        }
    }

    /// Join an off-thread downlink encode: restore the codec (its
    /// residual advanced by the encode) and push the version the encode
    /// produced onto the reference ring.
    fn join_encode(&mut self, handle: JoinHandle<EncodeResult>) -> Result<()> {
        let (codec, update, next_ref) = handle
            .join()
            .map_err(|_| anyhow!("downlink encode thread panicked"))??;
        self.down_codec = Some(codec);
        let delta = match update {
            ModelUpdate::Delta(us) => Some(us),
            _ => None,
        };
        self.ring.push(next_ref, delta);
        Ok(())
    }

    /// Run all rounds under the configured schedule (see the module docs
    /// for the sequential-vs-pipelined timeline; results are identical).
    pub fn run(&mut self) -> Result<FedSummary> {
        let mut rounds: Vec<RoundReport> = Vec::with_capacity(self.cfg.rounds);
        let mut straggler_rng = Rng::new(self.cfg.train.seed ^ 0x57AA);
        let mut dropout_rng = Rng::new(self.cfg.train.seed ^ 0xD50F);
        let mut downlink_rng = Rng::new(self.cfg.train.seed ^ 0xD0C0DE);
        let energy = EnergyTable::smic14();
        let link = LinkEnergy::wifi();
        // measured-survivor compute energy: the accel simulator's
        // backward-phase gating runs at each round's *realized* sparsity
        // instead of the static expected_survivor_fraction(P)
        let accel_cfg = crate::accel::config::efficientgrad();
        let workload =
            Workload::from_manifest(&self.model.name, &self.model.layers, self.model.batch);
        // pipelined schedule: the eval sweep lives on its own thread
        // (own Runtime — PJRT handles are not Send) and joins results
        // asynchronously
        let evaluator = if self.cfg.pipeline {
            Some(Evaluator::spawn(
                &self.model,
                self.fwd_art.clone(),
                self.cfg.train.eval_residency,
                self.test.clone(),
                self.cfg.train.seed,
            )?)
        } else {
            None
        };
        let mut evals_pending = 0usize;
        // downlink encode in flight on its own thread: spawned after
        // each fold (overlapping the eval), joined right before the next
        // dispatch needs its output
        let mut enc_pending: Option<JoinHandle<EncodeResult>> = None;
        // quorum rounds whose stragglers are still in flight
        let mut inbox: Vec<InFlightRound> = Vec::new();

        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            let mut leader_busy = Duration::ZERO;

            // advance the reference ring to the version this round
            // trains against: join the previous round's off-thread
            // encode (compressed modes) or snapshot the global (dense).
            // Round 0 trains the genesis version.
            let t = Instant::now();
            if let Some(handle) = enc_pending.take() {
                self.join_encode(handle)?;
            } else if self.cfg.comm == CommMode::Dense && round > 0 {
                self.ring.push(self.global.params.clone(), None);
            }
            let base_version = self.ring.head_version();
            leader_busy += t.elapsed();

            // broadcast: dense snapshots in dense mode; otherwise the
            // per-round delta / retained-delta chain / dense resync that
            // each worker's replica version calls for
            let (tx, rx) = mpsc::channel::<WorkerReport>();
            let mut dispatched_ids = Vec::with_capacity(self.workers.len());
            let mut dropped = Vec::new();
            let mut download_bytes = 0u64;
            let mut downlink_survivors = 0u64;
            let mut dense_downlinks = 0usize;
            let mut chained_downlinks = 0usize;
            for w in &self.workers {
                if dropout_rng.uniform() < self.cfg.dropout_prob {
                    // unreachable this round: misses the downlink, ships
                    // nothing. Its replica is intact, only *stale* — the
                    // next dispatch chains it forward if it is within the
                    // max_chain window, dense resync beyond it
                    dropped.push(w.id);
                    continue;
                }
                let slowdown = if straggler_rng.uniform() < self.cfg.straggler_prob {
                    self.cfg.straggler_slowdown
                } else {
                    1.0
                };
                let payload = self.downlink_payload(w.id);
                let (wire, survivors, is_dense, is_chain) = (
                    payload.wire_bytes(),
                    payload.survivors(),
                    payload.is_dense(),
                    payload.is_chain(),
                );
                match w.submit(WorkerTask {
                    round,
                    version: base_version,
                    payload,
                    local_steps: self.cfg.local_steps,
                    slowdown,
                    sleep: self.cfg.straggler_sleep,
                    reply: tx.clone(),
                }) {
                    Ok(()) => {
                        // ledger counts delivered messages only — a
                        // dispatch failure ships nothing
                        dispatched_ids.push(w.id);
                        self.worker_version[w.id] = Some(base_version);
                        download_bytes += wire;
                        downlink_survivors += survivors;
                        if is_dense {
                            dense_downlinks += 1;
                        }
                        if is_chain {
                            chained_downlinks += 1;
                        }
                    }
                    Err(e) => {
                        log::warn!("round {round}: worker {} unreachable: {e:#}", w.id);
                        dropped.push(w.id);
                        self.worker_version[w.id] = None;
                    }
                }
            }
            drop(tx);

            // gather: a worker that fails its round drops its reply
            // sender without sending, so the channel closes once every
            // dispatched task is resolved. At quorum = 1.0 that close is
            // the only exit (the full barrier — today's oracle); at
            // quorum < 1.0 the leader stops once ⌈quorum·dispatched⌉
            // reports are in and stashes the round's channel for the
            // stragglers. Both schedules decode through the same
            // StreamingAggregator; they differ only in *when* each
            // report's decode runs.
            let quorum_needed = if self.cfg.quorum >= 1.0 {
                dispatched_ids.len()
            } else {
                ((self.cfg.quorum * dispatched_ids.len() as f64).ceil() as usize)
                    .clamp(usize::from(!dispatched_ids.is_empty()), dispatched_ids.len())
            };
            let mut agg = StreamingAggregator::new(self.cfg.comm, self.workers.len());
            let mut meta: Vec<Option<ReportMeta>> = vec![None; self.workers.len()];
            let mut received = 0usize;
            let mut channel_closed = false;
            if self.cfg.pipeline {
                // streaming: decode each report the moment it arrives —
                // a straggler delays only its own decode work
                while received < quorum_needed {
                    match rx.recv() {
                        Ok(r) => {
                            let t = Instant::now();
                            let id = r.worker_id;
                            let m = ReportMeta::of(&r);
                            agg.accept(r.base_version, id, r.examples as f64, r.update)?;
                            meta[id] = Some(m);
                            received += 1;
                            leader_busy += t.elapsed();
                        }
                        Err(_) => {
                            channel_closed = true;
                            break;
                        }
                    }
                }
            } else {
                // sequential oracle: barrier (full or quorum) first,
                // then decode in worker-id order — the reference
                // schedule
                let mut reports: Vec<WorkerReport> = Vec::with_capacity(quorum_needed);
                while received < quorum_needed {
                    match rx.recv() {
                        Ok(r) => {
                            reports.push(r);
                            received += 1;
                        }
                        Err(_) => {
                            channel_closed = true;
                            break;
                        }
                    }
                }
                let t = Instant::now();
                reports.sort_by_key(|r| r.worker_id);
                for r in reports {
                    let id = r.worker_id;
                    let m = ReportMeta::of(&r);
                    agg.accept(r.base_version, id, r.examples as f64, r.update)?;
                    meta[id] = Some(m);
                }
                leader_busy += t.elapsed();
            }
            if channel_closed {
                for &id in &dispatched_ids {
                    if meta[id].is_none() {
                        // went silent mid-round. Usually a failed
                        // step/sync (downlink already applied), but the
                        // failure may also have been in the apply itself
                        // — we cannot tell from here, so treat its
                        // replica as suspect and dense-resync it
                        dropped.push(id);
                        self.worker_version[id] = None;
                    }
                }
            } else if received < dispatched_ids.len() {
                // quorum cutoff: the rest are stragglers, not failures —
                // keep the round's channel and fold their reports into a
                // later round with a staleness discount
                let outstanding: Vec<usize> = dispatched_ids
                    .iter()
                    .copied()
                    .filter(|&id| meta[id].is_none())
                    .collect();
                inbox.push(InFlightRound {
                    round,
                    rx,
                    outstanding,
                });
            }

            // late straggler reports: fold what has arrived, blocking on
            // rounds older than the pipeline depth — which bounds the
            // worst-case staleness at k ≤ pipeline_depth — each weighted
            // examples · λ^k. Which round a late report lands in depends
            // on when it arrives (this is genuinely asynchronous); the
            // fold for any given membership is deterministic because the
            // aggregator keys on (version, worker-id), never arrival.
            // Only per-report decode time lands in leader_busy — a
            // blocking wait on an overdue straggler is time spent
            // waiting on workers, which leader_secs must not claim.
            let mut late_busy = Duration::ZERO;
            let mut late_meta: Vec<(u64, usize, ReportMeta)> = Vec::new();
            let mut late_reports = 0usize;
            let mut stale_weight_mass = 0.0f64;
            let mut inbox_err: Option<anyhow::Error> = None;
            {
                let depth = self.cfg.pipeline_depth;
                let lambda = self.cfg.staleness_decay;
                let worker_version = &mut self.worker_version;
                let agg = &mut agg;
                let dropped = &mut dropped;
                inbox.retain_mut(|inflight| {
                    if inflight.round == round {
                        // stashed moments ago by THIS round's quorum
                        // cutoff: its stragglers fold no earlier than
                        // next round (k ≥ 1 by construction)
                        return true;
                    }
                    let overdue = inflight.round + depth <= round;
                    loop {
                        let msg = if overdue {
                            inflight
                                .rx
                                .recv()
                                .map_err(|_| mpsc::TryRecvError::Disconnected)
                        } else {
                            inflight.rx.try_recv()
                        };
                        match msg {
                            Ok(r) => {
                                let t = Instant::now();
                                let id = r.worker_id;
                                inflight.outstanding.retain(|&o| o != id);
                                let k = base_version.saturating_sub(r.base_version).max(1);
                                let weight = lambda.powi(k as i32);
                                if weight > 0.0 {
                                    let m = ReportMeta::of(&r);
                                    if let Err(e) = agg.accept(
                                        r.base_version,
                                        id,
                                        r.examples as f64 * weight,
                                        r.update,
                                    ) {
                                        inbox_err = Some(e);
                                        return false;
                                    }
                                    late_meta.push((r.base_version, id, m));
                                    late_reports += 1;
                                    stale_weight_mass += weight;
                                    late_busy += t.elapsed();
                                } else {
                                    // λ = 0: the report resolves the
                                    // straggler but is too stale to fold
                                    log::debug!(
                                        "round {round}: discarding fully-stale report \
                                         from worker {id} (k = {k})"
                                    );
                                }
                                if inflight.outstanding.is_empty() {
                                    return false;
                                }
                            }
                            Err(mpsc::TryRecvError::Empty) => return true,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                // the round's tasks all resolved but these
                                // workers never reported: failed mid-round
                                for &id in &inflight.outstanding {
                                    dropped.push(id);
                                    worker_version[id] = None;
                                }
                                return false;
                            }
                        }
                    }
                });
            }
            if let Some(e) = inbox_err {
                return Err(e);
            }
            // fold key order, so the ledger sums below are deterministic
            // for a given membership
            late_meta.sort_by_key(|&(v, id, _)| (v, id));
            leader_busy += late_busy;

            dropped.sort_unstable();
            dropped.dedup();
            let n_fresh = meta.iter().flatten().count();
            let n_reports = n_fresh + late_reports;
            if n_reports == 0 {
                // a fleet-wide outage round: nothing to aggregate, the
                // global model stands, and the dropout record tells the
                // story — a long-running deployment must not die to it
                log::warn!(
                    "round {round}: every worker missed the round ({} dropped)",
                    dropped.len()
                );
            }

            // aggregate: fold the decoded slots in (version, worker-id)
            // order into f64 accumulators (examples-weighted FedAvg over
            // the survivors, stale reports λ^k-discounted; O(nnz) per
            // worker in the compressed modes)
            let t = Instant::now();
            if let Some(params) = agg.finish(&self.ring.head().params)? {
                self.global.params = params;
            }
            // per-round scalars and ledgers: fresh reports in worker-id
            // order, then late reports in (version, id) order — arrival-
            // time accounting (a late report's bytes and device ledger
            // land in the round that folded it)
            let folded = || {
                let fresh = meta.iter().flatten();
                fresh.chain(late_meta.iter().map(|(_, _, m)| m))
            };
            let upload_bytes: u64 = folded().map(|m| m.wire_bytes).sum();
            let uplink_survivors: u64 = folded().map(|m| m.survivors).sum();
            let (mean_loss, mean_sparsity) = if n_reports == 0 {
                // no measurement exists — NaN, not a fake 0.0 that would
                // poison any averaged trajectory (FedSummary skips NaN)
                (f64::NAN, f64::NAN)
            } else {
                let n = n_reports as f64;
                let loss: f64 = folded().map(|m| m.mean_loss).sum();
                let spars: f64 = folded().map(|m| m.mean_sparsity).sum();
                (loss / n, spars / n)
            };
            // per-worker device-bus ledgers, aggregated like the params
            let worker_transfer: Vec<TransferStats> = folded().map(|m| m.transfer).collect();
            let device_transfer = worker_transfer
                .iter()
                .fold(TransferStats::default(), |acc, &t| acc + t);
            let worker_secs: Vec<f64> = folded().map(|m| m.sim_secs).collect();

            // next round's downlink, off-thread: the global delta vs the
            // reference head, through the same error-feedback codec as
            // the uplink; the thread advances the reference by the
            // *decoded* update, exactly like the workers will. The
            // carried residual is load-bearing: aggregation *rebases*
            // `global` on the reference every round, so any downlink
            // mass the codec failed to deliver would otherwise vanish
            // from all state — the residual is the only thing that
            // re-feeds it into the next round's delta. The encode
            // overlaps the eval below; its RNG position is taken here,
            // on the leader thread, in round order, so the encoded bits
            // match the serial schedule's exactly.
            if self.cfg.comm != CommMode::Dense {
                let mut codec = self
                    .down_codec
                    .take()
                    .expect("downlink codec home between encodes");
                let global = self.global.params.clone();
                let reference = self.ring.head().params.clone();
                let mut rng = downlink_rng.clone();
                let _ = downlink_rng.next_u64(); // the thread consumes exactly this draw
                enc_pending = Some(
                    std::thread::Builder::new()
                        .name("downlink-encode".into())
                        .spawn(move || -> EncodeResult {
                            let update = codec.encode(&global, &reference, &mut rng)?;
                            let mut next_ref = reference;
                            update.apply(&mut next_ref)?;
                            Ok((codec, update, next_ref))
                        })
                        .map_err(|e| anyhow!("spawning downlink encode: {e}"))?,
                );
            }
            leader_busy += t.elapsed();

            // eval: inline on the sequential schedule (the encode thread
            // overlaps this sweep); handed to the evaluator thread on
            // the pipelined one (the snapshot clone is the handoff cost
            // — the sweep overlaps round r+1)
            let t = Instant::now();
            let (eval_acc, leader_eval_transfer) = match &evaluator {
                None => {
                    let eval = self
                        .eval
                        .as_ref()
                        .expect("sequential leader owns an EvalState");
                    eval.reset_transfer_stats();
                    let acc = eval.dataset_accuracy(&self.global, &self.test, self.model.batch)?;
                    (acc, eval.transfer_stats())
                }
                Some(ev) => {
                    ev.submit(round, self.global.params.clone())?;
                    evals_pending += 1;
                    (f64::NAN, TransferStats::default())
                }
            };
            leader_busy += t.elapsed();

            let mut report = RoundReport {
                round,
                version: base_version + 1,
                mean_loss,
                mean_sparsity,
                upload_bytes,
                download_bytes,
                dispatched: dispatched_ids.len(),
                dropped,
                dense_downlinks,
                chained_downlinks,
                late_reports,
                stale_weight_mass,
                uplink_survivors,
                downlink_survivors,
                eval_acc,
                wall_secs: t0.elapsed().as_secs_f64(),
                leader_secs: leader_busy.as_secs_f64(),
                worker_secs,
                worker_transfer,
                device_transfer,
                leader_eval_transfer,
            };
            // pipelined: join whatever eval results are ready by now
            // (latest-available — this round's own eval may still be in
            // flight; FedSummary joins the rest)
            if let Some(ev) = &evaluator {
                for o in ev.drain_ready()? {
                    evals_pending -= 1;
                    if o.round == round {
                        report.eval_acc = o.acc;
                        report.leader_eval_transfer = o.transfer;
                    } else {
                        rounds[o.round].eval_acc = o.acc;
                        rounds[o.round].leader_eval_transfer = o.transfer;
                    }
                }
            }
            let (log_acc, acc_tag) = if report.eval_acc.is_finite() {
                (report.eval_acc, "")
            } else {
                // newest joined accuracy, marked as trailing
                (
                    rounds
                        .iter()
                        .rev()
                        .find(|r| r.eval_acc.is_finite())
                        .map(|r| r.eval_acc)
                        .unwrap_or(f64::NAN),
                    "~",
                )
            };
            log::info!(
                "round {round:3} v{} loss {mean_loss:.4} acc {log_acc:.4}{acc_tag} \
                 sparsity {mean_sparsity:.3} net {:.1} KB ({:.1} mJ) device {:.1} KB \
                 ({:.2} mJ) compute {:.1} mJ dropped {:?} late {} ({:.2}s, leader {:.3}s)",
                report.version,
                report.network_bytes() as f64 / 1e3,
                report.network_joules(&link) * 1e3,
                report.device_bytes() as f64 / 1e3,
                report.device_joules(&energy) * 1e3,
                report.compute_joules(&accel_cfg, &workload) * 1e3,
                report.dropped,
                report.late_reports,
                report.wall_secs,
                report.leader_secs,
            );
            rounds.push(report);
        }
        // the final round's encode has no recipient, but joining it
        // keeps the codec residual and ring head consistent (and
        // surfaces any encode error instead of swallowing it)
        if let Some(handle) = enc_pending.take() {
            self.join_encode(handle)?;
        }
        // quorum teardown: stragglers still in flight at run end have no
        // later round to fold into — their reports are dropped on the
        // floor (the workers' sends fail silently and the threads idle
        // until shutdown), exactly what a real deployment tearing down
        // mid-round would do
        drop(inbox);

        // pipelined: every submitted round joins before the summary —
        // all eval_acc values and leader-eval ledgers are final below
        if let Some(ev) = &evaluator {
            for o in ev.wait_for(evals_pending)? {
                rounds[o.round].eval_acc = o.acc;
                rounds[o.round].leader_eval_transfer = o.transfer;
            }
        }
        drop(evaluator); // joins the eval thread

        let final_acc = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
        let total_upload_bytes = rounds.iter().map(|r| r.upload_bytes).sum();
        let total_download_bytes = rounds.iter().map(|r| r.download_bytes).sum();
        let total_device_transfer = rounds.iter().fold(TransferStats::default(), |acc, r| {
            acc + r.device_transfer + r.leader_eval_transfer
        });
        Ok(FedSummary {
            rounds,
            final_acc,
            total_upload_bytes,
            total_download_bytes,
            total_device_transfer,
        })
    }

    /// Graceful shutdown (joins worker threads).
    pub fn shutdown(self) {
        for w in self.workers {
            w.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stub_round(round: usize, loss: f64, sparsity: f64) -> RoundReport {
        RoundReport {
            round,
            version: round as u64 + 1,
            mean_loss: loss,
            mean_sparsity: sparsity,
            upload_bytes: 0,
            download_bytes: 0,
            dispatched: 0,
            dropped: Vec::new(),
            dense_downlinks: 0,
            chained_downlinks: 0,
            late_reports: 0,
            stale_weight_mass: 0.0,
            uplink_survivors: 0,
            downlink_survivors: 0,
            eval_acc: 0.0,
            wall_secs: 0.0,
            leader_secs: 0.0,
            worker_secs: Vec::new(),
            worker_transfer: Vec::new(),
            device_transfer: TransferStats::default(),
            leader_eval_transfer: TransferStats::default(),
        }
    }

    #[test]
    fn summary_averages_skip_outage_rounds() {
        let s = FedSummary {
            rounds: vec![
                stub_round(0, 1.0, 0.5),
                stub_round(1, f64::NAN, f64::NAN), // fleet-wide outage
                stub_round(2, 3.0, 0.7),
            ],
            final_acc: 0.0,
            total_upload_bytes: 0,
            total_download_bytes: 0,
            total_device_transfer: TransferStats::default(),
        };
        // the outage round is skipped, not averaged in as zeros
        assert_eq!(s.mean_round_loss(), 2.0);
        assert!((s.mean_round_sparsity() - 0.6).abs() < 1e-12);
        let all_out = FedSummary {
            rounds: vec![stub_round(0, f64::NAN, f64::NAN)],
            ..s
        };
        assert!(all_out.mean_round_loss().is_nan());
        assert!(all_out.mean_round_sparsity().is_nan());
    }

    #[test]
    fn compute_joules_gates_on_measured_survivors() {
        let cfg = crate::accel::config::efficientgrad();
        let wl = crate::accel::resnet18_cifar(4);
        let steps = TransferStats {
            steps: 10,
            ..TransferStats::default()
        };
        let mut sparse = stub_round(0, 1.0, 0.9); // 90% zeros measured
        sparse.worker_transfer = vec![steps];
        let mut dense = stub_round(0, 1.0, 0.0); // nothing pruned
        dense.worker_transfer = vec![steps];
        let js = sparse.compute_joules(&cfg, &wl);
        let jd = dense.compute_joules(&cfg, &wl);
        assert!(js > 0.0, "measured-survivor energy must be positive");
        assert!(jd > js, "sparsity gating must discount compute: {jd} vs {js}");
        // outage round: no steps ran, no compute spent
        assert_eq!(stub_round(1, f64::NAN, f64::NAN).compute_joules(&cfg, &wl), 0.0);
    }
}
