//! Federated edge-training coordinator — the L3 systems contribution.
//!
//! The paper motivates EfficientGrad with federated learning: edge devices
//! must *train locally* and ship model updates, not data (§1). This module
//! implements that deployment: a leader drives rounds of local training on
//! N simulated edge workers (std threads, each with its own data shard and
//! PJRT executables), aggregates with FedAvg, and accounts communication
//! and (via the accel simulator's energy model) on-device training energy
//! per round.
//!
//! Worker execution is genuinely concurrent: the `xla` handles are not
//! `Send`, so each worker thread brings up its own PJRT client and
//! compiles its own executable — exactly like a fleet of edge devices,
//! each with its own accelerator and its own ParamStore replica.
//!
//! ## Round schedules
//!
//! Two leader schedules, selected by `federated.pipeline` / `--pipeline`
//! and **bit-identical in every result** (params, `eval_acc`, byte
//! ledgers — pinned in `tests/federated.rs`); they differ only in wall
//! time:
//!
//! * **sequential** (default, the oracle): barrier on every worker →
//!   decode + FedAvg → full test-set eval sweep → downlink encode, all
//!   serialized on the leader thread. Round wall time = slowest worker
//!   + all leader work.
//! * **pipelined**: each `WorkerReport` is decoded the moment it arrives
//!   off the mpsc channel ([`fedavg::StreamingAggregator`] — a straggler
//!   delays only its own decode), the final fold still runs in worker-id
//!   order into f64 accumulators (arrival order cannot change a bit),
//!   and the eval sweep moves to a dedicated [`evaluator::Evaluator`]
//!   thread whose results join the reports asynchronously — the leader
//!   encodes the downlink and dispatches round r+1 while accuracy
//!   computes. [`RoundReport::leader_secs`] / [`RoundReport::worker_secs`]
//!   split the round's wall time so the overlap is visible;
//!   `runtime_hotpath` benches the two schedules against each other
//!   under an injected straggler.
//!
//! The O(P) host loops both schedules share (FedAvg folds, codec
//! delta/residual passes, eq. 3 comm pruning, σ) chunk across a scoped
//! thread pool at fixed boundaries (`util::par`), which keeps them
//! deterministic while using every core.
//!
//! Transfer model: with the default resident step backend
//! (`runtime::resident`), each worker's host↔device traffic is one
//! params upload + one params/momenta download *per round*, not per
//! step. Each round carries the device-bus ledger end-to-end: every
//! worker reports its per-round [`TransferStats`], the leader sums them
//! next to the FedAvg aggregate ([`RoundReport::device_transfer`]) and
//! accounts its own eval sweep ([`RoundReport::leader_eval_transfer`]).
//!
//! The *network* tier ([`RoundReport::upload_bytes`] /
//! [`RoundReport::download_bytes`]) is measured from the actual wire
//! messages ([`crate::comm`]): with `comm = dense` both directions ship
//! full `4·P` snapshots (the legacy exchange, bit for bit); with
//! `comm = pruned|sign` workers uplink error-feedback pruned deltas, the
//! leader folds them into the global params in O(nnz)
//! ([`weighted_sparse_fedavg`]) and downlinks the global delta through
//! the same codec — dense snapshots remain only for the first round and
//! for resyncing workers that missed a downlink. Rounds degrade
//! gracefully: a worker that goes silent (dropout injection, dispatch
//! failure, failed step) is recorded in [`RoundReport::dropped`] and
//! FedAvg re-weights over the reports that did arrive; a fleet-wide
//! outage round reports NaN means (skipped by the summary averages), not
//! fake zeros. Formulas: `docs/TRANSFER_MODEL.md`.

pub mod evaluator;
pub mod fedavg;
pub mod worker;

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::accel::energy::{EnergyTable, LinkEnergy};
use crate::accel::{simulate_training, AccelConfig, Workload};
use crate::comm::{DeltaCodec, ModelUpdate};
use crate::config::{CommMode, FedConfig};
use crate::data::synthetic::{generate, SynthConfig};
use crate::data::Dataset;
use crate::manifest::{ArtifactSpec, Manifest, ModelSpec};
use crate::params::ParamStore;
use crate::runtime::{Runtime, TransferStats};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub use evaluator::{EvalOutcome, Evaluator};
pub use fedavg::{fedavg, weighted_fedavg, weighted_sparse_fedavg, StreamingAggregator};
pub use worker::{WorkerHandle, WorkerReport, WorkerTask};

/// Outcome of one federated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// round index (0-based)
    pub round: usize,
    /// mean of the workers' mean local-step losses. **NaN** on a
    /// fleet-wide outage round (no reports arrived — there is no
    /// measurement, and a fake 0.0 would poison any averaged
    /// trajectory); the [`FedSummary`] averages skip NaN rounds
    pub mean_loss: f64,
    /// mean realized gradient sparsity across workers (NaN on an outage
    /// round, like `mean_loss`)
    pub mean_sparsity: f64,
    /// measured wire bytes shipped up (worker->leader) this round
    pub upload_bytes: u64,
    /// measured wire bytes broadcast down (leader->worker) this round
    pub download_bytes: u64,
    /// workers the leader dispatched a task to this round
    pub dispatched: usize,
    /// worker ids that missed the round (offline at dispatch, dispatch
    /// failure, or went silent mid-round); FedAvg re-weighted over the
    /// rest, and offline workers resync from a dense snapshot next round
    pub dropped: Vec<usize>,
    /// downlink payloads that were dense snapshots (first round, resync,
    /// or `comm = dense`); the rest were pruned deltas
    pub dense_downlinks: usize,
    /// surviving (nonzero) delta coordinates across all uplink messages
    /// (0 in dense mode — every element travels)
    pub uplink_survivors: u64,
    /// surviving delta coordinates summed across downlink payloads
    pub downlink_survivors: u64,
    /// global-model accuracy on the leader's test set after aggregation.
    /// Sequential schedule: computed inline. Pipelined: joined
    /// asynchronously from the evaluator thread — NaN until joined, and
    /// every round is joined by the time [`Leader::run`] returns its
    /// [`FedSummary`]
    pub eval_acc: f64,
    /// leader-measured wall time for the whole round (dispatch through
    /// report construction; a pipelined round does not wait for its own
    /// eval, which overlaps the next round)
    pub wall_secs: f64,
    /// the slice of `wall_secs` the leader itself spent working —
    /// report decode, FedAvg fold, eval sweep (sequential schedule
    /// only) and downlink encode. The remainder of `wall_secs` is spent
    /// waiting on workers; pipelining shrinks `leader_secs` by moving
    /// eval off-thread and overlapping decode with the barrier
    pub leader_secs: f64,
    /// per-worker simulated wall time (stragglers show here)
    pub worker_secs: Vec<f64>,
    /// per-worker host↔device ledgers for the round, sorted by worker id
    /// (broadcast upload + local steps + round-boundary sync)
    pub worker_transfer: Vec<TransferStats>,
    /// sum of `worker_transfer` — the round's fleet-wide device-bus
    /// traffic, aggregated alongside the FedAvg params
    pub device_transfer: TransferStats,
    /// the leader's own eval-sweep ledger for this round (pipelined:
    /// joined with `eval_acc`)
    pub leader_eval_transfer: TransferStats,
}

impl RoundReport {
    /// Every device-bus byte this round moved, fleet + leader eval.
    pub fn device_bytes(&self) -> u64 {
        self.device_transfer.total_bytes() + self.leader_eval_transfer.total_bytes()
    }

    /// Every network byte this round moved, both directions.
    pub fn network_bytes(&self) -> u64 {
        self.upload_bytes + self.download_bytes
    }

    /// Simulated Joules of this round's *measured* device-bus traffic at
    /// `table`'s DRAM energy point — the ledger feeds the energy model,
    /// not an analytic byte estimate.
    pub fn device_joules(&self, table: &EnergyTable) -> f64 {
        table.bus_joules(self.device_bytes())
    }

    /// Simulated Joules of this round's measured network traffic over
    /// `link` (reported next to [`RoundReport::device_joules`]).
    pub fn network_joules(&self, link: &LinkEnergy) -> f64 {
        link.joules(self.network_bytes())
    }

    /// Simulated Joules of this round's *on-device training compute*:
    /// one simulated training step of `workload` on `cfg` — with the
    /// backward-phase sparsity gating driven by the round's **measured**
    /// survivor fraction `1 − mean_sparsity` instead of the static
    /// `expected_survivor_fraction(P)` — times the fleet's executed
    /// steps this round (the sum of the worker ledgers' step counts).
    /// 0.0 on an outage round: no steps ran, no compute was spent.
    /// Reported per round next to [`RoundReport::device_joules`] /
    /// [`RoundReport::network_joules`].
    pub fn compute_joules(&self, cfg: &AccelConfig, workload: &Workload) -> f64 {
        let steps: u64 = self.worker_transfer.iter().map(|t| t.steps).sum();
        if steps == 0 || !self.mean_sparsity.is_finite() {
            return 0.0;
        }
        let survivor = (1.0 - self.mean_sparsity).clamp(0.0, 1.0);
        simulate_training(cfg, workload, survivor).total_energy_j() * steps as f64
    }
}

/// Full run summary.
#[derive(Clone, Debug)]
pub struct FedSummary {
    /// per-round reports in order (pipelined eval results all joined)
    pub rounds: Vec<RoundReport>,
    /// last round's eval accuracy
    pub final_acc: f64,
    /// total worker->leader network bytes across the run
    pub total_upload_bytes: u64,
    /// total leader->worker network bytes across the run
    pub total_download_bytes: u64,
    /// total device-bus ledger across the run (all workers' rounds plus
    /// the leader's eval sweeps)
    pub total_device_transfer: TransferStats,
}

impl FedSummary {
    fn nan_mean(values: impl Iterator<Item = f64>) -> f64 {
        let mut n = 0usize;
        let mut sum = 0.0;
        for v in values {
            if v.is_finite() {
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    /// Mean per-round loss over the rounds that measured one —
    /// fleet-wide outage rounds carry NaN and are skipped, never
    /// averaged in as zeros.
    pub fn mean_round_loss(&self) -> f64 {
        Self::nan_mean(self.rounds.iter().map(|r| r.mean_loss))
    }

    /// Mean realized gradient sparsity over the measured rounds (outage
    /// rounds skipped, like [`FedSummary::mean_round_loss`]).
    pub fn mean_round_sparsity(&self) -> f64 {
        Self::nan_mean(self.rounds.iter().map(|r| r.mean_sparsity))
    }
}

/// Per-report scalars captured at decode time, slotted by worker id so
/// both schedules aggregate them in the same order regardless of when
/// each report arrived (the update itself moves into the
/// [`StreamingAggregator`]).
#[derive(Clone, Copy)]
struct ReportMeta {
    mean_loss: f64,
    mean_sparsity: f64,
    sim_secs: f64,
    transfer: TransferStats,
    wire_bytes: u64,
    survivors: u64,
}

impl ReportMeta {
    fn of(r: &WorkerReport) -> Self {
        Self {
            mean_loss: r.mean_loss,
            mean_sparsity: r.mean_sparsity,
            sim_secs: r.sim_secs,
            transfer: r.transfer,
            wire_bytes: r.update.wire_bytes(),
            survivors: r.update.survivors(),
        }
    }
}

/// The federated leader.
pub struct Leader {
    cfg: FedConfig,
    global: ParamStore,
    /// the params every in-sync worker holds — advanced only by applying
    /// the same downlink updates the workers apply, so leader and worker
    /// replicas stay bit-identical. Compressed modes only; `dense` ships
    /// `global.params` snapshots directly.
    reference: Vec<Tensor>,
    /// per-worker: has it received every downlink so far? A worker that
    /// misses one gets a dense snapshot (and is marked in-sync again).
    in_sync: Vec<bool>,
    /// the pruned global delta computed at the end of the previous round
    /// (`None` before round 1: everyone starts from a dense snapshot)
    pending_down: Option<ModelUpdate>,
    /// downlink error-feedback codec (compressed modes): since every
    /// aggregation rebases `global` on `reference`, the codec residual
    /// is what carries un-shipped downlink mass into the next round
    down_codec: DeltaCodec,
    workers: Vec<WorkerHandle>,
    test: Dataset,
    /// the sequential schedule's eval driver. `None` under
    /// `cfg.pipeline`: the evaluator thread owns the sweep there, and a
    /// leader-side `EvalState` would only duplicate the fwd compile and
    /// the resident param-buffer allocation
    eval: Option<crate::runtime::exec::EvalState>,
    /// model spec (batch, layers for the compute-energy workload, and
    /// everything the pipelined evaluator thread needs to bring up its
    /// own replica)
    model: ModelSpec,
    /// fwd artifact — compiled again by the evaluator thread in
    /// pipelined mode (PJRT handles are not `Send`)
    fwd_art: ArtifactSpec,
}

impl Leader {
    /// Build leader + workers. Shards the synthetic dataset across
    /// workers (IID or label-skewed per config).
    pub fn new(rt: &Runtime, manifest: &Manifest, cfg: FedConfig) -> Result<Self> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        cfg.validate()?; // programmatic construction gets the same checks
        let model = manifest.model(&cfg.train.model)?.clone();
        let full = generate(&SynthConfig {
            n: cfg.train.train_examples + cfg.train.test_examples,
            difficulty: cfg.train.difficulty as f32,
            seed: cfg.train.seed,
            ..Default::default()
        });
        let (train, test) = full.split(cfg.train.train_examples);
        let shards = train.shard(cfg.workers, cfg.iid, cfg.train.seed ^ 0x5A4D);

        let tag = format!("train_{}", cfg.train.mode);
        let art = model.artifact(&tag).with_context(|| {
            format!("mode {:?} not exported for {}", cfg.train.mode, model.name)
        })?;
        let fwd_art = model.artifact("fwd")?.clone();
        // resident eval uploads the post-FedAvg params once per round
        // (fingerprint cache) instead of once per test batch. Pipelined
        // runs skip the leader-side driver entirely — the evaluator
        // thread compiles its own (one Runtime per thread)
        let eval = if cfg.pipeline {
            None
        } else {
            let eval_exe = rt.load(&fwd_art)?;
            Some(crate::runtime::exec::EvalState::new(
                rt,
                eval_exe,
                &model,
                cfg.train.eval_residency,
            )?)
        };

        let workers = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                WorkerHandle::spawn(
                    i,
                    shard,
                    art.clone(),
                    &model,
                    cfg.train.clone(),
                    cfg.comm,
                    cfg.comm_rate,
                )
            })
            .collect::<Result<Vec<_>>>()?;

        let global = ParamStore::init(&model, cfg.train.seed);
        Ok(Self {
            reference: global.params.clone(),
            in_sync: vec![false; cfg.workers],
            pending_down: None,
            down_codec: DeltaCodec::new(cfg.comm, cfg.comm_rate),
            cfg,
            global,
            workers,
            test,
            eval,
            model,
            fwd_art,
        })
    }

    /// The aggregated global parameters (current as of the last round).
    pub fn global_params(&self) -> &[Tensor] {
        &self.global.params
    }

    /// Run all rounds under the configured schedule (see the module docs
    /// for the sequential-vs-pipelined timeline; results are identical).
    pub fn run(&mut self) -> Result<FedSummary> {
        let mut rounds: Vec<RoundReport> = Vec::with_capacity(self.cfg.rounds);
        let mut straggler_rng = Rng::new(self.cfg.train.seed ^ 0x57AA);
        let mut dropout_rng = Rng::new(self.cfg.train.seed ^ 0xD50F);
        let mut downlink_rng = Rng::new(self.cfg.train.seed ^ 0xD0C0DE);
        let energy = EnergyTable::smic14();
        let link = LinkEnergy::wifi();
        // measured-survivor compute energy: the accel simulator's
        // backward-phase gating runs at each round's *realized* sparsity
        // instead of the static expected_survivor_fraction(P)
        let accel_cfg = crate::accel::config::efficientgrad();
        let workload =
            Workload::from_manifest(&self.model.name, &self.model.layers, self.model.batch);
        // pipelined schedule: the eval sweep lives on its own thread
        // (own Runtime — PJRT handles are not Send) and joins results
        // asynchronously
        let evaluator = if self.cfg.pipeline {
            Some(Evaluator::spawn(
                &self.model,
                self.fwd_art.clone(),
                self.cfg.train.eval_residency,
                self.test.clone(),
                self.cfg.train.seed,
            )?)
        } else {
            None
        };
        let mut evals_pending = 0usize;

        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            let mut leader_busy = Duration::ZERO;
            // broadcast: dense snapshots in dense mode; the pending
            // global delta to in-sync workers otherwise (dense fallback
            // for round 0 and resyncs)
            let (tx, rx) = mpsc::channel::<WorkerReport>();
            let mut dispatched_ids = Vec::with_capacity(self.workers.len());
            let mut dropped = Vec::new();
            let mut download_bytes = 0u64;
            let mut downlink_survivors = 0u64;
            let mut dense_downlinks = 0usize;
            for w in &self.workers {
                if dropout_rng.uniform() < self.cfg.dropout_prob {
                    // unreachable this round: misses the downlink, ships
                    // nothing — resync with a dense snapshot next round
                    dropped.push(w.id);
                    self.in_sync[w.id] = false;
                    continue;
                }
                let slowdown = if straggler_rng.uniform() < self.cfg.straggler_prob {
                    self.cfg.straggler_slowdown
                } else {
                    1.0
                };
                let payload = if self.cfg.comm == CommMode::Dense {
                    ModelUpdate::Dense(self.global.params.clone())
                } else if self.in_sync[w.id] && self.pending_down.is_some() {
                    self.pending_down.as_ref().unwrap().clone()
                } else {
                    self.in_sync[w.id] = true;
                    ModelUpdate::Dense(self.reference.clone())
                };
                let (wire, survivors, is_dense) =
                    (payload.wire_bytes(), payload.survivors(), payload.is_dense());
                match w.submit(WorkerTask {
                    round,
                    payload,
                    local_steps: self.cfg.local_steps,
                    slowdown,
                    sleep: self.cfg.straggler_sleep,
                    reply: tx.clone(),
                }) {
                    Ok(()) => {
                        // ledger counts delivered messages only — a
                        // dispatch failure ships nothing
                        dispatched_ids.push(w.id);
                        download_bytes += wire;
                        downlink_survivors += survivors;
                        if is_dense {
                            dense_downlinks += 1;
                        }
                    }
                    Err(e) => {
                        log::warn!("round {round}: worker {} unreachable: {e:#}", w.id);
                        dropped.push(w.id);
                        self.in_sync[w.id] = false;
                    }
                }
            }
            drop(tx);

            // gather: a worker that fails its round drops its reply
            // sender without sending, so the channel closes once every
            // dispatched task is resolved. Both schedules decode through
            // the same StreamingAggregator; they differ only in *when*
            // each report's decode runs.
            let mut agg = StreamingAggregator::new(self.cfg.comm, self.workers.len());
            let mut meta: Vec<Option<ReportMeta>> = vec![None; self.workers.len()];
            if self.cfg.pipeline {
                // streaming: decode each report the moment it arrives —
                // a straggler delays only its own decode work
                for r in rx.iter() {
                    let t = Instant::now();
                    let id = r.worker_id;
                    let m = ReportMeta::of(&r);
                    agg.accept(id, r.examples as f64, r.update)?;
                    meta[id] = Some(m);
                    leader_busy += t.elapsed();
                }
            } else {
                // sequential oracle: barrier first, then decode in
                // worker-id order — the reference schedule
                let mut reports: Vec<WorkerReport> = rx.iter().collect();
                let t = Instant::now();
                reports.sort_by_key(|r| r.worker_id);
                for r in reports {
                    let id = r.worker_id;
                    let m = ReportMeta::of(&r);
                    agg.accept(id, r.examples as f64, r.update)?;
                    meta[id] = Some(m);
                }
                leader_busy += t.elapsed();
            }
            for &id in &dispatched_ids {
                if meta[id].is_none() {
                    // went silent mid-round. Usually a failed step/sync
                    // (downlink already applied), but the failure may
                    // also have been in the apply itself — we cannot
                    // tell from here, so treat its replica as suspect
                    // and resync it with a dense snapshot next round
                    dropped.push(id);
                    self.in_sync[id] = false;
                }
            }
            dropped.sort_unstable();
            let n_reports = meta.iter().flatten().count();
            if n_reports == 0 {
                // a fleet-wide outage round: nothing to aggregate, the
                // global model stands, and the dropout record tells the
                // story — a long-running deployment must not die to it
                log::warn!(
                    "round {round}: every worker missed the round ({} dropped)",
                    dropped.len()
                );
            }

            // aggregate: fold the decoded slots in worker-id order into
            // f64 accumulators (examples-weighted FedAvg over the
            // survivors; O(nnz) per worker in the compressed modes)
            let t = Instant::now();
            if let Some(params) = agg.finish(&self.reference)? {
                self.global.params = params;
            }
            let upload_bytes: u64 = meta.iter().flatten().map(|m| m.wire_bytes).sum();
            let uplink_survivors: u64 = meta.iter().flatten().map(|m| m.survivors).sum();
            let (mean_loss, mean_sparsity) = if n_reports == 0 {
                // no measurement exists — NaN, not a fake 0.0 that would
                // poison any averaged trajectory (FedSummary skips NaN)
                (f64::NAN, f64::NAN)
            } else {
                let n = n_reports as f64;
                let loss: f64 = meta.iter().flatten().map(|m| m.mean_loss).sum();
                let spars: f64 = meta.iter().flatten().map(|m| m.mean_sparsity).sum();
                (loss / n, spars / n)
            };
            // per-worker device-bus ledgers, aggregated like the params
            let worker_transfer: Vec<TransferStats> =
                meta.iter().flatten().map(|m| m.transfer).collect();
            let device_transfer = worker_transfer
                .iter()
                .fold(TransferStats::default(), |acc, &t| acc + t);
            let worker_secs: Vec<f64> = meta.iter().flatten().map(|m| m.sim_secs).collect();

            // eval: inline on the sequential schedule; handed to the
            // evaluator thread on the pipelined one (the snapshot clone
            // is the handoff cost — the sweep overlaps round r+1)
            let (eval_acc, leader_eval_transfer) = match &evaluator {
                None => {
                    let eval = self
                        .eval
                        .as_ref()
                        .expect("sequential leader owns an EvalState");
                    eval.reset_transfer_stats();
                    let acc = eval.dataset_accuracy(&self.global, &self.test, self.model.batch)?;
                    (acc, eval.transfer_stats())
                }
                Some(ev) => {
                    ev.submit(round, self.global.params.clone())?;
                    evals_pending += 1;
                    (f64::NAN, TransferStats::default())
                }
            };

            // next round's downlink: the global delta vs the workers'
            // reference, through the same error-feedback codec as the
            // uplink; the leader advances its reference replica by the
            // *decoded* update, exactly like the workers will. The
            // carried residual is load-bearing: aggregation *rebases*
            // `global` on `reference` every round, so any downlink mass
            // the codec failed to deliver would otherwise vanish from
            // all state — the residual is the only thing that re-feeds
            // it into the next round's delta
            if self.cfg.comm != CommMode::Dense {
                let update = self.down_codec.encode(
                    &self.global.params,
                    &self.reference,
                    &mut downlink_rng,
                )?;
                update.apply(&mut self.reference)?;
                self.pending_down = Some(update);
            }
            leader_busy += t.elapsed();

            let mut report = RoundReport {
                round,
                mean_loss,
                mean_sparsity,
                upload_bytes,
                download_bytes,
                dispatched: dispatched_ids.len(),
                dropped,
                dense_downlinks,
                uplink_survivors,
                downlink_survivors,
                eval_acc,
                wall_secs: t0.elapsed().as_secs_f64(),
                leader_secs: leader_busy.as_secs_f64(),
                worker_secs,
                worker_transfer,
                device_transfer,
                leader_eval_transfer,
            };
            // pipelined: join whatever eval results are ready by now
            // (latest-available — this round's own eval may still be in
            // flight; FedSummary joins the rest)
            if let Some(ev) = &evaluator {
                for o in ev.drain_ready()? {
                    evals_pending -= 1;
                    if o.round == round {
                        report.eval_acc = o.acc;
                        report.leader_eval_transfer = o.transfer;
                    } else {
                        rounds[o.round].eval_acc = o.acc;
                        rounds[o.round].leader_eval_transfer = o.transfer;
                    }
                }
            }
            let (log_acc, acc_tag) = if report.eval_acc.is_finite() {
                (report.eval_acc, "")
            } else {
                // newest joined accuracy, marked as trailing
                (
                    rounds
                        .iter()
                        .rev()
                        .find(|r| r.eval_acc.is_finite())
                        .map(|r| r.eval_acc)
                        .unwrap_or(f64::NAN),
                    "~",
                )
            };
            log::info!(
                "round {round:3} loss {mean_loss:.4} acc {log_acc:.4}{acc_tag} \
                 sparsity {mean_sparsity:.3} net {:.1} KB ({:.1} mJ) device {:.1} KB \
                 ({:.2} mJ) compute {:.1} mJ dropped {:?} ({:.2}s, leader {:.3}s)",
                report.network_bytes() as f64 / 1e3,
                report.network_joules(&link) * 1e3,
                report.device_bytes() as f64 / 1e3,
                report.device_joules(&energy) * 1e3,
                report.compute_joules(&accel_cfg, &workload) * 1e3,
                report.dropped,
                report.wall_secs,
                report.leader_secs,
            );
            rounds.push(report);
        }
        // pipelined: every submitted round joins before the summary —
        // all eval_acc values and leader-eval ledgers are final below
        if let Some(ev) = &evaluator {
            for o in ev.wait_for(evals_pending)? {
                rounds[o.round].eval_acc = o.acc;
                rounds[o.round].leader_eval_transfer = o.transfer;
            }
        }
        drop(evaluator); // joins the eval thread

        let final_acc = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
        let total_upload_bytes = rounds.iter().map(|r| r.upload_bytes).sum();
        let total_download_bytes = rounds.iter().map(|r| r.download_bytes).sum();
        let total_device_transfer = rounds.iter().fold(TransferStats::default(), |acc, r| {
            acc + r.device_transfer + r.leader_eval_transfer
        });
        Ok(FedSummary {
            rounds,
            final_acc,
            total_upload_bytes,
            total_download_bytes,
            total_device_transfer,
        })
    }

    /// Graceful shutdown (joins worker threads).
    pub fn shutdown(self) {
        for w in self.workers {
            w.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stub_round(round: usize, loss: f64, sparsity: f64) -> RoundReport {
        RoundReport {
            round,
            mean_loss: loss,
            mean_sparsity: sparsity,
            upload_bytes: 0,
            download_bytes: 0,
            dispatched: 0,
            dropped: Vec::new(),
            dense_downlinks: 0,
            uplink_survivors: 0,
            downlink_survivors: 0,
            eval_acc: 0.0,
            wall_secs: 0.0,
            leader_secs: 0.0,
            worker_secs: Vec::new(),
            worker_transfer: Vec::new(),
            device_transfer: TransferStats::default(),
            leader_eval_transfer: TransferStats::default(),
        }
    }

    #[test]
    fn summary_averages_skip_outage_rounds() {
        let s = FedSummary {
            rounds: vec![
                stub_round(0, 1.0, 0.5),
                stub_round(1, f64::NAN, f64::NAN), // fleet-wide outage
                stub_round(2, 3.0, 0.7),
            ],
            final_acc: 0.0,
            total_upload_bytes: 0,
            total_download_bytes: 0,
            total_device_transfer: TransferStats::default(),
        };
        // the outage round is skipped, not averaged in as zeros
        assert_eq!(s.mean_round_loss(), 2.0);
        assert!((s.mean_round_sparsity() - 0.6).abs() < 1e-12);
        let all_out = FedSummary {
            rounds: vec![stub_round(0, f64::NAN, f64::NAN)],
            ..s
        };
        assert!(all_out.mean_round_loss().is_nan());
        assert!(all_out.mean_round_sparsity().is_nan());
    }

    #[test]
    fn compute_joules_gates_on_measured_survivors() {
        let cfg = crate::accel::config::efficientgrad();
        let wl = crate::accel::resnet18_cifar(4);
        let steps = TransferStats {
            steps: 10,
            ..TransferStats::default()
        };
        let mut sparse = stub_round(0, 1.0, 0.9); // 90% zeros measured
        sparse.worker_transfer = vec![steps];
        let mut dense = stub_round(0, 1.0, 0.0); // nothing pruned
        dense.worker_transfer = vec![steps];
        let js = sparse.compute_joules(&cfg, &wl);
        let jd = dense.compute_joules(&cfg, &wl);
        assert!(js > 0.0, "measured-survivor energy must be positive");
        assert!(jd > js, "sparsity gating must discount compute: {jd} vs {js}");
        // outage round: no steps ran, no compute spent
        assert_eq!(stub_round(1, f64::NAN, f64::NAN).compute_joules(&cfg, &wl), 0.0);
    }
}
