//! Federated edge-training coordinator — the L3 systems contribution.
//!
//! The paper motivates EfficientGrad with federated learning: edge devices
//! must *train locally* and ship model updates, not data (§1). This module
//! implements that deployment: a leader drives rounds of local training on
//! N simulated edge workers (std threads, each with its own data shard and
//! PJRT executables), aggregates with FedAvg, and accounts communication
//! and (via the accel simulator's energy model) on-device training energy
//! per round.
//!
//! Worker execution is genuinely concurrent: the `xla` handles are not
//! `Send`, so each worker thread brings up its own PJRT client and
//! compiles its own executable — exactly like a fleet of edge devices,
//! each with its own accelerator and its own ParamStore replica.
//!
//! The leader reaches its fleet through a swappable transport tier
//! ([`crate::net`]): in-process channels by default (`Leader::new`
//! spawns the worker threads itself), or — with `federated.listen` /
//! `--listen` — a length-prefixed TCP endpoint that remote worker
//! processes (`efficientgrad worker --connect …`) join via a versioned,
//! config-hash-checked handshake. The round protocol, fault injection,
//! and every payload byte are identical on both; the loopback-TCP run
//! is pinned bit-for-bit against the in-process run in
//! `tests/federated.rs`. The leader also polls a shutdown flag
//! ([`crate::net::signal`], armed by SIGINT/SIGTERM in `main`) at every
//! round boundary: a signalled run finishes its round, persists the run
//! store, says goodbye to its workers, and exits resumable.
//!
//! ## Round schedules
//!
//! Two leader schedules, selected by `federated.pipeline` / `--pipeline`
//! and **bit-identical in every result** (params, `eval_acc`, byte
//! ledgers — pinned in `tests/federated.rs`); they differ only in wall
//! time. Both drain the same frame-at-arrival collection loop (the fold
//! is keyed on (version, worker-id), never arrival, so any given fold
//! membership produces the same bits regardless of decode timing); the
//! flag moves exactly one thing:
//!
//! * **sequential** (default, the oracle): the full test-set eval sweep
//!   runs inline on the leader thread after each fold. Round wall time =
//!   slowest worker + all leader work.
//! * **pipelined**: the eval sweep moves to a dedicated
//!   [`evaluator::Evaluator`] thread whose results join the reports
//!   asynchronously — the leader encodes the downlink and dispatches
//!   round r+1 while accuracy computes.
//!   [`RoundReport::leader_secs`] / [`RoundReport::worker_secs`]
//!   split the round's wall time so the overlap is visible;
//!   `runtime_hotpath` benches the two schedules against each other
//!   under an injected straggler.
//!
//! Orthogonally to both, the round *barrier* itself is elastic
//! (`federated.quorum` / `--quorum`, default 1.0 = the full barrier,
//! bit-for-bit today's behavior — see `docs/TRANSFER_MODEL.md` §Model
//! versions & staleness):
//!
//! * **Versioned references.** The leader retains a bounded ring of
//!   [`versions::ModelVersion`] snapshots (version id + reference params
//!   + the encoded per-round delta); every task and report is tagged
//!   with the version it was computed against.
//! * **Quorum rounds.** At `quorum < 1.0` the leader folds as soon as
//!   `⌈quorum·dispatched⌉` reports arrive and dispatches round r+1
//!   against the new version while round r's stragglers are still in
//!   flight (pipeline depth ≥ 2); a straggler's report is folded into
//!   the round it arrives in with staleness weight `examples · λ^k`
//!   (`federated.staleness_decay`, k = versions behind), and
//!   `federated.pipeline_depth` bounds how many rounds may stay in
//!   flight — and with it the worst-case staleness k.
//! * **Chained downlinks.** A worker whose replica is `k ≤
//!   federated.max_chain` versions behind (a dropout that came back) is
//!   resynced with the *chain* of the retained per-round deltas —
//!   bit-identical to catching every downlink, `8 + Σ link` wire bytes
//!   instead of a dense `4·P` snapshot, and its error-feedback residual
//!   survives (a dense resync resets it).
//! * **Encode/eval overlap.** The O(P) downlink encode runs on its own
//!   thread between the fold and the next dispatch, overlapping the
//!   eval sweep (sequential) or the eval handoff (pipelined); the
//!   caller's RNG draw is taken on the leader thread in round order, so
//!   the encoded bits are identical to the serial schedule's.
//!
//! ## Integrity, faults, and durability
//!
//! Every wire exchange travels inside an integrity-checked envelope
//! ([`crate::comm::envelope`]): a [`Frame`] carries magic, schema
//! version, payload kind, length, and an FNV-1a checksum, and a frame
//! that fails any of those checks is *rejected, never applied* — on
//! either end of the link. Detection escalates deterministically:
//!
//! * **Corrupt uplink** (bad envelope, undecodable report, a report
//!   whose sealed `worker_id` contradicts its transport address, or a
//!   duplicate delivery): the frame is quarantined and counted in
//!   [`RoundReport::corrupt_frames`]; if that leaves the worker
//!   unreported, it is recorded in [`RoundReport::dropped`] and its
//!   replica marked unknown → dense resync at next dispatch.
//! * **Non-finite content** in a well-formed report (NaN/Inf delta
//!   values or metrics): rejected at the fold boundary and counted in
//!   [`RoundReport::rejected_reports`] — the wire was intact, so the
//!   worker's replica version tag stands.
//! * **Corrupt downlink**: the worker poisons its replica and replies
//!   [`FrameKind::Nack`]; the leader answers with ONE dense retry
//!   ([`RoundReport::downlink_retries`]), and a second rejection
//!   quarantines the worker until next round's dense resync.
//! * **Silence** (crash injection, device failure): the round's reply
//!   channel disconnecting is the signal; the worker is dropped for the
//!   round and dense-resynced when it comes back.
//!
//! All of it is drivable by a seeded, exactly-reproducible
//! [`crate::faults::FaultPlan`] (`federated.faults` / `--faults`), whose
//! decisions are pure functions of (site, round, worker, attempt) on
//! dedicated RNG streams — an all-zero plan is byte-identical to no
//! plan. For durability, `federated.run_store` persists a
//! content-addressed [`runstore::RunState`] (global params, version
//! ring, codec residual, every worker's [`worker::WorkerSnapshot`], and
//! all three leader RNG states) after every round; `--resume` restores
//! it and continues bit-for-bit against the uninterrupted run (pinned at
//! `quorum = 1.0` — in-flight stragglers at a kill point have no channel
//! to survive in). `FaultPlan::kill_round` halts the coordinator right
//! after a persist, which is how the kill/resume pin is exercised.
//!
//! The O(P) host loops both schedules share (FedAvg folds, codec
//! delta/residual passes, eq. 3 comm pruning, σ) chunk across a scoped
//! thread pool at fixed boundaries (`util::par`), which keeps them
//! deterministic while using every core.
//!
//! Transfer model: with the default resident step backend
//! (`runtime::resident`), each worker's host↔device traffic is one
//! params upload + one params/momenta download *per round*, not per
//! step. Each round carries the device-bus ledger end-to-end: every
//! worker reports its per-round [`TransferStats`], the leader sums them
//! next to the FedAvg aggregate ([`RoundReport::device_transfer`]) and
//! accounts its own eval sweep ([`RoundReport::leader_eval_transfer`]).
//!
//! The *network* tier ([`RoundReport::upload_bytes`] /
//! [`RoundReport::download_bytes`]) is measured from the actual wire
//! messages ([`crate::comm`]): with `comm = dense` both directions ship
//! full `4·P` snapshots (the legacy exchange, bit for bit); with
//! `comm = pruned|sign` workers uplink error-feedback pruned deltas, the
//! leader folds them into the global params in O(nnz)
//! ([`weighted_sparse_fedavg`]) and downlinks the global delta through
//! the same codec — dense snapshots remain only for the first round and
//! for resyncing workers that missed a downlink. The envelope's flat
//! per-frame overhead is ledgered separately
//! ([`RoundReport::envelope_bytes`]). Rounds degrade gracefully: a
//! worker that goes silent is recorded in [`RoundReport::dropped`] and
//! FedAvg re-weights over the reports that did arrive; a fleet-wide
//! outage round reports NaN means (skipped by the summary averages), not
//! fake zeros. Formulas: `docs/TRANSFER_MODEL.md`.

pub mod evaluator;
pub mod fedavg;
pub mod hierarchy;
pub mod runstore;
pub mod versions;
pub mod worker;

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::accel::energy::{EnergyTable, LinkEnergy};
use crate::accel::{simulate_training, AccelConfig, Workload};
use crate::comm::envelope::{encode_update, FRAME_HEADER_BYTES};
use crate::comm::{DeltaCodec, Frame, FrameKind, ModelUpdate};
use crate::config::{CommMode, FedConfig};
use crate::data::synthetic::{generate, SynthConfig};
use crate::data::Dataset;
use crate::faults::FaultPlan;
use crate::manifest::{ArtifactSpec, Manifest, ModelSpec};
use crate::net::tcp::TcpTransport;
use crate::net::{InProcess, Transport};
use crate::params::ParamStore;
use crate::runtime::{Runtime, TransferStats};
use crate::tensor::Tensor;
use crate::util::backoff::Backoff;
use crate::util::rng::Rng;

pub use evaluator::{EvalOutcome, Evaluator};
pub use fedavg::{fedavg, weighted_fedavg, weighted_sparse_fedavg, StreamingAggregator};
pub use hierarchy::{Hierarchy, TierStats};
pub use versions::{ModelVersion, VersionRing};
pub use worker::{
    CommSetup, LiteWorker, Worker, WorkerHandle, WorkerReport, WorkerSnapshot, WorkerTask,
};

/// Outcome of one federated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// round index (0-based)
    pub round: usize,
    /// the model version this round's fold produced (round r dispatches
    /// against version r and folds version r+1; version 0 is the shared
    /// init)
    pub version: u64,
    /// mean of the workers' mean local-step losses. **NaN** on a
    /// fleet-wide outage round (no reports arrived — there is no
    /// measurement, and a fake 0.0 would poison any averaged
    /// trajectory); the [`FedSummary`] averages skip NaN rounds
    pub mean_loss: f64,
    /// mean realized gradient sparsity across workers (NaN on an outage
    /// round, like `mean_loss`)
    pub mean_sparsity: f64,
    /// measured wire bytes shipped up (worker->leader) this round
    pub upload_bytes: u64,
    /// measured wire bytes broadcast down (leader->worker) this round
    pub download_bytes: u64,
    /// envelope overhead this round: the flat 24-byte frame header times
    /// every frame the leader sent or received (tasks, retries, reports,
    /// nacks — including duplicates and quarantined frames; a late frame
    /// lands in the round that read it). Ledgered separately from the
    /// payload bytes so the integrity tax is visible
    pub envelope_bytes: u64,
    /// transport-plane bytes this round, as seen from the leader's
    /// endpoint: message length prefixes, handshakes, heartbeats, task
    /// framing, goodbyes — every wire byte the transport tier adds on
    /// top of the payload + envelope ledgers above. Always 0 in-process
    /// (no sockets, no tax). Deliberately **excluded** from the twin-run
    /// wire family: heartbeat counts depend on wall-clock timing, so
    /// this is the one ledger field the TCP⇔in-process parity pin does
    /// not compare (`docs/TRANSFER_MODEL.md` §Transport tier)
    pub transport_bytes: u64,
    /// workers the leader dispatched a task to this round
    pub dispatched: usize,
    /// worker ids that missed a round (offline at dispatch, dispatch
    /// failure, went silent mid-round, or quarantined by an integrity
    /// check); FedAvg re-weighted over the rest. Under a quorum schedule
    /// a silent worker is recorded in the round the leader *learns* of
    /// it (its stashed straggler channel disconnecting), which may be
    /// after the round it failed in. Offline workers resync next
    /// dispatch — chained if within the `max_chain` window, dense
    /// beyond it
    pub dropped: Vec<usize>,
    /// frames this round that failed an integrity check and were
    /// quarantined instead of applied: bad envelope (checksum, magic,
    /// schema, length), undecodable payload, wrong-direction kind, a
    /// sealed `worker_id` contradicting the transport address, or a
    /// duplicate delivery
    pub corrupt_frames: usize,
    /// well-formed reports rejected at the fold boundary for non-finite
    /// content (NaN/Inf delta values or metrics). Counted separately
    /// from `corrupt_frames` because the wire was intact: the sender's
    /// replica is still version-consistent, so it keeps its version tag
    /// and is NOT dense-resynced — only its gradient was discarded
    pub rejected_reports: usize,
    /// dense retry downlinks sent in answer to worker Nacks this round.
    /// Bounded at one per worker per round: a second rejection
    /// quarantines the worker until next round's dense resync
    pub downlink_retries: usize,
    /// downlink payloads that were dense snapshots (first round, resync
    /// beyond the chain window, nack retries, or `comm = dense`); the
    /// rest were pruned deltas or chains
    pub dense_downlinks: usize,
    /// downlink payloads that were chained deltas — workers
    /// `2 ..= max_chain` versions behind replaying the rounds they
    /// missed instead of paying a dense resync
    pub chained_downlinks: usize,
    /// the sampled cohort this round dispatched to, ascending worker
    /// ids, when cohort sampling is active (`0 < sample_m < workers`);
    /// empty otherwise — every registered worker was eligible, today's
    /// pre-fleet behavior
    pub cohort: Vec<usize>,
    /// edge aggregators the fold ran through (1 = the flat path)
    pub aggregators: usize,
    /// edge→root tier uplink bytes this round: each active edge's sealed
    /// pre-folded sparse delta (`docs/TRANSFER_MODEL.md` §Fleet tier,
    /// [`crate::comm::wire::fleet_tier_bytes`]). 0 on flat rounds —
    /// there is no tier to cross
    pub tier_upload_bytes: u64,
    /// straggler reports from earlier rounds folded into THIS round's
    /// FedAvg (quorum < 1.0 only; λ = 0 discards arrive-but-unfolded).
    /// Their wire bytes, device ledgers and loss/sparsity means land in
    /// this round's accounting — arrival-time bookkeeping
    pub late_reports: usize,
    /// Σ λ^k over the folded late reports: the fresh-report weight mass
    /// the stragglers retained after staleness discounting (equals
    /// `late_reports` at λ = 1, 0.0 when none folded)
    pub stale_weight_mass: f64,
    /// surviving (nonzero) delta coordinates across all uplink messages
    /// (0 in dense mode — every element travels)
    pub uplink_survivors: u64,
    /// surviving delta coordinates summed across downlink payloads
    pub downlink_survivors: u64,
    /// global-model accuracy on the leader's test set after aggregation.
    /// Sequential schedule: computed inline. Pipelined: joined
    /// asynchronously from the evaluator thread — NaN until joined, and
    /// every round is joined by the time [`Leader::run`] returns its
    /// [`FedSummary`]
    pub eval_acc: f64,
    /// leader-measured wall time for the whole round (dispatch through
    /// report construction; a pipelined round does not wait for its own
    /// eval, which overlaps the next round)
    pub wall_secs: f64,
    /// the slice of `wall_secs` the leader itself spent working —
    /// report decode, FedAvg fold, and the eval sweep (sequential
    /// schedule only). The downlink encode runs on its own thread
    /// overlapping the eval, so only its spawn/join shows here. The
    /// remainder of `wall_secs` is spent waiting on workers; pipelining
    /// shrinks `leader_secs` by moving eval off-thread and overlapping
    /// decode with the barrier
    pub leader_secs: f64,
    /// per-worker simulated wall time (stragglers show here)
    pub worker_secs: Vec<f64>,
    /// per-worker host↔device ledgers for the round, sorted by worker id
    /// (broadcast upload + local steps + round-boundary sync)
    pub worker_transfer: Vec<TransferStats>,
    /// sum of `worker_transfer` — the round's fleet-wide device-bus
    /// traffic, aggregated alongside the FedAvg params
    pub device_transfer: TransferStats,
    /// the leader's own eval-sweep ledger for this round (pipelined:
    /// joined with `eval_acc`)
    pub leader_eval_transfer: TransferStats,
}

impl RoundReport {
    /// Every device-bus byte this round moved, fleet + leader eval.
    pub fn device_bytes(&self) -> u64 {
        self.device_transfer.total_bytes() + self.leader_eval_transfer.total_bytes()
    }

    /// Every network byte this round moved, both directions (payloads +
    /// envelope overhead + transport-plane tax), including the
    /// edge→root tier's uplinks on two-tier rounds.
    pub fn network_bytes(&self) -> u64 {
        self.upload_bytes
            + self.download_bytes
            + self.envelope_bytes
            + self.tier_upload_bytes
            + self.transport_bytes
    }

    /// Simulated Joules of this round's *measured* device-bus traffic at
    /// `table`'s DRAM energy point — the ledger feeds the energy model,
    /// not an analytic byte estimate.
    pub fn device_joules(&self, table: &EnergyTable) -> f64 {
        table.bus_joules(self.device_bytes())
    }

    /// Simulated Joules of this round's measured network traffic over
    /// `link` (reported next to [`RoundReport::device_joules`]).
    pub fn network_joules(&self, link: &LinkEnergy) -> f64 {
        link.joules(self.network_bytes())
    }

    /// Simulated Joules of this round's *on-device training compute*:
    /// one simulated training step of `workload` on `cfg` — with the
    /// backward-phase sparsity gating driven by the round's **measured**
    /// survivor fraction `1 − mean_sparsity` instead of the static
    /// `expected_survivor_fraction(P)` — times the fleet's executed
    /// steps this round (the sum of the worker ledgers' step counts).
    /// 0.0 on an outage round: no steps ran, no compute was spent.
    /// Reported per round next to [`RoundReport::device_joules`] /
    /// [`RoundReport::network_joules`].
    pub fn compute_joules(&self, cfg: &AccelConfig, workload: &Workload) -> f64 {
        let steps: u64 = self.worker_transfer.iter().map(|t| t.steps).sum();
        if steps == 0 || !self.mean_sparsity.is_finite() {
            return 0.0;
        }
        let survivor = (1.0 - self.mean_sparsity).clamp(0.0, 1.0);
        simulate_training(cfg, workload, survivor).total_energy_j() * steps as f64
    }
}

/// Full run summary.
#[derive(Clone, Debug)]
pub struct FedSummary {
    /// per-round reports in order (pipelined eval results all joined).
    /// A resumed run reports only the rounds it ran (`round` indices
    /// continue from the persisted state)
    pub rounds: Vec<RoundReport>,
    /// last round's eval accuracy
    pub final_acc: f64,
    /// total worker->leader network bytes across the run
    pub total_upload_bytes: u64,
    /// total leader->worker network bytes across the run
    pub total_download_bytes: u64,
    /// total device-bus ledger across the run (all workers' rounds plus
    /// the leader's eval sweeps)
    pub total_device_transfer: TransferStats,
}

impl FedSummary {
    fn nan_mean(values: impl Iterator<Item = f64>) -> f64 {
        let mut n = 0usize;
        let mut sum = 0.0;
        for v in values {
            if v.is_finite() {
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    /// Mean per-round loss over the rounds that measured one —
    /// fleet-wide outage rounds carry NaN and are skipped, never
    /// averaged in as zeros.
    pub fn mean_round_loss(&self) -> f64 {
        Self::nan_mean(self.rounds.iter().map(|r| r.mean_loss))
    }

    /// Mean realized gradient sparsity over the measured rounds (outage
    /// rounds skipped, like [`FedSummary::mean_round_loss`]).
    pub fn mean_round_sparsity(&self) -> f64 {
        Self::nan_mean(self.rounds.iter().map(|r| r.mean_sparsity))
    }
}

/// Per-report scalars captured at decode time, slotted by worker id so
/// every schedule aggregates them in the same order regardless of when
/// each report arrived (the update itself moves into the
/// [`StreamingAggregator`]).
#[derive(Clone, Copy)]
struct ReportMeta {
    mean_loss: f64,
    mean_sparsity: f64,
    sim_secs: f64,
    transfer: TransferStats,
    wire_bytes: u64,
    survivors: u64,
}

impl ReportMeta {
    fn of(r: &WorkerReport) -> Self {
        Self {
            mean_loss: r.mean_loss,
            mean_sparsity: r.mean_sparsity,
            sim_secs: r.sim_secs,
            transfer: r.transfer,
            wire_bytes: r.update.wire_bytes(),
            survivors: r.update.survivors(),
        }
    }
}

/// One round's mutable collection state: which dispatched workers have
/// resolved (reported, been rejected, or been quarantined), the
/// streaming fold, and the integrity/byte counters the round report
/// publishes. Lives on the stack of one `run()` round; [`handle_frame`]
/// advances it one frame at a time.
struct Gather {
    /// per-worker: this round's exchange is settled (accepted report,
    /// rejected report, or quarantine) — indexed by worker id
    resolved: Vec<bool>,
    /// per-worker dense-retry budget for the round (the escalation
    /// ladder allows exactly one). A [`Backoff`] rather than a bool so
    /// the in-process and TCP transports share one retry discipline:
    /// in-process uses the zero-delay [`Backoff::immediate`] schedule
    /// (no jitter stream consumed — bit-identical to the old latch),
    /// and the budget/delay knobs live in one place
    retry: Vec<Backoff>,
    /// accepted (folded) fresh reports
    received: usize,
    corrupt_frames: usize,
    rejected_reports: usize,
    downlink_retries: usize,
    envelope_bytes: u64,
    download_bytes: u64,
    dense_downlinks: usize,
    /// the aggregation front-end: flat (1 edge) or two-tier — either
    /// way, [`handle_frame`] routes reports through the same `accept`
    agg: Hierarchy,
    meta: Vec<Option<ReportMeta>>,
    dropped: Vec<usize>,
}

impl Gather {
    fn new(mode: CommMode, n_workers: usize, aggregators: usize) -> Self {
        Self {
            resolved: vec![false; n_workers],
            retry: vec![Backoff::immediate(1); n_workers],
            received: 0,
            corrupt_frames: 0,
            rejected_reports: 0,
            downlink_retries: 0,
            envelope_bytes: 0,
            download_bytes: 0,
            dense_downlinks: 0,
            agg: Hierarchy::new(mode, n_workers, aggregators),
            meta: vec![None; n_workers],
            dropped: Vec::new(),
        }
    }

    /// Write a worker off for the round: dropped from the fold, replica
    /// unknown → dense resync at next dispatch. No-op if its exchange
    /// already settled (then the offending frame was a duplicate and the
    /// settled outcome stands).
    fn quarantine(&mut self, wid: usize, worker_version: &mut [Option<u64>]) {
        if !self.resolved[wid] {
            self.resolved[wid] = true;
            self.dropped.push(wid);
            worker_version[wid] = None;
        }
    }
}

/// Process one uplink frame for the current round. Returns the reply
/// channel of a dense retry when the frame was a Nack with retry budget
/// left — the caller drains it to resolution before touching the main
/// channel again (the exhausted [`Backoff`] makes the nested calls
/// terminal, so recursion depth is bounded at one).
#[allow(clippy::too_many_arguments)]
fn handle_frame(
    g: &mut Gather,
    worker_version: &mut [Option<u64>],
    transport: &mut dyn Transport,
    plan: &FaultPlan,
    head_params: &[Tensor],
    round: usize,
    base_version: u64,
    local_steps: usize,
    wid: usize,
    frame: Frame,
) -> Result<Option<mpsc::Receiver<(usize, Frame)>>> {
    g.envelope_bytes += FRAME_HEADER_BYTES;
    let (kind, payload) = match frame.open() {
        Ok(x) => x,
        Err(e) => {
            log::warn!("round {round}: corrupt frame from worker {wid} quarantined: {e:#}");
            g.corrupt_frames += 1;
            g.quarantine(wid, worker_version);
            return Ok(None);
        }
    };
    match kind {
        // an Update frame is downlink-only; on the uplink it is a
        // protocol violation, not a report
        FrameKind::Update => {
            log::warn!("round {round}: worker {wid} sent an Update frame on the uplink");
            g.corrupt_frames += 1;
            g.quarantine(wid, worker_version);
            Ok(None)
        }
        FrameKind::Nack => {
            if g.resolved[wid] {
                // a nack after the exchange settled — spurious
                g.corrupt_frames += 1;
                return Ok(None);
            }
            let delay_ms = match g.retry[wid].next_delay_ms() {
                // the retry budget is spent (the dense retry was
                // rejected too): give up for the round, dense-resync at
                // next dispatch
                None => {
                    log::warn!(
                        "round {round}: worker {wid} rejected the dense retry — quarantined"
                    );
                    g.resolved[wid] = true;
                    g.dropped.push(wid);
                    worker_version[wid] = None;
                    return Ok(None);
                }
                Some(d) => d,
            };
            if delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(delay_ms));
            }
            // escalation step 1: answer the nack with a dense snapshot
            // of the reference head on a fresh reply channel. The
            // retry's slowdown/sleep are fixed at healthy — straggler
            // injection is timing-only and already drawn for the round.
            g.downlink_retries += 1;
            let payload = ModelUpdate::Dense(head_params.to_vec());
            g.download_bytes += payload.wire_bytes();
            g.dense_downlinks += 1;
            g.envelope_bytes += FRAME_HEADER_BYTES;
            let mut retry = Frame::seal(FrameKind::Update, &encode_update(&payload));
            if let Some(f) = plan.downlink(round, wid, 1) {
                plan.mutate(&mut retry, f, round, wid, 1);
            }
            let (rtx, rrx) = mpsc::channel();
            match transport.submit(wid, WorkerTask {
                round,
                version: base_version,
                frame: retry,
                local_steps,
                slowdown: 1.0,
                sleep: false,
                reply: rtx,
            }) {
                Ok(()) => Ok(Some(rrx)),
                Err(e) => {
                    log::warn!("round {round}: retry dispatch to worker {wid} failed: {e:#}");
                    g.resolved[wid] = true;
                    g.dropped.push(wid);
                    worker_version[wid] = None;
                    Ok(None)
                }
            }
        }
        FrameKind::Report => {
            let r = match WorkerReport::decode(payload) {
                Ok(r) => r,
                Err(e) => {
                    log::warn!(
                        "round {round}: undecodable report from worker {wid} quarantined: {e:#}"
                    );
                    g.corrupt_frames += 1;
                    g.quarantine(wid, worker_version);
                    return Ok(None);
                }
            };
            if g.resolved[wid] {
                // duplicate delivery of a settled exchange
                g.corrupt_frames += 1;
                return Ok(None);
            }
            if r.worker_id != wid {
                // the sealed report contradicts its transport address —
                // something forged or misrouted the frame
                log::warn!(
                    "round {round}: report sealed for worker {} arrived from worker {wid}",
                    r.worker_id
                );
                g.corrupt_frames += 1;
                g.quarantine(wid, worker_version);
                return Ok(None);
            }
            if !(r.update.all_finite() && r.mean_loss.is_finite() && r.mean_sparsity.is_finite())
            {
                // intact wire, poisoned content: folding a NaN would
                // destroy the global model. The worker's replica is
                // still version-consistent, so no resync — only its
                // contribution is discarded.
                log::warn!(
                    "round {round}: rejecting non-finite report from worker {wid} \
                     (loss {}, sparsity {})",
                    r.mean_loss,
                    r.mean_sparsity
                );
                g.rejected_reports += 1;
                g.resolved[wid] = true;
                return Ok(None);
            }
            let m = ReportMeta::of(&r);
            g.agg.accept(r.base_version, wid, r.examples as f64, r.update)?;
            g.meta[wid] = Some(m);
            g.received += 1;
            g.resolved[wid] = true;
            Ok(None)
        }
        // transport-control kinds (Task, Hello, Heartbeat, …) are
        // consumed by the transport tier and never reach the round's
        // data path — one arriving here means the peer is broken or
        // forging frames
        _ => {
            log::warn!("round {round}: worker {wid} sent a {kind:?} frame on the uplink");
            g.corrupt_frames += 1;
            g.quarantine(wid, worker_version);
            Ok(None)
        }
    }
}

/// One quorum round still awaiting straggler reports: the round's reply
/// channel plus the dispatched workers that had not reported when the
/// round closed at its quorum. Resolved by later rounds — arrivals fold
/// late with a staleness weight, a disconnect with reports still
/// outstanding means those workers failed mid-round.
struct InFlightRound {
    round: usize,
    rx: mpsc::Receiver<(usize, Frame)>,
    /// dispatched workers that had not reported at the quorum cutoff
    /// (each report carries its own `base_version` tag for the
    /// staleness weight)
    outstanding: Vec<usize>,
}

/// What the off-thread downlink encode hands back at join: the codec
/// (with its residual advanced), the encoded update, and the reference
/// params the update advances the head to.
type EncodeResult = Result<(DeltaCodec, ModelUpdate, Vec<Tensor>)>;

/// The federated leader.
pub struct Leader {
    cfg: FedConfig,
    global: ParamStore,
    /// bounded ring of version-tagged reference snapshots. The head is
    /// the params every current worker holds — advanced only by applying
    /// the same downlink updates the workers apply, so leader and worker
    /// replicas stay bit-identical; retained predecessors (and their
    /// per-round deltas) are what chained downlinks replay. Dense mode
    /// pushes snapshot-only versions so version tagging is uniform.
    ring: VersionRing,
    /// per-worker replica version: `Some(v)` = the worker holds
    /// reference version v (stale is fine — chain or resync at next
    /// dispatch); `None` = unknown/diverged (never dispatched, went
    /// silent mid-round, quarantined, or dispatch failed) → dense resync
    worker_version: Vec<Option<u64>>,
    /// downlink error-feedback codec (compressed modes): since every
    /// aggregation rebases `global` on the reference head, the codec
    /// residual is what carries un-shipped downlink mass into the next
    /// round. `None` only while an encode is in flight on the overlap
    /// thread (the thread owns it and hands it back at join).
    down_codec: Option<DeltaCodec>,
    /// the pipe to the worker fleet: in-process channels by default,
    /// a TCP endpoint under `cfg.listen` — the round protocol is
    /// transport-agnostic (`crate::net`)
    transport: Box<dyn Transport>,
    /// round-boundary shutdown flag: the process-wide signal flag by
    /// default ([`crate::net::signal`]); tests swap in a leaked local
    /// flag via [`Leader::set_stop_flag`] so they never poison other
    /// tests' leaders
    stop: &'static AtomicBool,
    test: Dataset,
    /// the sequential schedule's eval driver. `None` under
    /// `cfg.pipeline`: the evaluator thread owns the sweep there, and a
    /// leader-side `EvalState` would only duplicate the fwd compile and
    /// the resident param-buffer allocation
    eval: Option<crate::runtime::exec::EvalState>,
    /// model spec (batch, layers for the compute-energy workload, and
    /// everything the pipelined evaluator thread needs to bring up its
    /// own replica)
    model: ModelSpec,
    /// fwd artifact — compiled again by the evaluator thread in
    /// pipelined mode (PJRT handles are not `Send`)
    fwd_art: ArtifactSpec,
    /// first round `run()` will execute: 0 on a fresh run, persisted
    /// round + 1 after a resume
    start_round: usize,
    /// leader RNG streams restored from the run store (consumed by the
    /// next `run()`); `None` = fresh streams from the seed
    rng_states: Option<runstore::RngStates>,
}

impl Leader {
    /// Build leader + workers. Shards the synthetic dataset across
    /// workers (IID or label-skewed per config). With `cfg.resume`, the
    /// persisted state in `cfg.run_store` is restored before the first
    /// round — global params, version ring, codec residual, every
    /// worker's snapshot, and the leader RNG streams — after verifying
    /// the store was written by a run with an identical core config.
    pub fn new(rt: &Runtime, manifest: &Manifest, cfg: FedConfig) -> Result<Self> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        cfg.validate()?; // programmatic construction gets the same checks
        let model = manifest.model(&cfg.train.model)?.clone();
        let full = generate(&SynthConfig {
            n: cfg.train.train_examples + cfg.train.test_examples,
            difficulty: cfg.train.difficulty as f32,
            seed: cfg.train.seed,
            ..Default::default()
        });
        let (train, test) = full.split(cfg.train.train_examples);
        let shards = train.shard(cfg.workers, cfg.iid, cfg.train.seed ^ 0x5A4D);

        let tag = format!("train_{}", cfg.train.mode);
        let art = model.artifact(&tag).with_context(|| {
            format!("mode {:?} not exported for {}", cfg.train.mode, model.name)
        })?;
        let fwd_art = model.artifact("fwd")?.clone();
        // resident eval uploads the post-FedAvg params once per round
        // (fingerprint cache) instead of once per test batch. Pipelined
        // runs skip the leader-side driver entirely — the evaluator
        // thread compiles its own (one Runtime per thread)
        let eval = if cfg.pipeline {
            None
        } else {
            let eval_exe = rt.load(&fwd_art)?;
            Some(crate::runtime::exec::EvalState::new(
                rt,
                eval_exe,
                &model,
                cfg.train.eval_residency,
            )?)
        };

        // the transport decides where the fleet lives: `listen` binds a
        // TCP endpoint and waits for `efficientgrad worker --connect`
        // processes (admitted only with a matching config hash); the
        // default spawns the worker threads right here, exactly as
        // before. Remote workers build their own shard/artifact state
        // via [`spawn_edge_worker`].
        let transport: Box<dyn Transport> = match &cfg.listen {
            Some(addr) => Box::new(TcpTransport::bind(
                addr,
                cfg.workers,
                runstore::config_hash(&cfg),
                cfg.heartbeat_ms,
                cfg.round_deadline_ms,
            )?),
            None => {
                let workers = shards
                    .into_iter()
                    .enumerate()
                    .map(|(i, shard)| {
                        WorkerHandle::spawn(
                            i,
                            shard,
                            art.clone(),
                            &model,
                            cfg.train.clone(),
                            worker::CommSetup {
                                mode: cfg.comm,
                                rate: cfg.comm_rate,
                                pruner: cfg.comm_pruner,
                                quant: cfg.wire_quant,
                            },
                            cfg.faults.clone(),
                        )
                    })
                    .collect::<Result<Vec<_>>>()?;
                Box::new(InProcess::new(workers))
            }
        };

        let global = ParamStore::init(&model, cfg.train.seed);
        // retain enough history to chain a worker max_chain versions
        // behind (the chain needs the newest max_chain deltas, each
        // carried by its version entry, plus the head itself)
        let ring_cap = cfg.max_chain.max(1) + 1;
        let mut this = Self {
            ring: VersionRing::new(ring_cap, global.params.clone()),
            worker_version: vec![None; cfg.workers],
            down_codec: Some(
                DeltaCodec::with_pruner(cfg.comm, cfg.comm_rate, cfg.comm_pruner)
                    .with_quant(cfg.wire_quant),
            ),
            cfg,
            global,
            transport,
            stop: crate::net::signal::shutdown_flag(),
            test,
            eval,
            model,
            fwd_art,
            start_round: 0,
            rng_states: None,
        };
        if this.cfg.resume {
            let dir = this
                .cfg
                .run_store
                .clone()
                .ok_or_else(|| anyhow!("--resume requires federated.run_store"))?;
            this.restore(Path::new(&dir))
                .with_context(|| format!("resuming from run store {dir}"))?;
        }
        Ok(this)
    }

    /// The aggregated global parameters (current as of the last round).
    pub fn global_params(&self) -> &[Tensor] {
        &self.global.params
    }

    /// The version-tagged reference ring (telemetry / tests).
    pub fn versions(&self) -> &VersionRing {
        &self.ring
    }

    /// The bound listen address under `cfg.listen` (`None` in-process).
    /// With `--listen 127.0.0.1:0` this is how callers learn the
    /// OS-assigned port to point workers at.
    pub fn listen_addr(&self) -> Option<std::net::SocketAddr> {
        self.transport.local_addr()
    }

    /// Replace the round-boundary shutdown flag (default: the
    /// process-wide signal flag). Tests pass a `Box::leak`ed flag so
    /// exercising graceful shutdown cannot poison other tests' leaders.
    pub fn set_stop_flag(&mut self, flag: &'static AtomicBool) {
        self.stop = flag;
    }

    /// Install a persisted [`runstore::RunState`]: refuses a store whose
    /// config hash or worker count disagrees with this leader (resuming
    /// under different hyperparameters would silently produce a
    /// trajectory neither run describes).
    fn restore(&mut self, dir: &Path) -> Result<()> {
        let state = runstore::load(dir)?;
        let expect = runstore::config_hash(&self.cfg);
        if state.config_hash != expect {
            bail!(
                "run store was written under a different config \
                 (hash {:016x}, this run {expect:016x})",
                state.config_hash
            );
        }
        if state.workers.len() != self.transport.workers() {
            bail!(
                "run store has {} workers, this run {}",
                state.workers.len(),
                self.transport.workers()
            );
        }
        self.global.params = state.global;
        self.ring = VersionRing::from_versions(self.cfg.max_chain.max(1) + 1, state.versions)?;
        if let Some(c) = self.down_codec.as_mut() {
            c.set_residual(state.down_residual);
        }
        // over TCP this blocks (up to the per-worker deadline) until
        // each worker has connected and acked its snapshot — start the
        // worker processes before the resumed leader, their handshake
        // backoff rides out the window where nothing is listening yet
        for (i, p) in state.workers.iter().enumerate() {
            self.worker_version[i] = p.version;
            self.transport.restore(i, p.snap.clone())?;
        }
        self.rng_states = Some(state.rng);
        self.start_round = state.round + 1;
        log::info!(
            "resumed from {dir:?}: round {} done, continuing at {}",
            state.round,
            self.start_round
        );
        Ok(())
    }

    /// Persist the leader's cross-round state after `round` completed:
    /// every worker's snapshot (blocks behind any still-running task),
    /// the global params, version ring, downlink residual, and the
    /// passed-in RNG states.
    fn persist(&mut self, dir: &Path, round: usize, rng: runstore::RngStates) -> Result<()> {
        let mut workers = Vec::with_capacity(self.transport.workers());
        for wid in 0..self.transport.workers() {
            workers.push(runstore::WorkerPersist {
                version: self.worker_version[wid],
                snap: self.transport.capture(wid)?,
            });
        }
        let state = runstore::RunState {
            config_hash: runstore::config_hash(&self.cfg),
            round,
            rng,
            global: self.global.params.clone(),
            versions: self.ring.iter().cloned().collect(),
            down_residual: self
                .down_codec
                .as_ref()
                .map(|c| c.residual().to_vec())
                .unwrap_or_default(),
            workers,
        };
        runstore::save(dir, &state)
    }

    /// Choose worker `id`'s downlink for the version at the ring head:
    /// dense snapshots in dense mode; otherwise the per-round delta for
    /// a replica one version behind, a chain of the retained deltas for
    /// one `2 ..= max_chain` behind, and a dense resync beyond that (or
    /// when the replica state is unknown — never dispatched, silent
    /// failure, quarantine, or the needed history was evicted).
    fn downlink_payload(&self, id: usize) -> ModelUpdate {
        if self.cfg.comm == CommMode::Dense {
            return ModelUpdate::Dense(self.global.params.clone());
        }
        let head = self.ring.head();
        match self.worker_version[id] {
            Some(v) if head.version == v + 1 => match &head.delta {
                Some(us) => ModelUpdate::Delta(us.clone()),
                None => ModelUpdate::Dense(head.params.clone()),
            },
            Some(v)
                if v < head.version && (head.version - v) as usize <= self.cfg.max_chain =>
            {
                // replays the missed rounds bit-identically; falls back
                // to a snapshot if any link left the ring
                self.ring
                    .chain_from(v)
                    .unwrap_or_else(|| ModelUpdate::Dense(head.params.clone()))
            }
            _ => ModelUpdate::Dense(head.params.clone()),
        }
    }

    /// Join an off-thread downlink encode: restore the codec (its
    /// residual advanced by the encode) and push the version the encode
    /// produced onto the reference ring.
    fn join_encode(&mut self, handle: JoinHandle<EncodeResult>) -> Result<()> {
        let (codec, update, next_ref) = handle
            .join()
            .map_err(|_| anyhow!("downlink encode thread panicked"))??;
        self.down_codec = Some(codec);
        let delta = match update {
            ModelUpdate::Delta(us) => Some(us),
            _ => None,
        };
        self.ring.push(next_ref, delta);
        Ok(())
    }

    /// Run all rounds under the configured schedule (see the module docs
    /// for the sequential-vs-pipelined timeline; results are identical).
    pub fn run(&mut self) -> Result<FedSummary> {
        let start_round = self.start_round;
        let run_store = self.cfg.run_store.clone();
        let plan = self.cfg.faults.clone().unwrap_or_default();
        let mut rounds: Vec<RoundReport> =
            Vec::with_capacity(self.cfg.rounds.saturating_sub(start_round));
        // resumed streams continue exactly where the persisted run's
        // left off; fresh runs derive them from the seed as always
        let (mut straggler_rng, mut dropout_rng, mut downlink_rng, mut sample_rng) =
            match self.rng_states.take() {
                Some(s) => (
                    Rng::from_state(s.straggler),
                    Rng::from_state(s.dropout),
                    Rng::from_state(s.downlink),
                    Rng::from_state(s.sample),
                ),
                None => (
                    Rng::new(self.cfg.train.seed ^ 0x57AA),
                    Rng::new(self.cfg.train.seed ^ 0xD50F),
                    Rng::new(self.cfg.train.seed ^ 0xD0C0DE),
                    // cohort sampling; consumed ONLY when 0 < m < n, so
                    // unsampled runs never touch it
                    Rng::new(self.cfg.train.seed ^ 0xC0807),
                ),
            };
        let energy = EnergyTable::smic14();
        let link = LinkEnergy::wifi();
        // measured-survivor compute energy: the accel simulator's
        // backward-phase gating runs at each round's *realized* sparsity
        // instead of the static expected_survivor_fraction(P)
        let accel_cfg = crate::accel::config::efficientgrad();
        let workload =
            Workload::from_manifest(&self.model.name, &self.model.layers, self.model.batch);
        // pipelined schedule: the eval sweep lives on its own thread
        // (own Runtime — PJRT handles are not Send) and joins results
        // asynchronously
        let evaluator = if self.cfg.pipeline {
            Some(Evaluator::spawn(
                &self.model,
                self.fwd_art.clone(),
                self.cfg.train.eval_residency,
                self.test.clone(),
                self.cfg.train.seed,
            )?)
        } else {
            None
        };
        let mut evals_pending = 0usize;
        // downlink encode in flight on its own thread: spawned after
        // each fold (overlapping the eval), joined at the round's end
        // when the ring advances
        let mut enc_pending: Option<JoinHandle<EncodeResult>> = None;
        // quorum rounds whose stragglers are still in flight
        let mut inbox: Vec<InFlightRound> = Vec::new();

        for round in start_round..self.cfg.rounds {
            // graceful shutdown (SIGINT/SIGTERM or a test flag): checked
            // only at the round boundary, so the flag never interrupts a
            // fold mid-flight — the previous round fully drained and
            // persisted, the run store is resumable with --resume, and
            // the teardown below closes worker connections cleanly
            if self.stop.load(Ordering::SeqCst) {
                log::warn!("shutdown requested — stopping before round {round}");
                break;
            }
            let t0 = Instant::now();
            let mut leader_busy = Duration::ZERO;
            let base_version = self.ring.head_version();
            // transport-plane tax is ledgered per round as a delta of
            // the transport's cumulative counter (0 in-process)
            let plane0 = self.transport.plane_bytes();

            // broadcast: dense snapshots in dense mode; otherwise the
            // per-round delta / retained-delta chain / dense resync that
            // each worker's replica version calls for — each payload
            // sealed in an integrity-checked frame (and possibly damaged
            // right after, if the fault plan says this downlink fails)
            let (tx, rx) = mpsc::channel::<(usize, Frame)>();
            let mut g = Gather::new(self.cfg.comm, self.transport.workers(), self.cfg.aggregators);
            let mut dispatched_ids = Vec::with_capacity(self.transport.workers());
            let mut downlink_survivors = 0u64;
            let mut chained_downlinks = 0usize;
            // cohort: 0 < sample_m < n draws m worker ids per round from
            // the dedicated sample stream (sorted ascending, so the
            // dropout/straggler/downlink draws below happen in the same
            // id order as a full round). sample_m ∈ {0, n} takes the
            // full-fleet path untouched — the sample stream is never
            // consumed, bit-for-bit the pre-fleet behavior. Unsampled
            // workers just sit the round out with their replica intact:
            // the next cohort that includes them chains them forward
            // (`k ≤ max_chain`) or dense-resyncs beyond the window.
            let n = self.transport.workers();
            let sampling = self.cfg.sample_m > 0 && self.cfg.sample_m < n;
            let cohort: Vec<usize> = if sampling {
                let mut ids: Vec<usize> = sample_rng
                    .permutation(n)
                    .into_iter()
                    .take(self.cfg.sample_m)
                    .map(|i| i as usize)
                    .collect();
                ids.sort_unstable();
                ids
            } else {
                (0..n).collect()
            };
            for &wid in &cohort {
                // transport-site faults fire before the dropout draw;
                // they key on (round, wid) without touching the leader's
                // rng streams, so twin runs under the same fault plan
                // draw dropout/straggler in the same order for the same
                // ids on either transport
                if plan.disconnects(round, wid) {
                    // the fault plan severs this worker's connection: the
                    // leader sees a dead link at dispatch. In-process the
                    // sever is a no-op and the worker is simply skipped —
                    // either way its replica is intact, only stale, so
                    // the next dispatch chains or dense-resyncs it
                    self.transport.sever(wid);
                    g.dropped.push(wid);
                    continue;
                }
                if plan.partitioned(round, wid) {
                    // network partition: the link is up but unroutable
                    // this round; skip dispatch, keep the version tag
                    g.dropped.push(wid);
                    continue;
                }
                if dropout_rng.uniform() < self.cfg.dropout_prob {
                    // unreachable this round: misses the downlink, ships
                    // nothing. Its replica is intact, only *stale* — the
                    // next dispatch chains it forward if it is within the
                    // max_chain window, dense resync beyond it
                    g.dropped.push(wid);
                    continue;
                }
                let slowdown = if straggler_rng.uniform() < self.cfg.straggler_prob {
                    self.cfg.straggler_slowdown
                } else {
                    1.0
                };
                let payload = self.downlink_payload(wid);
                let (wire, survivors, is_dense, is_chain) = (
                    payload.wire_bytes(),
                    payload.survivors(),
                    payload.is_dense(),
                    payload.is_chain(),
                );
                let mut frame = Frame::seal(FrameKind::Update, &encode_update(&payload));
                if let Some(f) = plan.downlink(round, wid, 0) {
                    plan.mutate(&mut frame, f, round, wid, 0);
                }
                match self.transport.submit(
                    wid,
                    WorkerTask {
                        round,
                        version: base_version,
                        frame,
                        local_steps: self.cfg.local_steps,
                        slowdown,
                        sleep: self.cfg.straggler_sleep,
                        reply: tx.clone(),
                    },
                ) {
                    Ok(()) => {
                        // ledger counts delivered messages only — a
                        // dispatch failure ships nothing
                        dispatched_ids.push(wid);
                        self.worker_version[wid] = Some(base_version);
                        g.download_bytes += wire;
                        g.envelope_bytes += FRAME_HEADER_BYTES;
                        downlink_survivors += survivors;
                        if is_dense {
                            g.dense_downlinks += 1;
                        }
                        if is_chain {
                            chained_downlinks += 1;
                        }
                    }
                    Err(e) => {
                        log::warn!("round {round}: worker {wid} unreachable: {e:#}");
                        g.dropped.push(wid);
                        self.worker_version[wid] = None;
                    }
                }
            }
            drop(tx);

            // gather: one frame at a time through handle_frame — accept,
            // reject, quarantine, or answer a nack with a dense retry
            // whose fresh channel is drained to resolution inline. A
            // worker that fails its round drops its reply sender without
            // sending, so the channel closes once every dispatched task
            // is resolved. At quorum = 1.0 that close is the only exit
            // (the full barrier — today's oracle — and it drains
            // duplicate frames deterministically); at quorum < 1.0 the
            // leader stops once ⌈quorum·dispatched⌉ reports are in and
            // stashes the round's channel for the stragglers.
            let quorum_needed = if self.cfg.quorum >= 1.0 {
                dispatched_ids.len()
            } else {
                ((self.cfg.quorum * dispatched_ids.len() as f64).ceil() as usize)
                    .clamp(usize::from(!dispatched_ids.is_empty()), dispatched_ids.len())
            };
            let full_barrier = self.cfg.quorum >= 1.0;
            let mut channel_closed = false;
            let local_steps = self.cfg.local_steps;
            let mut late_busy = Duration::ZERO;
            let mut late_meta: Vec<(u64, usize, ReportMeta)> = Vec::new();
            let mut late_reports = 0usize;
            let mut stale_weight_mass = 0.0f64;
            {
                let transport: &mut dyn Transport = &mut *self.transport;
                let n_live = transport.workers();
                let worker_version = &mut self.worker_version;
                let head_params: &[Tensor] = &self.ring.head().params;
                while full_barrier || g.received < quorum_needed {
                    match rx.recv() {
                        Ok((wid, frame)) => {
                            if wid >= n_live {
                                g.corrupt_frames += 1;
                                continue;
                            }
                            // slow-reader fault: the leader's read of this
                            // worker's uplink stalls. Injected at the same
                            // site for both transports, after the bounds
                            // check and before any ledgering
                            let lag = plan.slow_read_ms(round, wid);
                            if lag > 0 {
                                std::thread::sleep(Duration::from_millis(lag));
                            }
                            let t = Instant::now();
                            let retry_rx = handle_frame(
                                &mut g,
                                worker_version,
                                transport,
                                &plan,
                                head_params,
                                round,
                                base_version,
                                local_steps,
                                wid,
                                frame,
                            )?;
                            leader_busy += t.elapsed();
                            if let Some(rrx) = retry_rx {
                                // drain the retry channel to resolution
                                // before touching the main channel again
                                // (the bounded per-worker retry budget
                                // makes these calls terminal once spent
                                // — no unbounded nested retries)
                                while let Ok((rwid, rframe)) = rrx.recv() {
                                    let t = Instant::now();
                                    handle_frame(
                                        &mut g,
                                        worker_version,
                                        transport,
                                        &plan,
                                        head_params,
                                        round,
                                        base_version,
                                        local_steps,
                                        rwid,
                                        rframe,
                                    )?;
                                    leader_busy += t.elapsed();
                                }
                                if !g.resolved[wid] {
                                    // silent during the retry (crash
                                    // injection / device failure)
                                    g.resolved[wid] = true;
                                    g.dropped.push(wid);
                                    worker_version[wid] = None;
                                }
                            }
                        }
                        Err(_) => {
                            channel_closed = true;
                            break;
                        }
                    }
                }
                if channel_closed {
                    for &id in &dispatched_ids {
                        if !g.resolved[id] {
                            // went silent mid-round: failed step/sync,
                            // crash injection, or a rejected downlink it
                            // never even nacked — the replica state is
                            // unknowable from here, dense-resync it
                            g.resolved[id] = true;
                            g.dropped.push(id);
                            worker_version[id] = None;
                        }
                    }
                } else if g.received < dispatched_ids.len() {
                    // quorum cutoff: the unresolved rest are stragglers,
                    // not failures — keep the round's channel and fold
                    // their reports into a later round with a staleness
                    // discount
                    let outstanding: Vec<usize> = dispatched_ids
                        .iter()
                        .copied()
                        .filter(|&id| !g.resolved[id])
                        .collect();
                    if !outstanding.is_empty() {
                        inbox.push(InFlightRound {
                            round,
                            rx,
                            outstanding,
                        });
                    }
                }

                // late straggler frames: same integrity gauntlet as fresh
                // ones (envelope, decode, address, finiteness), then fold
                // what passed — blocking on rounds older than the
                // pipeline depth — each weighted examples · λ^k. Which
                // round a late report lands in depends on when it arrives
                // (this is genuinely asynchronous); the fold for any
                // given membership is deterministic because the
                // aggregator keys on (version, worker-id), never arrival.
                // Only per-frame work lands in leader_busy — a blocking
                // wait on an overdue straggler is time spent waiting on
                // workers, which leader_secs must not claim. A late Nack
                // gets no retry: the round it rejected is long folded, so
                // the worker is quarantined until next dispatch.
                let mut inbox_err: Option<anyhow::Error> = None;
                {
                    let depth = self.cfg.pipeline_depth;
                    let lambda = self.cfg.staleness_decay;
                    let g = &mut g;
                    inbox.retain_mut(|inflight| {
                        if inflight.round == round {
                            // stashed moments ago by THIS round's quorum
                            // cutoff: its stragglers fold no earlier than
                            // next round (k ≥ 1 by construction)
                            return true;
                        }
                        let overdue = inflight.round + depth <= round;
                        loop {
                            let msg = if overdue {
                                inflight
                                    .rx
                                    .recv()
                                    .map_err(|_| mpsc::TryRecvError::Disconnected)
                            } else {
                                inflight.rx.try_recv()
                            };
                            match msg {
                                Ok((wid, frame)) => {
                                    let t = Instant::now();
                                    g.envelope_bytes += FRAME_HEADER_BYTES;
                                    if !inflight.outstanding.contains(&wid) {
                                        // duplicate or misrouted frame on
                                        // a settled slot
                                        g.corrupt_frames += 1;
                                        late_busy += t.elapsed();
                                        continue;
                                    }
                                    let mut bad = false;
                                    match frame.open() {
                                        Err(e) => {
                                            log::warn!(
                                                "round {round}: corrupt late frame from \
                                                 worker {wid} quarantined: {e:#}"
                                            );
                                            g.corrupt_frames += 1;
                                            bad = true;
                                        }
                                        Ok((FrameKind::Update, _)) => {
                                            g.corrupt_frames += 1;
                                            bad = true;
                                        }
                                        Ok((FrameKind::Nack, _)) => {
                                            log::warn!(
                                                "round {round}: late nack from worker \
                                                 {wid} — quarantined until next dispatch"
                                            );
                                            bad = true;
                                        }
                                        Ok((FrameKind::Report, payload)) => {
                                            match WorkerReport::decode(payload) {
                                                Err(e) => {
                                                    log::warn!(
                                                        "round {round}: undecodable late \
                                                         report from worker {wid}: {e:#}"
                                                    );
                                                    g.corrupt_frames += 1;
                                                    bad = true;
                                                }
                                                Ok(r) if r.worker_id != wid => {
                                                    g.corrupt_frames += 1;
                                                    bad = true;
                                                }
                                                Ok(r)
                                                    if !(r.update.all_finite()
                                                        && r.mean_loss.is_finite()
                                                        && r.mean_sparsity.is_finite()) =>
                                                {
                                                    // intact wire — the
                                                    // version tag stands,
                                                    // no resync
                                                    g.rejected_reports += 1;
                                                    inflight
                                                        .outstanding
                                                        .retain(|&o| o != wid);
                                                }
                                                Ok(r) => {
                                                    inflight
                                                        .outstanding
                                                        .retain(|&o| o != wid);
                                                    let k = base_version
                                                        .saturating_sub(r.base_version)
                                                        .max(1);
                                                    let weight = lambda.powi(k as i32);
                                                    if weight > 0.0 {
                                                        let m = ReportMeta::of(&r);
                                                        if let Err(e) = g.agg.accept(
                                                            r.base_version,
                                                            wid,
                                                            r.examples as f64 * weight,
                                                            r.update,
                                                        ) {
                                                            inbox_err = Some(e);
                                                            return false;
                                                        }
                                                        late_meta.push((
                                                            r.base_version,
                                                            wid,
                                                            m,
                                                        ));
                                                        late_reports += 1;
                                                        stale_weight_mass += weight;
                                                    } else {
                                                        // λ = 0: resolves
                                                        // the straggler,
                                                        // too stale to
                                                        // fold
                                                        log::debug!(
                                                            "round {round}: discarding \
                                                             fully-stale report from \
                                                             worker {wid} (k = {k})"
                                                        );
                                                    }
                                                }
                                            }
                                        }
                                    }
                                    if bad {
                                        inflight.outstanding.retain(|&o| o != wid);
                                        g.dropped.push(wid);
                                        worker_version[wid] = None;
                                    }
                                    late_busy += t.elapsed();
                                    if inflight.outstanding.is_empty() {
                                        return false;
                                    }
                                }
                                Err(mpsc::TryRecvError::Empty) => return true,
                                Err(mpsc::TryRecvError::Disconnected) => {
                                    // the round's tasks all resolved but
                                    // these workers never reported:
                                    // failed mid-round
                                    for &id in &inflight.outstanding {
                                        g.dropped.push(id);
                                        worker_version[id] = None;
                                    }
                                    return false;
                                }
                            }
                        }
                    });
                }
                if let Some(e) = inbox_err {
                    return Err(e);
                }
            }
            // fold key order, so the ledger sums below are deterministic
            // for a given membership
            late_meta.sort_by_key(|&(v, id, _)| (v, id));
            leader_busy += late_busy;

            let Gather {
                agg,
                meta,
                mut dropped,
                corrupt_frames,
                rejected_reports,
                downlink_retries,
                envelope_bytes,
                download_bytes,
                dense_downlinks,
                ..
            } = g;
            dropped.sort_unstable();
            dropped.dedup();
            let n_fresh = meta.iter().flatten().count();
            let n_reports = n_fresh + late_reports;
            if n_reports == 0 {
                // a fleet-wide outage round: nothing to aggregate, the
                // global model stands, and the dropout record tells the
                // story — a long-running deployment must not die to it
                log::warn!(
                    "round {round}: every worker missed the round ({} dropped)",
                    dropped.len()
                );
            }

            // aggregate: fold the decoded slots in (version, worker-id)
            // order into f64 accumulators (examples-weighted FedAvg over
            // the survivors, stale reports λ^k-discounted; O(nnz) per
            // worker in the compressed modes)
            let t = Instant::now();
            let n_aggregators = agg.edges();
            let (folded_params, tier) = agg.finish(&self.ring.head().params)?;
            if let Some(params) = folded_params {
                self.global.params = params;
            }
            // per-round scalars and ledgers: fresh reports in worker-id
            // order, then late reports in (version, id) order — arrival-
            // time accounting (a late report's bytes and device ledger
            // land in the round that folded it)
            let folded = || {
                let fresh = meta.iter().flatten();
                fresh.chain(late_meta.iter().map(|(_, _, m)| m))
            };
            let upload_bytes: u64 = folded().map(|m| m.wire_bytes).sum();
            let uplink_survivors: u64 = folded().map(|m| m.survivors).sum();
            let (mean_loss, mean_sparsity) = if n_reports == 0 {
                // no measurement exists — NaN, not a fake 0.0 that would
                // poison any averaged trajectory (FedSummary skips NaN)
                (f64::NAN, f64::NAN)
            } else {
                let n = n_reports as f64;
                let loss: f64 = folded().map(|m| m.mean_loss).sum();
                let spars: f64 = folded().map(|m| m.mean_sparsity).sum();
                (loss / n, spars / n)
            };
            // per-worker device-bus ledgers, aggregated like the params
            let worker_transfer: Vec<TransferStats> = folded().map(|m| m.transfer).collect();
            let device_transfer = worker_transfer
                .iter()
                .fold(TransferStats::default(), |acc, &t| acc + t);
            let worker_secs: Vec<f64> = folded().map(|m| m.sim_secs).collect();

            // next round's downlink, off-thread: the global delta vs the
            // reference head, through the same error-feedback codec as
            // the uplink; the thread advances the reference by the
            // *decoded* update, exactly like the workers will. The
            // carried residual is load-bearing: aggregation *rebases*
            // `global` on the reference every round, so any downlink
            // mass the codec failed to deliver would otherwise vanish
            // from all state — the residual is the only thing that
            // re-feeds it into the next round's delta. The encode
            // overlaps the eval below; its RNG position is taken here,
            // on the leader thread, in round order, so the encoded bits
            // match the serial schedule's exactly.
            if self.cfg.comm != CommMode::Dense {
                let mut codec = self
                    .down_codec
                    .take()
                    .expect("downlink codec home between encodes");
                let global = self.global.params.clone();
                let reference = self.ring.head().params.clone();
                let mut rng = downlink_rng.clone();
                let _ = downlink_rng.next_u64(); // the thread consumes exactly this draw
                enc_pending = Some(
                    std::thread::Builder::new()
                        .name("downlink-encode".into())
                        .spawn(move || -> EncodeResult {
                            let update = codec.encode(&global, &reference, &mut rng)?;
                            let mut next_ref = reference;
                            update.apply(&mut next_ref)?;
                            Ok((codec, update, next_ref))
                        })
                        .map_err(|e| anyhow!("spawning downlink encode: {e}"))?,
                );
            }
            leader_busy += t.elapsed();

            // eval: inline on the sequential schedule (the encode thread
            // overlaps this sweep); handed to the evaluator thread on
            // the pipelined one (the snapshot clone is the handoff cost
            // — the sweep overlaps round r+1)
            let t = Instant::now();
            let (eval_acc, leader_eval_transfer) = match &evaluator {
                None => {
                    let eval = self
                        .eval
                        .as_ref()
                        .expect("sequential leader owns an EvalState");
                    eval.reset_transfer_stats();
                    let acc = eval.dataset_accuracy(&self.global, &self.test, self.model.batch)?;
                    (acc, eval.transfer_stats())
                }
                Some(ev) => {
                    ev.submit(round, self.global.params.clone())?;
                    evals_pending += 1;
                    (f64::NAN, TransferStats::default())
                }
            };
            leader_busy += t.elapsed();

            let transport_bytes = self.transport.plane_bytes().saturating_sub(plane0);
            let mut report = RoundReport {
                round,
                version: base_version + 1,
                mean_loss,
                mean_sparsity,
                upload_bytes,
                download_bytes,
                envelope_bytes,
                transport_bytes,
                dispatched: dispatched_ids.len(),
                dropped,
                corrupt_frames,
                rejected_reports,
                downlink_retries,
                dense_downlinks,
                chained_downlinks,
                cohort: if sampling { cohort } else { Vec::new() },
                aggregators: n_aggregators,
                tier_upload_bytes: tier.tier_upload_bytes,
                late_reports,
                stale_weight_mass,
                uplink_survivors,
                downlink_survivors,
                eval_acc,
                wall_secs: t0.elapsed().as_secs_f64(),
                leader_secs: leader_busy.as_secs_f64(),
                worker_secs,
                worker_transfer,
                device_transfer,
                leader_eval_transfer,
            };
            // pipelined: join whatever eval results are ready by now
            // (latest-available — this round's own eval may still be in
            // flight; FedSummary joins the rest)
            if let Some(ev) = &evaluator {
                for o in ev.drain_ready()? {
                    evals_pending -= 1;
                    if o.round == round {
                        report.eval_acc = o.acc;
                        report.leader_eval_transfer = o.transfer;
                    } else {
                        rounds[o.round - start_round].eval_acc = o.acc;
                        rounds[o.round - start_round].leader_eval_transfer = o.transfer;
                    }
                }
            }
            let (log_acc, acc_tag) = if report.eval_acc.is_finite() {
                (report.eval_acc, "")
            } else {
                // newest joined accuracy, marked as trailing
                (
                    rounds
                        .iter()
                        .rev()
                        .find(|r| r.eval_acc.is_finite())
                        .map(|r| r.eval_acc)
                        .unwrap_or(f64::NAN),
                    "~",
                )
            };
            log::info!(
                "round {round:3} v{} loss {mean_loss:.4} acc {log_acc:.4}{acc_tag} \
                 sparsity {mean_sparsity:.3} net {:.1} KB ({:.1} mJ) device {:.1} KB \
                 ({:.2} mJ) compute {:.1} mJ dropped {:?} late {} ({:.2}s, leader {:.3}s)",
                report.version,
                report.network_bytes() as f64 / 1e3,
                report.network_joules(&link) * 1e3,
                report.device_bytes() as f64 / 1e3,
                report.device_joules(&energy) * 1e3,
                report.compute_joules(&accel_cfg, &workload) * 1e3,
                report.dropped,
                report.late_reports,
                report.wall_secs,
                report.leader_secs,
            );
            rounds.push(report);

            // advance the reference ring to the version the next round
            // trains against: join the off-thread encode (compressed
            // modes — it overlapped the eval above) or snapshot the
            // global (dense). Runs on the final round too, so persisted
            // state always has the codec residual home and the ring head
            // at the folded version.
            if let Some(handle) = enc_pending.take() {
                self.join_encode(handle)?;
            } else if self.cfg.comm == CommMode::Dense {
                self.ring.push(self.global.params.clone(), None);
            }

            // durability: persist a resumable snapshot at the round
            // boundary (worker capture blocks behind any straggler task
            // still running — allowed; the resume pin is scoped to
            // quorum = 1.0, where the round left nothing in flight)
            if let Some(dir) = &run_store {
                let rng = runstore::RngStates {
                    dropout: dropout_rng.state(),
                    straggler: straggler_rng.state(),
                    downlink: downlink_rng.state(),
                    sample: sample_rng.state(),
                };
                self.persist(Path::new(dir), round, rng)
                    .with_context(|| format!("persisting run state to {dir}"))?;
            }

            // coordinator kill injection: halt right after the persist —
            // exactly the crash the resume path must recover from
            if plan.kill_round == Some(round) {
                log::warn!("round {round}: coordinator kill point — halting run");
                break;
            }
        }
        // quorum teardown: stragglers still in flight at run end have no
        // later round to fold into — their reports are dropped on the
        // floor (the workers' sends fail silently and the threads idle
        // until shutdown), exactly what a real deployment tearing down
        // mid-round would do
        drop(inbox);

        // pipelined: every submitted round joins before the summary —
        // all eval_acc values and leader-eval ledgers are final below
        if let Some(ev) = &evaluator {
            for o in ev.wait_for(evals_pending)? {
                rounds[o.round - start_round].eval_acc = o.acc;
                rounds[o.round - start_round].leader_eval_transfer = o.transfer;
            }
        }
        drop(evaluator); // joins the eval thread

        let final_acc = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
        let total_upload_bytes = rounds.iter().map(|r| r.upload_bytes).sum();
        let total_download_bytes = rounds.iter().map(|r| r.download_bytes).sum();
        let total_device_transfer = rounds.iter().fold(TransferStats::default(), |acc, r| {
            acc + r.device_transfer + r.leader_eval_transfer
        });
        Ok(FedSummary {
            rounds,
            final_acc,
            total_upload_bytes,
            total_download_bytes,
            total_device_transfer,
        })
    }

    /// Graceful shutdown: in-process this joins the worker threads; over
    /// TCP it sends goodbye frames and closes every connection.
    pub fn shutdown(mut self) {
        self.transport.shutdown();
    }
}

/// Build the worker a remote process would run for slot `id` of a
/// federated config — the same shard, artifact, and comm setup the
/// in-process path spawns, so a TCP fleet trains bit-for-bit the run
/// the leader would have produced locally. Both sides regenerate the
/// dataset from the seeded recipe; only config (hash-checked at the
/// handshake) has to agree, never data files.
pub fn spawn_edge_worker(manifest: &Manifest, cfg: &FedConfig, id: usize) -> Result<WorkerHandle> {
    if id >= cfg.workers {
        bail!("worker id {id} out of range (fleet of {})", cfg.workers);
    }
    cfg.validate()?;
    let model = manifest.model(&cfg.train.model)?.clone();
    let full = generate(&SynthConfig {
        n: cfg.train.train_examples + cfg.train.test_examples,
        difficulty: cfg.train.difficulty as f32,
        seed: cfg.train.seed,
        ..Default::default()
    });
    let (train, _test) = full.split(cfg.train.train_examples);
    let shard = train
        .shard(cfg.workers, cfg.iid, cfg.train.seed ^ 0x5A4D)
        .into_iter()
        .nth(id)
        .expect("shard() yields cfg.workers shards");
    let tag = format!("train_{}", cfg.train.mode);
    let art = model
        .artifact(&tag)
        .with_context(|| format!("mode {:?} not exported for {}", cfg.train.mode, model.name))?;
    WorkerHandle::spawn(
        id,
        shard,
        art.clone(),
        &model,
        cfg.train.clone(),
        worker::CommSetup {
            mode: cfg.comm,
            rate: cfg.comm_rate,
            pruner: cfg.comm_pruner,
            quant: cfg.wire_quant,
        },
        cfg.faults.clone(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stub_round(round: usize, loss: f64, sparsity: f64) -> RoundReport {
        RoundReport {
            round,
            version: round as u64 + 1,
            mean_loss: loss,
            mean_sparsity: sparsity,
            upload_bytes: 0,
            download_bytes: 0,
            envelope_bytes: 0,
            transport_bytes: 0,
            dispatched: 0,
            dropped: Vec::new(),
            corrupt_frames: 0,
            rejected_reports: 0,
            downlink_retries: 0,
            dense_downlinks: 0,
            chained_downlinks: 0,
            cohort: Vec::new(),
            aggregators: 1,
            tier_upload_bytes: 0,
            late_reports: 0,
            stale_weight_mass: 0.0,
            uplink_survivors: 0,
            downlink_survivors: 0,
            eval_acc: 0.0,
            wall_secs: 0.0,
            leader_secs: 0.0,
            worker_secs: Vec::new(),
            worker_transfer: Vec::new(),
            device_transfer: TransferStats::default(),
            leader_eval_transfer: TransferStats::default(),
        }
    }

    #[test]
    fn summary_averages_skip_outage_rounds() {
        let s = FedSummary {
            rounds: vec![
                stub_round(0, 1.0, 0.5),
                stub_round(1, f64::NAN, f64::NAN), // fleet-wide outage
                stub_round(2, 3.0, 0.7),
            ],
            final_acc: 0.0,
            total_upload_bytes: 0,
            total_download_bytes: 0,
            total_device_transfer: TransferStats::default(),
        };
        // the outage round is skipped, not averaged in as zeros
        assert_eq!(s.mean_round_loss(), 2.0);
        assert!((s.mean_round_sparsity() - 0.6).abs() < 1e-12);
        let all_out = FedSummary {
            rounds: vec![stub_round(0, f64::NAN, f64::NAN)],
            ..s
        };
        assert!(all_out.mean_round_loss().is_nan());
        assert!(all_out.mean_round_sparsity().is_nan());
    }

    #[test]
    fn compute_joules_gates_on_measured_survivors() {
        let cfg = crate::accel::config::efficientgrad();
        let wl = crate::accel::resnet18_cifar(4);
        let steps = TransferStats {
            steps: 10,
            ..TransferStats::default()
        };
        let mut sparse = stub_round(0, 1.0, 0.9); // 90% zeros measured
        sparse.worker_transfer = vec![steps];
        let mut dense = stub_round(0, 1.0, 0.0); // nothing pruned
        dense.worker_transfer = vec![steps];
        let js = sparse.compute_joules(&cfg, &wl);
        let jd = dense.compute_joules(&cfg, &wl);
        assert!(js > 0.0, "measured-survivor energy must be positive");
        assert!(jd > js, "sparsity gating must discount compute: {jd} vs {js}");
        // outage round: no steps ran, no compute spent
        assert_eq!(stub_round(1, f64::NAN, f64::NAN).compute_joules(&cfg, &wl), 0.0);
    }

    // --- handle_frame: the per-frame integrity state machine. The Nack
    // arm needs a live worker to dispatch a retry to, so these tests
    // exercise the other arms (the retry/escalation path is covered
    // end-to-end in tests/federated.rs, artifact-gated).

    fn stub_report(worker_id: usize) -> WorkerReport {
        WorkerReport {
            worker_id,
            round: 0,
            base_version: 0,
            update: ModelUpdate::Dense(vec![]),
            examples: 8,
            mean_loss: 0.5,
            mean_sparsity: 0.25,
            sim_secs: 0.0,
            transfer: TransferStats::default(),
        }
    }

    fn feed(
        g: &mut Gather,
        wv: &mut [Option<u64>],
        wid: usize,
        frame: Frame,
    ) -> Result<Option<mpsc::Receiver<(usize, Frame)>>> {
        let plan = FaultPlan::default();
        // a workerless transport: nack retries fall straight through the
        // submit-failure path, which these tests never exercise
        let mut transport = InProcess::new(Vec::<WorkerHandle>::new());
        handle_frame(g, wv, &mut transport, &plan, &[], 0, 0, 1, wid, frame)
    }

    #[test]
    fn corrupt_frame_is_quarantined_not_applied() {
        let mut g = Gather::new(CommMode::Dense, 2, 1);
        let mut wv = vec![Some(0u64); 2];
        let mut frame = Frame::seal(FrameKind::Report, &stub_report(0).encode());
        let n = frame.as_bytes().len();
        frame.bytes_mut()[n - 1] ^= 0xA5; // payload damage
        feed(&mut g, &mut wv, 0, frame).unwrap();
        assert_eq!(g.corrupt_frames, 1);
        assert_eq!(g.received, 0);
        assert_eq!(g.dropped, vec![0]);
        assert_eq!(wv[0], None, "quarantine forgets the replica version");
        assert_eq!(wv[1], Some(0), "other workers untouched");
        assert_eq!(g.envelope_bytes, FRAME_HEADER_BYTES);
    }

    #[test]
    fn wrong_kind_and_misaddressed_frames_are_quarantined() {
        let mut g = Gather::new(CommMode::Dense, 3, 1);
        let mut wv = vec![Some(0u64); 3];
        // an Update frame has no business on the uplink
        let up = Frame::seal(FrameKind::Update, &encode_update(&ModelUpdate::Dense(vec![])));
        feed(&mut g, &mut wv, 1, up).unwrap();
        assert_eq!((g.corrupt_frames, wv[1]), (1, None));
        // a sealed report contradicting its transport address
        let forged = Frame::seal(FrameKind::Report, &stub_report(0).encode());
        feed(&mut g, &mut wv, 2, forged).unwrap();
        assert_eq!((g.corrupt_frames, wv[2]), (2, None));
        assert_eq!(g.received, 0);
        let mut dropped = g.dropped.clone();
        dropped.sort_unstable();
        assert_eq!(dropped, vec![1, 2]);
    }

    #[test]
    fn non_finite_reports_reject_without_resync() {
        let mut g = Gather::new(CommMode::Dense, 1, 1);
        let mut wv = vec![Some(3u64)];
        let mut r = stub_report(0);
        r.mean_loss = f64::NAN;
        feed(&mut g, &mut wv, 0, Frame::seal(FrameKind::Report, &r.encode())).unwrap();
        assert_eq!(g.rejected_reports, 1);
        assert_eq!(g.corrupt_frames, 0, "the wire was intact");
        assert_eq!(g.received, 0, "a rejected report never folds");
        assert!(g.dropped.is_empty(), "rejection is not a drop");
        assert!(g.resolved[0], "the exchange is settled");
        assert_eq!(wv[0], Some(3), "replica version tag stands — no dense resync");
    }

    #[test]
    fn duplicate_delivery_counts_but_keeps_first_outcome() {
        let mut g = Gather::new(CommMode::Dense, 1, 1);
        let mut wv = vec![Some(0u64)];
        let frame = Frame::seal(FrameKind::Report, &stub_report(0).encode());
        feed(&mut g, &mut wv, 0, frame.clone()).unwrap();
        assert_eq!((g.received, g.corrupt_frames), (1, 0));
        feed(&mut g, &mut wv, 0, frame).unwrap();
        assert_eq!(g.received, 1, "the duplicate must not fold twice");
        assert_eq!(g.corrupt_frames, 1);
        assert!(g.dropped.is_empty(), "first outcome stands");
        assert_eq!(wv[0], Some(0));
        // a spurious nack after settlement is counted the same way
        feed(&mut g, &mut wv, 0, Frame::seal(FrameKind::Nack, &[])).unwrap();
        assert_eq!(g.corrupt_frames, 2);
        assert_eq!(g.envelope_bytes, 3 * FRAME_HEADER_BYTES);
    }
}
