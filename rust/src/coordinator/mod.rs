//! Federated edge-training coordinator — the L3 systems contribution.
//!
//! The paper motivates EfficientGrad with federated learning: edge devices
//! must *train locally* and ship model updates, not data (§1). This module
//! implements that deployment: a leader drives rounds of local training on
//! N simulated edge workers (std threads, each with its own data shard and
//! PJRT executables), aggregates with FedAvg, and accounts communication
//! and (via the accel simulator's energy model) on-device training energy
//! per round.
//!
//! Worker execution is genuinely concurrent: the `xla` handles are not
//! `Send`, so each worker thread brings up its own PJRT client and
//! compiles its own executable — exactly like a fleet of edge devices,
//! each with its own accelerator and its own ParamStore replica.
//!
//! Transfer model: with the default resident step backend
//! (`runtime::resident`), each worker's host↔device traffic is one
//! params upload + one params/momenta download *per round*, not per
//! step; the leader's network accounting (`RoundReport::upload_bytes`)
//! is unchanged — residency moves bytes off the device bus, the
//! federated uplink was already per-round.

pub mod fedavg;
pub mod worker;

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::FedConfig;
use crate::data::synthetic::{generate, SynthConfig};
use crate::data::Dataset;
use crate::manifest::Manifest;
use crate::params::ParamStore;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

pub use fedavg::{fedavg, weighted_fedavg};
pub use worker::{WorkerHandle, WorkerReport, WorkerTask};

/// Outcome of one federated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: usize,
    pub mean_loss: f64,
    pub mean_sparsity: f64,
    /// bytes shipped up (worker->leader) this round
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub eval_acc: f64,
    pub wall_secs: f64,
    /// per-worker simulated wall time (stragglers show here)
    pub worker_secs: Vec<f64>,
}

/// Full run summary.
#[derive(Clone, Debug)]
pub struct FedSummary {
    pub rounds: Vec<RoundReport>,
    pub final_acc: f64,
    pub total_upload_bytes: u64,
    pub total_download_bytes: u64,
}

/// The federated leader.
pub struct Leader {
    cfg: FedConfig,
    global: ParamStore,
    workers: Vec<WorkerHandle>,
    test: Dataset,
    eval: crate::runtime::exec::EvalState,
    model_batch: usize,
}

impl Leader {
    /// Build leader + workers. Shards the synthetic dataset across
    /// workers (IID or label-skewed per config).
    pub fn new(rt: &Runtime, manifest: &Manifest, cfg: FedConfig) -> Result<Self> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        let model = manifest.model(&cfg.train.model)?.clone();
        let full = generate(&SynthConfig {
            n: cfg.train.train_examples + cfg.train.test_examples,
            difficulty: cfg.train.difficulty as f32,
            seed: cfg.train.seed,
            ..Default::default()
        });
        let (train, test) = full.split(cfg.train.train_examples);
        let shards = train.shard(cfg.workers, cfg.iid, cfg.train.seed ^ 0x5A4D);

        let tag = format!("train_{}", cfg.train.mode);
        let art = model.artifact(&tag).with_context(|| {
            format!("mode {:?} not exported for {}", cfg.train.mode, model.name)
        })?;
        let eval_exe = rt.load(model.artifact("fwd")?)?;
        let eval = crate::runtime::exec::EvalState::new(eval_exe, &model)?;

        let workers = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                WorkerHandle::spawn(i, shard, art.clone(), &model, cfg.train.clone())
            })
            .collect::<Result<Vec<_>>>()?;

        let global = ParamStore::init(&model, cfg.train.seed);
        Ok(Self {
            cfg,
            global,
            workers,
            test,
            eval,
            model_batch: model.batch,
        })
    }

    /// Bytes of one model broadcast (params only; momenta stay local,
    /// feedback B is derived from the shared seed — a real EfficientGrad
    /// deployment never ships B).
    fn model_bytes(&self) -> u64 {
        (self.global.param_elements() * 4) as u64
    }

    /// Run all rounds.
    pub fn run(&mut self) -> Result<FedSummary> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        let mut straggler_rng = Rng::new(self.cfg.train.seed ^ 0x57AA);
        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            // broadcast current global params
            let (tx, rx) = mpsc::channel::<WorkerReport>();
            let mut dispatched = 0usize;
            for w in &self.workers {
                let slowdown = if straggler_rng.uniform() < self.cfg.straggler_prob {
                    self.cfg.straggler_slowdown
                } else {
                    1.0
                };
                w.submit(WorkerTask {
                    round,
                    params: self.global.params.clone(),
                    local_steps: self.cfg.local_steps,
                    slowdown,
                    reply: tx.clone(),
                })?;
                dispatched += 1;
            }
            drop(tx);

            // gather
            let mut reports = Vec::with_capacity(dispatched);
            for _ in 0..dispatched {
                reports.push(rx.recv().context("worker died mid-round")?);
            }
            reports.sort_by_key(|r| r.worker_id);

            // aggregate (examples-weighted FedAvg)
            let weights: Vec<f64> = reports.iter().map(|r| r.examples as f64).collect();
            let updates: Vec<&Vec<crate::tensor::Tensor>> =
                reports.iter().map(|r| &r.params).collect();
            self.global.params = weighted_fedavg(&updates, &weights)?;

            let mean_loss = reports.iter().map(|r| r.mean_loss).sum::<f64>()
                / reports.len() as f64;
            let mean_sparsity = reports.iter().map(|r| r.mean_sparsity).sum::<f64>()
                / reports.len() as f64;
            let eval_acc = self.evaluate()?;
            let report = RoundReport {
                round,
                mean_loss,
                mean_sparsity,
                upload_bytes: self.model_bytes() * dispatched as u64,
                download_bytes: self.model_bytes() * dispatched as u64,
                eval_acc,
                wall_secs: t0.elapsed().as_secs_f64(),
                worker_secs: reports.iter().map(|r| r.sim_secs).collect(),
            };
            log::info!(
                "round {round:3} loss {mean_loss:.4} acc {eval_acc:.4} sparsity {mean_sparsity:.3} ({:.2}s)",
                report.wall_secs
            );
            rounds.push(report);
        }
        let final_acc = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
        let total_upload_bytes = rounds.iter().map(|r| r.upload_bytes).sum();
        let total_download_bytes = rounds.iter().map(|r| r.download_bytes).sum();
        Ok(FedSummary {
            rounds,
            final_acc,
            total_upload_bytes,
            total_download_bytes,
        })
    }

    fn evaluate(&self) -> Result<f64> {
        let mut correct = 0.0;
        let mut total = 0usize;
        for idx in crate::data::batcher::eval_batches(&self.test, self.model_batch) {
            let batch = self.test.gather(&idx);
            correct += self.eval.accuracy(&self.global, &batch)? * idx.len() as f64;
            total += idx.len();
        }
        if total == 0 {
            bail!("test set smaller than one batch");
        }
        Ok(correct / total as f64)
    }

    /// Graceful shutdown (joins worker threads).
    pub fn shutdown(self) {
        for w in self.workers {
            w.shutdown();
        }
    }
}
