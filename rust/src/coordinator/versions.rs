//! Version-tagged model references — the bounded ring behind the elastic
//! round schedule.
//!
//! The leader used to hold a single un-versioned `reference` replica, so
//! every worker had to be exactly one downlink behind (or pay a dense
//! resync), and a round could not close until every replica agreed. The
//! [`VersionRing`] generalizes that to a bounded history: each federated
//! round's fold produces a new [`ModelVersion`] — a version id, the
//! reference parameters workers at that version hold, and the encoded
//! per-round delta that advanced the previous version to it. With the
//! ring in hand:
//!
//! * every wire message is tagged with the version it was computed
//!   against (`WorkerTask::version` / `WorkerReport::base_version`), so
//!   a straggler's late report can be folded with the right staleness
//!   weight instead of being discarded;
//! * a worker `k ≤ max_chain` versions behind is resynced with
//!   [`VersionRing::chain_from`] — the *chain* of the retained per-round
//!   deltas, which replays exactly the downlinks it missed (same float
//!   ops, same order, so its replica lands bit-identical to an always-on
//!   peer's) at `8 + Σ link` wire bytes instead of a dense `4·P`
//!   snapshot (`docs/TRANSFER_MODEL.md` §Model versions & staleness).
//!
//! The ring is bounded: pushing past capacity evicts the oldest version,
//! after which workers that far behind fall back to a dense resync —
//! memory stays O(cap · P) no matter how long the run.

use std::collections::VecDeque;

use crate::comm::{ModelUpdate, TensorUpdate};
use crate::tensor::Tensor;

/// One retained snapshot of the reference trajectory.
#[derive(Clone, Debug)]
pub struct ModelVersion {
    /// version id: 0 is the genesis (init params); round r's fold
    /// produces version r+1
    pub version: u64,
    /// the reference params a worker at this version holds (the
    /// codec-decoded trajectory — *not* the leader's raw FedAvg output,
    /// whose un-shipped mass lives in the downlink codec's residual)
    pub params: Vec<Tensor>,
    /// the per-round delta that advanced `version − 1` to this version
    /// (`None` for the genesis, and for every version of a dense-comm
    /// run, where snapshots travel instead of deltas)
    pub delta: Option<Vec<TensorUpdate>>,
}

/// Bounded ring of [`ModelVersion`]s, newest last.
pub struct VersionRing {
    versions: VecDeque<ModelVersion>,
    cap: usize,
}

impl VersionRing {
    /// Start the ring at the genesis version 0 holding `params`.
    /// `cap` ≥ 2 versions are retained (the head plus at least one
    /// predecessor).
    pub fn new(cap: usize, params: Vec<Tensor>) -> Self {
        let mut versions = VecDeque::with_capacity(cap.max(2));
        versions.push_back(ModelVersion {
            version: 0,
            params,
            delta: None,
        });
        Self {
            versions,
            cap: cap.max(2),
        }
    }

    /// The newest version.
    pub fn head(&self) -> &ModelVersion {
        self.versions.back().expect("ring is never empty")
    }

    pub fn head_version(&self) -> u64 {
        self.head().version
    }

    /// Number of versions currently retained.
    pub fn retained(&self) -> usize {
        self.versions.len()
    }

    /// Look up a retained version by id.
    pub fn get(&self, version: u64) -> Option<&ModelVersion> {
        let oldest = self.versions.front()?.version;
        if version < oldest || version > self.head_version() {
            return None;
        }
        self.versions.get((version - oldest) as usize)
    }

    /// Append the next version (id `head + 1`), evicting the oldest
    /// beyond capacity. `delta` is the encoded per-round downlink that
    /// advanced the previous head to `params` (None in dense mode).
    /// Returns the new version id.
    pub fn push(&mut self, params: Vec<Tensor>, delta: Option<Vec<TensorUpdate>>) -> u64 {
        let version = self.head_version() + 1;
        self.versions.push_back(ModelVersion {
            version,
            params,
            delta,
        });
        while self.versions.len() > self.cap {
            self.versions.pop_front();
        }
        version
    }

    /// Iterate the retained versions oldest first — the run store
    /// persists exactly this window so a resumed coordinator can keep
    /// serving chained downlinks.
    pub fn iter(&self) -> impl Iterator<Item = &ModelVersion> {
        self.versions.iter()
    }

    /// Rebuild a ring from persisted versions (oldest first, contiguous
    /// ids). The crash/resume counterpart of [`VersionRing::iter`].
    pub fn from_versions(cap: usize, versions: Vec<ModelVersion>) -> anyhow::Result<Self> {
        if versions.is_empty() {
            anyhow::bail!("run store holds no model versions");
        }
        for w in versions.windows(2) {
            if w[1].version != w[0].version + 1 {
                anyhow::bail!(
                    "run store versions not contiguous: {} then {}",
                    w[0].version,
                    w[1].version
                );
            }
        }
        let cap = cap.max(2);
        if versions.len() > cap {
            anyhow::bail!("run store holds {} versions, ring capacity {cap}", versions.len());
        }
        Ok(Self {
            versions: versions.into(),
            cap,
        })
    }

    /// The chained downlink that brings a replica at version `base` up
    /// to the head: the retained per-round deltas `base+1 ..= head`,
    /// oldest first. `None` when the chain cannot be built — `base` is
    /// the head already, a needed version was evicted, or any link in
    /// the window has no delta (dense-comm rounds) — in which case the
    /// caller falls back to a dense resync.
    pub fn chain_from(&self, base: u64) -> Option<ModelUpdate> {
        let head = self.head_version();
        if base >= head {
            return None;
        }
        let links: Option<Vec<Vec<TensorUpdate>>> = (base + 1..=head)
            .map(|v| self.get(v).and_then(|mv| mv.delta.clone()))
            .collect();
        Some(ModelUpdate::Chain(links?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::wire::{chained_model_bytes, SparseTensor};

    fn t(v: &[f32]) -> Tensor {
        Tensor::new(vec![v.len()], v.to_vec())
    }

    fn delta(v: &[f32]) -> Vec<TensorUpdate> {
        vec![TensorUpdate::Sparse(SparseTensor::encode(v))]
    }

    /// Push `n` sparse deltas onto a genesis-zero ring, advancing the
    /// params by each delta like the leader does.
    fn ring_with(n: usize, cap: usize) -> VersionRing {
        let mut ring = VersionRing::new(cap, vec![Tensor::zeros(&[3])]);
        for i in 0..n {
            let d = delta(&[i as f32 + 1.0, 0.0, -(i as f32) - 1.0]);
            let mut params = ring.head().params.clone();
            ModelUpdate::Chain(vec![d.clone()]).apply(&mut params).unwrap();
            ring.push(params, Some(d));
        }
        ring
    }

    #[test]
    fn ring_retains_a_bounded_window() {
        let ring = ring_with(5, 3);
        assert_eq!(ring.head_version(), 5);
        assert_eq!(ring.retained(), 3);
        assert!(ring.get(2).is_none(), "evicted version must be gone");
        assert!(ring.get(3).is_some());
        assert!(ring.get(6).is_none());
        assert_eq!(ring.get(5).unwrap().version, 5);
    }

    #[test]
    fn chain_from_replays_to_bit_identical_params_for_k_1_2_3() {
        // the chained-downlink ≡ dense-resync param-parity pin: a worker
        // k ∈ {1, 2, 3} versions behind that applies the chain must land
        // on EXACTLY the head's reference params — the same floats a
        // dense resync would have shipped
        let ring = ring_with(3, 4);
        for k in 1..=3u64 {
            let base = ring.head_version() - k;
            let mut replica = ring.get(base).unwrap().params.clone();
            let chain = ring.chain_from(base).unwrap();
            // bytes follow the documented formula: header + Σ links
            let want_bytes = chained_model_bytes((base + 1..=ring.head_version()).map(|v| {
                ring.get(v)
                    .unwrap()
                    .delta
                    .as_ref()
                    .unwrap()
                    .iter()
                    .map(|u| u.wire_bytes())
                    .sum()
            }));
            assert_eq!(chain.wire_bytes(), want_bytes, "k={k}");
            chain.apply(&mut replica).unwrap();
            assert_eq!(
                replica,
                ring.head().params,
                "k={k}: chain replay diverged from the dense-resync params"
            );
        }
    }

    #[test]
    fn quantized_chain_replays_bit_identically_and_ships_merged_bytes() {
        // same pin as above, but the retained deltas are v2 Quantized
        // tensors: chain_from must (a) replay to exactly the head params
        // — apply is still link-by-link, merging is a wire encoding only
        // — and (b) account wire bytes by the merged-chain formula,
        // which undercuts the legacy f32-sparse chain PR 9 shipped
        use crate::comm::wire::{merged_chain_bytes, sparse_tensor_bytes, QuantBits, QuantTensor};
        let qdelta = |v: &[f32]| {
            vec![TensorUpdate::Quantized(QuantTensor::encode(v, QuantBits::Q8))]
        };
        let mut ring = VersionRing::new(4, vec![Tensor::zeros(&[64])]);
        for i in 0..3 {
            let mut dense = vec![0.0f32; 64];
            // overlapping supports so the merged union is non-trivial
            for j in (i * 8)..(i * 8 + 24) {
                dense[j] = (j as f32 - 12.0) * 0.25;
            }
            let d = qdelta(&dense);
            let mut params = ring.head().params.clone();
            ModelUpdate::Chain(vec![d.clone()]).apply(&mut params).unwrap();
            ring.push(params, Some(d));
        }
        for k in 1..=3u64 {
            let base = ring.head_version() - k;
            let mut replica = ring.get(base).unwrap().params.clone();
            let chain = ring.chain_from(base).unwrap();
            let ModelUpdate::Chain(links) = &chain else { panic!() };
            let per_link_v1 = chained_model_bytes(
                links.iter().map(|l| l.iter().map(|u| u.wire_bytes()).sum()),
            );
            if k >= 2 {
                // the merge needs ≥ 2 links to amortize the shared
                // support; a single link rides the v1 record
                assert_eq!(chain.wire_bytes(), merged_chain_bytes(links), "k={k}");
            } else {
                assert_eq!(chain.wire_bytes(), per_link_v1, "k={k}");
            }
            // every k undercuts what the legacy f32-sparse chain would
            // have shipped for the same survivors (8 B each + support)
            let legacy = chained_model_bytes(links.iter().map(|l| {
                l.iter()
                    .map(|u| {
                        let TensorUpdate::Quantized(q) = u else { panic!() };
                        sparse_tensor_bytes(q.nnz())
                    })
                    .sum()
            }));
            assert!(
                chain.wire_bytes() < legacy,
                "k={k}: quantized chain {} >= legacy f32 chain {legacy}",
                chain.wire_bytes()
            );
            chain.apply(&mut replica).unwrap();
            assert_eq!(
                replica,
                ring.head().params,
                "k={k}: quantized chain replay diverged"
            );
        }
    }

    #[test]
    fn iter_and_from_versions_roundtrip_the_window() {
        let ring = ring_with(4, 3);
        let persisted: Vec<ModelVersion> = ring.iter().cloned().collect();
        assert_eq!(persisted.len(), 3);
        let rebuilt = VersionRing::from_versions(3, persisted).unwrap();
        assert_eq!(rebuilt.head_version(), ring.head_version());
        assert_eq!(rebuilt.head().params, ring.head().params);
        assert_eq!(
            rebuilt.chain_from(2).unwrap().wire_bytes(),
            ring.chain_from(2).unwrap().wire_bytes()
        );
        // a gap in the ids is a torn store, not a ring
        let mut gappy: Vec<ModelVersion> = ring.iter().cloned().collect();
        gappy.remove(1);
        assert!(VersionRing::from_versions(3, gappy).is_err());
        assert!(VersionRing::from_versions(3, Vec::new()).is_err());
    }

    #[test]
    fn chain_from_refuses_when_history_is_missing() {
        // current replica: nothing to chain
        let ring = ring_with(3, 4);
        assert!(ring.chain_from(3).is_none());
        // evicted base: the window moved past it
        let ring = ring_with(5, 3);
        assert!(ring.chain_from(1).is_none());
        // dense-mode history (no deltas retained): chain unavailable
        let mut ring = VersionRing::new(4, vec![t(&[0.0])]);
        ring.push(vec![t(&[1.0])], None);
        assert!(ring.chain_from(0).is_none());
    }
}
