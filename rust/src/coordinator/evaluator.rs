//! Off-thread evaluator — the pipelined leader's eval stage.
//!
//! In the sequential schedule the leader's test-set sweep sits on the
//! round-critical path: round wall time = slowest worker + decode +
//! FedAvg + **a full eval sweep** + downlink encode. The evaluator moves
//! that sweep onto its own thread: the leader ships each round's
//! post-FedAvg parameter snapshot through a channel and immediately goes
//! on to encode the downlink and dispatch round r+1, while accuracy
//! computes concurrently with the next round's worker compute.
//!
//! The thread owns its own [`Runtime`] + [`EvalState`] — the `xla`
//! crate's PJRT handles are not `Send`, so one `Runtime` per thread is
//! the documented contract (`runtime/mod.rs`), exactly as the federated
//! workers already do. The sweep body itself is
//! [`EvalState::dataset_accuracy`], the same function the sequential
//! leader calls, so the pipelined `eval_acc` and the leader-eval
//! transfer ledger are bit-identical to the oracle's
//! (`tests/federated.rs` pins it).
//!
//! Results are joined asynchronously: the leader drains whatever is
//! ready at round-log time ([`Evaluator::drain_ready`]) and blocks for
//! the stragglers only once, before building the `FedSummary`
//! ([`Evaluator::wait_for`]).

use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::ResidencyMode;
use crate::data::Dataset;
use crate::manifest::{ArtifactSpec, ModelSpec};
use crate::params::ParamStore;
use crate::runtime::exec::EvalState;
use crate::runtime::{Runtime, TransferStats};
use crate::tensor::Tensor;

/// One finished round evaluation, joined into its `RoundReport` by the
/// leader.
#[derive(Clone, Debug)]
pub struct EvalOutcome {
    /// the round whose post-FedAvg params were evaluated
    pub round: usize,
    /// global-model accuracy on the leader's test set
    pub acc: f64,
    /// the evaluator's host↔device ledger for this sweep (one `4·P`
    /// param upload per round with resident eval, same as the oracle)
    pub transfer: TransferStats,
}

struct EvalJob {
    round: usize,
    params: Vec<Tensor>,
}

/// Handle to the evaluator thread. Dropping it closes the job channel
/// and joins the thread.
pub struct Evaluator {
    tx: Option<Sender<EvalJob>>,
    rx: Receiver<Result<EvalOutcome, String>>,
    join: Option<JoinHandle<()>>,
}

impl Evaluator {
    /// Spawn the evaluator thread: it brings up its own PJRT client,
    /// compiles the fwd artifact, and owns `test`. Compile failures
    /// surface through the ready handshake so `spawn` stays synchronous
    /// and fallible (the `WorkerHandle::spawn` pattern).
    pub fn spawn(
        model: &ModelSpec,
        fwd: ArtifactSpec,
        eval_residency: ResidencyMode,
        test: Dataset,
        seed: u64,
    ) -> Result<Self> {
        let model = model.clone();
        let (tx, job_rx) = mpsc::channel::<EvalJob>();
        let (out_tx, rx) = mpsc::channel::<Result<EvalOutcome, String>>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("leader-eval".into())
            .spawn(move || {
                let setup = (|| -> Result<(EvalState, ParamStore)> {
                    let rt = Runtime::cpu()?;
                    let exe = rt.load(&fwd)?;
                    let eval = EvalState::new(&rt, exe, &model, eval_residency)?;
                    // the store only lends its params/shape to the fwd
                    // artifact; each job overwrites them with the round's
                    // post-FedAvg snapshot
                    Ok((eval, ParamStore::init(&model, seed)))
                })();
                let (eval, mut store) = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = job_rx.recv() {
                    store.params = job.params;
                    eval.reset_transfer_stats();
                    let out = eval
                        .dataset_accuracy(&store, &test, model.batch)
                        .map(|acc| EvalOutcome {
                            round: job.round,
                            acc,
                            transfer: eval.transfer_stats(),
                        })
                        .map_err(|e| format!("{e:#}"));
                    if out_tx.send(out).is_err() {
                        return; // leader gone
                    }
                }
            })
            .map_err(|e| anyhow!("spawning evaluator thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("evaluator died during startup"))?
            .map_err(|e| e.context("evaluator failed to compile fwd artifact"))?;
        Ok(Self {
            tx: Some(tx),
            rx,
            join: Some(join),
        })
    }

    /// Queue one round's post-FedAvg snapshot (non-blocking; jobs are
    /// evaluated FIFO).
    pub fn submit(&self, round: usize, params: Vec<Tensor>) -> Result<()> {
        self.tx
            .as_ref()
            .expect("evaluator channel open while handle lives")
            .send(EvalJob { round, params })
            .map_err(|_| anyhow!("evaluator channel closed"))
    }

    /// Every outcome that has finished so far — never blocks (round-log
    /// time: join the latest available results into their reports).
    pub fn drain_ready(&self) -> Result<Vec<EvalOutcome>> {
        let mut out = Vec::new();
        loop {
            match self.rx.try_recv() {
                Ok(Ok(o)) => out.push(o),
                Ok(Err(e)) => return Err(anyhow!("evaluator: {e}")),
                Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
            }
        }
        Ok(out)
    }

    /// Block until `n` more outcomes arrive (run teardown: every
    /// submitted round must be joined before the `FedSummary` is built).
    pub fn wait_for(&self, n: usize) -> Result<Vec<EvalOutcome>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.rx.recv() {
                Ok(Ok(o)) => out.push(o),
                Ok(Err(e)) => return Err(anyhow!("evaluator: {e}")),
                Err(_) => return Err(anyhow!("evaluator thread died with evals outstanding")),
            }
        }
        Ok(out)
    }
}

impl Drop for Evaluator {
    fn drop(&mut self) {
        self.tx.take(); // close the job channel so the thread exits
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
