//! FedAvg aggregation (McMahan et al. 2017 — the paper's reference [16]).
//!
//! Two paths: the dense mean over full parameter snapshots
//! ([`weighted_fedavg`], the legacy exchange), and the sparse-accumulate
//! path over pruned wire deltas ([`weighted_sparse_fedavg`]) — the leader
//! folds each worker's surviving coordinates straight into the global
//! params in O(nnz) per worker instead of decoding dense per-worker
//! tensors.

use anyhow::{bail, Result};

use crate::comm::TensorUpdate;
use crate::tensor::Tensor;

/// Unweighted mean of parameter sets.
pub fn fedavg(updates: &[&Vec<Tensor>]) -> Result<Vec<Tensor>> {
    let w = vec![1.0; updates.len()];
    weighted_fedavg(updates, &w)
}

/// Examples-weighted FedAvg: global_i = Σ_k (n_k / n) · params_k,i.
///
/// ```
/// use efficientgrad::coordinator::weighted_fedavg;
/// use efficientgrad::tensor::Tensor;
/// let a = vec![Tensor::new(vec![2], vec![0.0, 2.0])];
/// let b = vec![Tensor::new(vec![2], vec![4.0, 6.0])];
/// // worker b holds 3x the examples of worker a
/// let global = weighted_fedavg(&[&a, &b], &[1.0, 3.0]).unwrap();
/// assert_eq!(global[0].data(), &[3.0, 5.0]);
/// ```
pub fn weighted_fedavg(updates: &[&Vec<Tensor>], weights: &[f64]) -> Result<Vec<Tensor>> {
    if updates.is_empty() {
        bail!("no updates to aggregate");
    }
    if updates.len() != weights.len() {
        bail!("{} updates vs {} weights", updates.len(), weights.len());
    }
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        bail!("non-positive total weight");
    }
    let n_tensors = updates[0].len();
    for (k, u) in updates.iter().enumerate() {
        if u.len() != n_tensors {
            bail!("worker {k} returned {} tensors, expected {n_tensors}", u.len());
        }
    }
    // seed the accumulator with a scaled copy of the first update: one
    // pass, no zero-fill + axpy double traversal
    let alpha0 = (weights[0] / total) as f32;
    let mut out: Vec<Tensor> = updates[0].iter().map(|t| t.scaled(alpha0)).collect();
    for (k, u) in updates.iter().enumerate().skip(1) {
        let alpha = (weights[k] / total) as f32;
        for (acc, t) in out.iter_mut().zip(u.iter()) {
            if acc.shape() != t.shape() {
                bail!("worker {k}: shape mismatch {:?} vs {:?}", t.shape(), acc.shape());
            }
            acc.axpy(alpha, t);
        }
    }
    Ok(out)
}

/// Delta FedAvg over pruned wire updates:
/// `global_i = base_i + Σ_k (n_k / n) · decode(Δ_k)_i`.
///
/// `base` is the reference the workers trained from (each worker's
/// `local_k = base + decode(Δ_k)` up to pruning error, which its codec
/// carries as error-feedback residual), so this is exactly
/// `Σ_k w_k · local_k` in expectation — the FedAvg semantic carried to
/// the compressed wire. Cost: one O(P) copy of `base`, then O(nnz) per
/// worker ([`Tensor::axpy_sparse`] underneath), never O(P·workers).
///
/// ```
/// use efficientgrad::comm::{SparseTensor, TensorUpdate};
/// use efficientgrad::coordinator::weighted_sparse_fedavg;
/// use efficientgrad::tensor::Tensor;
/// let base = vec![Tensor::new(vec![3], vec![1.0, 1.0, 1.0])];
/// // worker a moved coord 0 by +2, worker b (3x the examples) coord 2 by -4
/// let a = vec![TensorUpdate::Sparse(SparseTensor::encode(&[2.0, 0.0, 0.0]))];
/// let b = vec![TensorUpdate::Sparse(SparseTensor::encode(&[0.0, 0.0, -4.0]))];
/// let g = weighted_sparse_fedavg(&base, &[&a, &b], &[1.0, 3.0]).unwrap();
/// assert_eq!(g[0].data(), &[1.5, 1.0, -2.0]);
/// ```
pub fn weighted_sparse_fedavg(
    base: &[Tensor],
    updates: &[&Vec<TensorUpdate>],
    weights: &[f64],
) -> Result<Vec<Tensor>> {
    if updates.is_empty() {
        bail!("no updates to aggregate");
    }
    if updates.len() != weights.len() {
        bail!("{} updates vs {} weights", updates.len(), weights.len());
    }
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        bail!("non-positive total weight");
    }
    let mut out: Vec<Tensor> = base.to_vec();
    for (k, u) in updates.iter().enumerate() {
        if u.len() != base.len() {
            bail!("worker {k} sent {} delta tensors, expected {}", u.len(), base.len());
        }
        let alpha = (weights[k] / total) as f32;
        for (acc, tu) in out.iter_mut().zip(u.iter()) {
            if tu.elems() != acc.len() {
                bail!(
                    "worker {k}: delta sized {} vs tensor {}",
                    tu.elems(),
                    acc.len()
                );
            }
            tu.axpy_into(alpha, acc);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{for_all, UsizeIn};
    use crate::util::rng::Rng;

    fn t(v: &[f32]) -> Tensor {
        Tensor::new(vec![v.len()], v.to_vec())
    }

    #[test]
    fn unweighted_mean() {
        let a = vec![t(&[1.0, 2.0])];
        let b = vec![t(&[3.0, 4.0])];
        let out = fedavg(&[&a, &b]).unwrap();
        assert_eq!(out[0].data(), &[2.0, 3.0]);
    }

    #[test]
    fn weighted_mean() {
        let a = vec![t(&[0.0])];
        let b = vec![t(&[10.0])];
        let out = weighted_fedavg(&[&a, &b], &[1.0, 3.0]).unwrap();
        assert!((out[0].data()[0] - 7.5).abs() < 1e-6);
    }

    #[test]
    fn rejects_mismatches() {
        let a = vec![t(&[0.0])];
        let b = vec![t(&[1.0]), t(&[2.0])];
        assert!(fedavg(&[&a, &b]).is_err());
        assert!(weighted_fedavg(&[&a], &[]).is_err());
        assert!(weighted_fedavg(&[&a], &[0.0]).is_err());
        let c = vec![t(&[1.0, 2.0])];
        assert!(fedavg(&[&a, &c]).is_err());
        let empty: &[&Vec<Tensor>] = &[];
        assert!(fedavg(empty).is_err());
    }

    #[test]
    fn sparse_fedavg_matches_dense_on_equivalent_inputs() {
        use crate::comm::{SparseTensor, TensorUpdate};
        // base + Δ_k == the dense snapshots handed to weighted_fedavg:
        // both paths must agree to f32 rounding
        let base = vec![t(&[1.0, -2.0, 0.5, 0.0])];
        let d1 = [0.5f32, 0.0, -0.25, 0.0];
        let d2 = [0.0f32, 1.0, 0.0, 2.0];
        let weights = [2.0, 3.0];
        let dense1 = vec![t(&[1.5, -2.0, 0.25, 0.0])];
        let dense2 = vec![t(&[1.0, -1.0, 0.5, 2.0])];
        let want = weighted_fedavg(&[&dense1, &dense2], &weights).unwrap();
        let u1 = vec![TensorUpdate::Sparse(SparseTensor::encode(&d1))];
        let u2 = vec![TensorUpdate::Sparse(SparseTensor::encode(&d2))];
        let got = weighted_sparse_fedavg(&base, &[&u1, &u2], &weights).unwrap();
        for (a, b) in want[0].data().iter().zip(got[0].data()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_fedavg_rejects_mismatches() {
        use crate::comm::{SparseTensor, TensorUpdate};
        let base = vec![t(&[0.0, 0.0])];
        let ok = vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0, 0.0]))];
        let wrong_size = vec![TensorUpdate::Sparse(SparseTensor::encode(&[1.0]))];
        let wrong_count: Vec<TensorUpdate> = vec![];
        assert!(weighted_sparse_fedavg(&base, &[&ok], &[1.0]).is_ok());
        assert!(weighted_sparse_fedavg(&base, &[&wrong_size], &[1.0]).is_err());
        assert!(weighted_sparse_fedavg(&base, &[&wrong_count], &[1.0]).is_err());
        assert!(weighted_sparse_fedavg(&base, &[&ok], &[]).is_err());
        assert!(weighted_sparse_fedavg(&base, &[&ok], &[0.0]).is_err());
        let none: &[&Vec<TensorUpdate>] = &[];
        assert!(weighted_sparse_fedavg(&base, none, &[]).is_err());
    }

    #[test]
    fn prop_identical_updates_are_fixed_point() {
        // FedAvg(k copies of P) == P for any k and any tensor contents
        for_all(11, &UsizeIn(1, 8), 32, |&k| {
            let mut rng = Rng::new(k as u64);
            let mut data = vec![0f32; 33];
            rng.fill_normal(&mut data, 2.0);
            let p = vec![t(&data)];
            let refs: Vec<&Vec<Tensor>> = (0..k).map(|_| &p).collect();
            let out = fedavg(&refs).map_err(|e| e.to_string())?;
            let max_err = out[0]
                .data()
                .iter()
                .zip(&data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if max_err < 1e-5 {
                Ok(())
            } else {
                Err(format!("fixed point violated: {max_err}"))
            }
        });
    }

    #[test]
    fn prop_aggregate_within_convex_hull() {
        // every coordinate of the aggregate lies in [min, max] of inputs
        for_all(12, &UsizeIn(2, 6), 32, |&k| {
            let mut sets = Vec::new();
            for i in 0..k {
                let mut rng = Rng::new(100 + i as u64);
                let mut d = vec![0f32; 17];
                rng.fill_normal(&mut d, 1.0);
                sets.push(vec![t(&d)]);
            }
            let refs: Vec<&Vec<Tensor>> = sets.iter().collect();
            let weights: Vec<f64> = (1..=k).map(|i| i as f64).collect();
            let out = weighted_fedavg(&refs, &weights).map_err(|e| e.to_string())?;
            for j in 0..17 {
                let lo = sets.iter().map(|s| s[0].data()[j]).fold(f32::MAX, f32::min);
                let hi = sets.iter().map(|s| s[0].data()[j]).fold(f32::MIN, f32::max);
                let v = out[0].data()[j];
                if v < lo - 1e-5 || v > hi + 1e-5 {
                    return Err(format!("coord {j}: {v} outside [{lo}, {hi}]"));
                }
            }
            Ok(())
        });
    }
}
